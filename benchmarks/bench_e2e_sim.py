"""Paper §5.4: end-to-end simulator integration — wall-time of simulating
the full workload vs only the representative kernels (+ reconstruction),
with the resulting cycle error.  Mirrors the HyFiSS integration: the sampled
run feeds the simulator a script of representative kernels and scales by
cluster weights."""

from __future__ import annotations

from benchmarks.common import metrics_for, plans_for, save_results
from repro.sim.simulate import sampling_error, sim_wall_time

PROGRAMS = ("nw", "lu", "cfd", "phi-2", "pythia")


def run(programs=PROGRAMS, fast: bool = False, verbose: bool = True):
    table = {}
    for prog in programs:
        plan = plans_for(prog, fast=fast, verbose=verbose)["GCL-Sampler"]
        ms = metrics_for(prog, "P1")
        t_full = sim_wall_time(ms)
        t_sampled = sim_wall_time(ms, plan.rep_indices())
        table[prog] = {
            "sim_time_full_s": t_full,
            "sim_time_sampled_s": t_sampled,
            "sim_speedup": t_full / max(t_sampled, 1e-12),
            "cycle_error_pct": sampling_error(plan, ms),
            "reps": len(plan.rep_indices()),
            "kernels": len(ms),
        }
        if verbose:
            r = table[prog]
            print(f"[e2e] {prog:8s} full {r['sim_time_full_s']:8.1f}s -> "
                  f"sampled {r['sim_time_sampled_s']:6.1f}s "
                  f"({r['sim_speedup']:.1f}x, err {r['cycle_error_pct']:.2f}%)",
                  flush=True)
    save_results("e2e_simulation", table)
    return table


if __name__ == "__main__":
    run()
