"""Scale-out benchmark: train + plan engines across a simulated device mesh.

Runs STANDALONE in its own process (``python -m benchmarks.bench_scaleout``)
because ``--xla_force_host_platform_device_count`` must be set before jax
initializes — ``benchmarks.run`` therefore shells out via :func:`run`
instead of importing jax-side code from this module.

Reported per device count (1..N simulated host devices):

- **train**: measured steps/s of the compiled scan engine on a
  data-parallel mesh, plus the MODELLED scaling — per-device FLOPs of the
  compiled sharded scan from XLA ``cost_analysis`` (under SPMD
  partitioning cost_analysis is per-device, the same methodology as
  ``repro.launch.dryrun``), with per-device collective bytes from the
  partitioned HLO;
- **plan**: measured plans/s of the sharded K-sweep dispatch (one dispatch
  serves N_devices x max_batch programs), modelled per-program-per-device
  FLOPs scaling, and the warm-path recompile count (MUST be 0: the
  executable-cache key is device-count-aware);
- **grad compression**: per-device collective bytes of the data-parallel
  gradient exchange over the REAL model's parameter tree — exact f32
  ``psum_mean`` vs error-feedback int8 ``compressed_psum_mean`` (int16
  reduce payload), both lowered under shard_map.

Why modelled speedup is the headline: simulated host devices share the
machine's physical cores, so wall-clock on a 1-core CI runner CANNOT show
parallel speedup — per-device compute from the partitioned executable is
the hardware-independent scaling signal (deterministic, stable in CI).
Wall-clock numbers are still reported and gated as no-regression floors.

Results go to ``benchmarks/results/scaleout.json`` AND a repo-root
``BENCH_scaleout.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_scaleout.json")
FORCE_FLAG = "--xla_force_host_platform_device_count"


def run(fast: bool = True, device_counts=None):
    """benchmarks.run entry point: re-exec this module in a fresh process
    (the forced-host-device flag cannot take effect in a process that
    already imported jax), then return the written artifact."""
    cmd = [sys.executable, "-m", "benchmarks.bench_scaleout"]
    if fast:
        cmd.append("--smoke")
    if device_counts:
        cmd += ["--devices", ",".join(str(d) for d in device_counts)]
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", os.path.join(REPO_ROOT, "src"))
    subprocess.run(cmd, check=True, env=env, cwd=REPO_ROOT)
    with open(BENCH_PATH) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# everything below runs only in the re-exec'd process (jax imported lazily,
# AFTER main() pins XLA_FLAGS)
# ---------------------------------------------------------------------------


def _cost(compiled) -> dict:
    """Per-device flops + collective bytes of a compiled executable (list-
    or dict-shaped cost_analysis, depending on jax version)."""
    from repro.launch.roofline import collective_bytes_from_hlo

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops_per_device": float(ca.get("flops") or 0.0),
            "coll_bytes_per_device": float(coll["per_device_bytes"])}


def _train_graphs(n=12, cap=48):
    from repro.core.graphs import build_kernel_graph
    from repro.tracing.templates import make_kernel

    ks = [make_kernel(f"k{i}", "gemm",
                      {"M": 128 * (i % 3 + 1), "N": 128, "K": 128}, i, seed=i)
          for i in range(n)]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=cap))
            for k in ks]


def _lower_scan(trainer, graphs, rules):
    """Lower + compile the REAL engine scan on representative sharded
    inputs — the same staging path ``_fit_scan`` runs."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import rgcn as rgcn_mod
    from repro.core.batching import (
        MAX_EDGES_PER_MICROBATCH, MAX_NODES_PER_MICROBATCH, bucket_size,
        plan_epoch,
    )
    from repro.distributed.sharding import shard_batch_put
    from repro.optim import adamw_init

    tc = trainer.tc
    rng = np.random.default_rng(tc.seed)
    bs = min(tc.batch_size, len(graphs))
    selections = np.stack([rng.choice(len(graphs), size=bs)
                           for _ in range(tc.steps)])
    plan = plan_epoch(graphs, selections,
                      max_nodes_per_graph=MAX_NODES_PER_MICROBATCH,
                      max_edges_per_graph=MAX_EDGES_PER_MICROBATCH)
    chunk_len = min(tc.scan_chunk, bucket_size(max(plan.n_steps, 1), 1))
    seg = plan.segments[0]
    rows_np = {f: arr[:chunk_len] for f, arr in seg.batches.items()}
    stacked = shard_batch_put(rows_np, rules, leading=1)
    key = jax.random.PRNGKey(tc.seed)
    base_key, k_init = jax.random.split(key)
    params = rgcn_mod.init_rgcn(k_init, trainer.rc)
    state = adamw_init(params, trainer._opt)
    keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(
        jnp.arange(chunk_len))
    live = jnp.ones((chunk_len,), bool)
    eng = trainer._engine()
    return eng.scan.lower(state, stacked, keys, live).compile()


def _bench_train(ndevs, steps, batch_size) -> dict:
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import ContrastiveTrainer, GCLTrainConfig
    from repro.launch.mesh import make_data_mesh

    graphs = _train_graphs(n=max(12, batch_size + 4))
    tc = GCLTrainConfig(steps=steps, batch_size=batch_size,
                        scan_chunk=8, log_every=1000)
    out = {}
    for ndev in ndevs:
        rules = make_data_mesh(ndev) if ndev > 1 else None
        trainer = ContrastiveTrainer(RGCNConfig(), tc, mesh_rules=rules)
        trainer.fit(graphs)            # warm: compiles land here
        t0 = time.perf_counter()
        _, info = trainer.fit(graphs)
        wall = time.perf_counter() - t0
        rec = _cost(_lower_scan(trainer, graphs, rules))
        rec.update(steps_per_s_wall=steps / wall,
                   data_shards=info["data_shards"])
        out[str(ndev)] = rec
        print(f"[scaleout] train ndev={ndev}: "
              f"{rec['steps_per_s_wall']:.2f} steps/s wall, "
              f"{rec['flops_per_device']:.3g} flops/dev", flush=True)
    base = out[str(ndevs[0])]["flops_per_device"]
    for ndev in ndevs:
        out[str(ndev)]["modelled_speedup"] = (
            base / max(out[str(ndev)]["flops_per_device"], 1.0))
    return out


def _bench_grad_compress(ndev) -> dict:
    """Per-device collective bytes of the DP gradient exchange on the real
    parameter tree: exact f32 psum_mean vs error-feedback int8 (int16
    reduce payload)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import rgcn as rgcn_mod
    from repro.core.rgcn import RGCNConfig
    from repro.launch.mesh import make_data_mesh
    from repro.launch.roofline import collective_bytes_from_hlo
    from repro.optim.grad_compress import compressed_psum_mean, psum_mean

    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), RGCNConfig())
    mesh = make_data_mesh(ndev).mesh
    rep = jax.tree_util.tree_map(lambda _: P(), params)

    def f32(grads):
        return psum_mean(grads, "data")

    def int8(grads, err):
        return compressed_psum_mean(grads, err, "data")

    err = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    low_f32 = jax.jit(shard_map(f32, mesh=mesh, in_specs=(rep,),
                                out_specs=rep)).lower(params)
    low_i8 = jax.jit(shard_map(int8, mesh=mesh, in_specs=(rep, rep),
                               out_specs=(rep, rep))).lower(params, err)
    b_f32 = collective_bytes_from_hlo(
        low_f32.compile().as_text())["per_device_bytes"]
    b_i8 = collective_bytes_from_hlo(
        low_i8.compile().as_text())["per_device_bytes"]
    # numerics sanity: compressed mean tracks the exact mean
    g_ref = jax.jit(shard_map(f32, mesh=mesh, in_specs=(rep,),
                              out_specs=rep))(params)
    g_cmp, _ = jax.jit(shard_map(int8, mesh=mesh, in_specs=(rep, rep),
                                 out_specs=(rep, rep)))(params, err)
    errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            / (float(jnp.max(jnp.abs(a))) + 1e-12)
            for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                            jax.tree_util.tree_leaves(g_cmp))]
    return {"devices": ndev,
            "f32_coll_bytes_per_device": float(b_f32),
            "int8_coll_bytes_per_device": float(b_i8),
            "bytes_reduction": float(b_f32) / max(float(b_i8), 1.0),
            "max_rel_quant_err": max(errs)}


def _bench_plan(ndevs, n_programs, points, dim, max_batch) -> dict:
    import numpy as np

    from repro.core.clustering import (
        _effective_shards, _round_sil_block, _shard_args, _sweep_fn,
        bucket_points, engine_stats,
    )
    from repro.sampling.engine import PlanEngine

    rng = np.random.default_rng(0)
    embs = [rng.normal(size=(points - (i % 4), dim)).astype(np.float32)
            for i in range(n_programs)]
    out = {}
    for ndev in ndevs:
        eng = PlanEngine(k_max=8, iters=10, max_batch=max_batch,
                         data_devices=ndev)
        eng.cluster_many(embs)         # warm: compiles land here
        b0 = engine_stats()["builds"]
        t0 = time.perf_counter()
        eng.cluster_many(embs)
        wall = time.perf_counter() - t0
        recompiles = engine_stats()["builds"] - b0

        # modelled: per-program per-device flops of ONE full dispatch
        # (ndev x max_batch programs), from the cached sharded executable
        b_total = max_batch * ndev
        n_pad = bucket_points(points)
        shards = _effective_shards(b_total, ndev)
        fn = _sweep_fn(b_total, n_pad, dim, 8, 10, False,
                       _round_sil_block(n_pad, 512), shards)
        args = (np.zeros((b_total, n_pad, dim), np.float32),
                np.zeros((b_total, n_pad), bool),
                np.zeros((b_total, 8), np.int32),
                np.zeros((b_total, n_pad), bool))
        if shards > 1:
            args = _shard_args(args, shards)
        cost = _cost(fn.lower(*args).compile())
        rec = {
            "plans_per_s_wall": n_programs / wall,
            "warm_recompiles": int(recompiles),
            "dispatches": eng.stats["dispatches"],
            "flops_per_program_per_device":
                cost["flops_per_device"] / b_total,
            "coll_bytes_per_device": cost["coll_bytes_per_device"],
            "data_shards": shards,
        }
        out[str(ndev)] = rec
        print(f"[scaleout] plan ndev={ndev}: "
              f"{rec['plans_per_s_wall']:.1f} plans/s wall, "
              f"{rec['warm_recompiles']} warm recompiles", flush=True)
    base = out[str(ndevs[0])]["flops_per_program_per_device"]
    for ndev in ndevs:
        out[str(ndev)]["modelled_speedup"] = (
            base / max(out[str(ndev)]["flops_per_program_per_device"], 1.0))
    return out


def _bench(ndevs, fast: bool) -> dict:
    import jax

    steps = 8 if fast else 32
    doc = {
        "device_counts": list(ndevs),
        "backend_devices": jax.device_count(),
        "fast": fast,
        "train": _bench_train(ndevs, steps=steps,
                              batch_size=8 if fast else 16),
        "plan": _bench_plan(ndevs, n_programs=32 if fast else 128,
                            points=64, dim=16, max_batch=4 if fast else 8),
        "grad_compress": _bench_grad_compress(max(ndevs)),
    }
    top = str(max(ndevs))
    doc["headline"] = {
        "train_modelled_speedup": doc["train"][top]["modelled_speedup"],
        "plan_modelled_speedup": doc["plan"][top]["modelled_speedup"],
        "warm_recompiles": max(v["warm_recompiles"]
                               for v in doc["plan"].values()),
        "grad_compress_bytes_reduction":
            doc["grad_compress"]["bytes_reduction"],
    }
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma list of simulated device counts")
    args = ap.parse_args()
    ndevs = sorted({int(d) for d in args.devices.split(",")})
    if args.smoke:
        ndevs = [d for d in ndevs if d in (min(ndevs), max(ndevs))]

    # the forced-host-device flag only works BEFORE jax initializes
    if "jax" in sys.modules:
        import jax

        if jax.device_count() < max(ndevs):
            raise SystemExit(
                f"jax already initialized with {jax.device_count()} "
                f"device(s); run this module in a fresh process")
    else:
        os.environ["XLA_FLAGS"] = " ".join(
            p for p in [os.environ.get("XLA_FLAGS", ""),
                        f"{FORCE_FLAG}={max(ndevs)}"] if p)

    doc = _bench(ndevs, fast=args.smoke)

    from benchmarks.common import save_results

    save_results("scaleout", doc)
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    h = doc["headline"]
    print(f"[scaleout] modelled @ {max(ndevs)} devices: "
          f"train {h['train_modelled_speedup']:.2f}x, "
          f"plan {h['plan_modelled_speedup']:.2f}x, "
          f"warm recompiles {h['warm_recompiles']}, "
          f"grad-compress bytes {h['grad_compress_bytes_reduction']:.2f}x "
          f"-> {BENCH_PATH}", flush=True)


if __name__ == "__main__":
    main()
