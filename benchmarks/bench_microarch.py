"""Paper Fig. 6: microarchitectural-metric fidelity — achieved occupancy,
IPC, L1/L2 hit rates, full vs sampled, for cfd (Rodinia) and pythia (LLM)."""

from __future__ import annotations

from benchmarks.common import metrics_for, plans_for, save_results
from repro.sim.simulate import full_metrics, reconstruct

PROGRAMS = ("cfd", "pythia")
METRICS = ("cycles", "ipc", "l1_hit", "l2_hit", "occupancy")


def run(fast: bool = False, verbose: bool = True):
    table = {}
    for prog in PROGRAMS:
        plan = plans_for(prog, fast=fast, verbose=verbose)["GCL-Sampler"]
        ms = metrics_for(prog, "P1")
        full = full_metrics(ms)
        est = reconstruct(plan, ms)
        table[prog] = {
            m: {
                "full": full[m],
                "sampled": est[m],
                "error_pct": abs(full[m] - est[m]) / max(abs(full[m]), 1e-12) * 100,
            }
            for m in METRICS
        }
        if verbose:
            for m in METRICS:
                r = table[prog][m]
                print(f"[fig6] {prog:8s} {m:10s} full={r['full']:.4g} "
                      f"sampled={r['sampled']:.4g} err={r['error_pct']:.2f}%",
                      flush=True)
    save_results("fig6_microarch", table)
    return table


if __name__ == "__main__":
    run()
