"""Packed-bucketed vs. dense `pad_batch` embed-path throughput on a
mixed-size kernel population (ISSUE 1 acceptance: >=2x, with jit compiles
bounded by the bucket count).

The population mimics a real invocation stream: many small kernels, a few
large ones, and repeated invocations of the same kernels.  The dense path
pads every graph to the population max (one large kernel inflates every
small one); the packed path pays only for the bytes it batches, and the
content-hash cache encodes repeated invocations once.

    PYTHONPATH=src python -m benchmarks.bench_batching [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import save_results
from repro.core import rgcn as rgcn_mod
from repro.core.graphs import build_kernel_graph
from repro.core.rgcn import RGCNConfig
from repro.core.train import ContrastiveTrainer, GCLTrainConfig
from repro.tracing.templates import make_kernel


def make_population(n_small=48, n_large=2, cap_small=16, cap_large=96):
    """Mixed-size, all-DISTINCT graphs: `n_small` light kernels plus
    `n_large` heavy ones (the heavy tail is what inflates dense padding)."""
    graphs = []
    for i in range(n_small):
        k = make_kernel(f"s{i}", "gemm",
                        {"M": 64 + 4 * i, "N": 64, "K": 64}, i, seed=i)
        graphs.append(build_kernel_graph(k.trace(2, cap_small)))
    for i in range(n_large):
        k = make_kernel(f"L{i}", "gemm",
                        {"M": 2048, "N": 512, "K": 512}, n_small + i, seed=100 + i)
        graphs.append(build_kernel_graph(k.trace(2, cap_large)))
    return graphs


def _time(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return out, time.time() - t0


def run(smoke: bool = False, verbose: bool = True):
    if smoke:
        graphs = make_population(n_small=12, n_large=1)
        repeats = 2
    else:
        graphs = make_population()
        repeats = 3
    sizes = np.array([g.n_nodes for g in graphs])
    trainer = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig())
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), trainer.rc)

    # -- dense baseline (compile + steady state), all-distinct graphs --------
    _, dense_cold = _time(trainer.embed_dense, params, graphs)
    z_dense, dense_warm = _time(trainer.embed_dense, params, graphs)

    # -- packed path, all-distinct graphs (pure packing/bucketing win) -------
    _, packed_cold = _time(trainer.embed, params, graphs)
    stats_cold = dict(trainer.embed_stats)
    trainer._embed_cache.clear()
    z_packed, packed_warm = _time(trainer.embed, params, graphs)
    trainer._embed_cache.clear()

    # -- repeated-invocation stream: dedup + content cache -------------------
    stream = graphs * repeats
    _, dense_stream = _time(trainer.embed_dense, params, stream)
    _, packed_stream = _time(trainer.embed, params, stream)  # dedups in-call
    _, packed_stream_hot = _time(trainer.embed, params, stream)  # all cached
    stats_hot = dict(trainer.embed_stats)

    np.testing.assert_allclose(z_packed, z_dense, atol=1e-3, rtol=1e-3)
    n = len(graphs)
    result = {
        "graphs": n,
        "nodes_min": int(sizes.min()), "nodes_max": int(sizes.max()),
        "nodes_mean": float(sizes.mean()),
        "dense_cold_s": dense_cold, "dense_warm_s": dense_warm,
        "packed_cold_s": packed_cold, "packed_warm_s": packed_warm,
        "speedup_distinct": dense_warm / max(packed_warm, 1e-9),
        "stream_graphs": len(stream),
        "dense_stream_s": dense_stream,
        "packed_stream_s": packed_stream,
        "packed_stream_hot_s": packed_stream_hot,
        "speedup_stream": dense_stream / max(packed_stream, 1e-9),
        "bucket_keys": stats_cold["bucket_keys"],
        "compiles": stats_cold["compiles"],
        "cache_hits_hot": stats_hot["cache_hits"],
        "dense_graphs_per_s": n / max(dense_warm, 1e-9),
        "packed_graphs_per_s": n / max(packed_warm, 1e-9),
    }
    if verbose:
        print(f"[batching] {n} distinct graphs, nodes {result['nodes_min']}"
              f"..{result['nodes_max']} (mean {result['nodes_mean']:.0f})")
        print(f"  dense   : cold {dense_cold:.2f}s warm {dense_warm:.3f}s "
              f"({result['dense_graphs_per_s']:.1f} g/s)")
        print(f"  packed  : cold {packed_cold:.2f}s warm {packed_warm:.3f}s "
              f"({result['packed_graphs_per_s']:.1f} g/s)")
        print(f"  speedup : {result['speedup_distinct']:.1f}x on all-distinct "
              f"graphs")
        print(f"  stream  : {len(stream)} invocations ({repeats}x repeats) — "
              f"dense {dense_stream:.3f}s, packed {packed_stream:.3f}s "
              f"({result['speedup_stream']:.1f}x), hot-cache "
              f"{packed_stream_hot:.3f}s ({stats_hot['cache_hits']} hits)")
        print(f"  compiles: {result['compiles']} "
              f"(buckets: {result['bucket_keys']}) — bounded by bucket count")
        assert stats_cold["compiles"] < 0 or (
            stats_cold["compiles"] <= len(stats_cold["bucket_keys"])
        ), "compile count exceeded bucket count"
    save_results("batching" + ("_smoke" if smoke else ""), result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small population for CI")
    args = ap.parse_args()
    r = run(smoke=args.smoke)
    ok = r["speedup_distinct"] >= (1.0 if args.smoke else 2.0)
    print(f"RESULT: {'PASS' if ok else 'FAIL'} "
          f"({r['speedup_distinct']:.1f}x on all-distinct graphs)")
    raise SystemExit(0 if ok else 1)
