"""Plan-serving SLO benchmark: continuous batching + warm executable pool.

Drives :class:`repro.serving.PlanService` (DESIGN.md §9) with open-loop
Poisson traffic (latency measured from the *scheduled* arrival — no
coordinated omission) and reports the serving headlines:

- **cold vs warm**: the same offered load served from a pristine
  executable cache (first requests pay the sweep compiles) vs after
  ``warmup`` pre-built the pool — p99 ratio is the warm-pool win;
- **continuous batching vs dispatch-per-request**: the same loads served
  with the fill-or-deadline batcher (``max_batch=8``) vs a degenerate
  ``max_batch=1`` service — plans/s at the highest load is the batching
  win;
- **load sweep**: p50/p99 plan latency, plans/s, queue depth, batch
  occupancy and flush causes at offered loads expressed as multiples of
  the measured dispatch-per-request capacity;
- **parity**: served plans vs the sequential reference
  (`select_k_and_cluster` + `plan_from_labels`) — labels/K/reps must be
  identical request-for-request;
- **plan-build overlap**: ``overlap_plan_build`` on vs off through
  ``PlanEngine.plan_many`` (host representative scan hidden behind the
  next chunk's device dispatch).

Results go to ``benchmarks/results/serve_latency.json`` AND a repo-root
``BENCH_serve_latency.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import save_results
from repro.analysis.sanitize import recompile_guard
from repro.core import clustering
from repro.core.clustering import select_k_and_cluster
from repro.sampling.base import plan_from_labels
from repro.sampling.engine import (
    PlanEngine, PlanRequest, bucket_key, normalize_embeddings,
)
from repro.serving import PlanService, run_open_loop, synthetic_fleet

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _closed_loop_capacity(engine: PlanEngine, fleet, n_rounds: int = 2):
    """Best-of closed-loop plans/s through ``plan_many`` (warm)."""
    best = 0.0
    for _ in range(n_rounds):
        t0 = time.perf_counter()
        engine.plan_many([PlanRequest(r.embeddings, r.seqs, r.method,
                                      seed=r.seed) for r in fleet])
        best = max(best, len(fleet) / (time.perf_counter() - t0))
    return best


def run(n_requests: int = 240, d: int = 16, k_max: int = 8, iters: int = 10,
        max_batch: int = 8, max_delay_ms: float = 4.0,
        load_factors=(0.5, 1.0, 3.0), cold_rate: float = 50.0,
        fast: bool = False, verbose: bool = True) -> dict:
    if fast:  # benchmarks.run / CI entry point
        n_requests, load_factors, cold_rate = 80, (0.5, 3.0), 30.0

    fleet = synthetic_fleet(n_requests, d=d, seed=0)
    buckets = sorted({bucket_key(r.embeddings) for r in fleet})
    svc_kw = dict(max_batch=max_batch, max_delay_ms=max_delay_ms,
                  k_max=k_max, iters=iters)
    subset = fleet[:min(24 if fast else 48, n_requests)]

    # -- cold vs warm (same offered load, same requests) ---------------------
    # Cold FIRST: a pristine process-wide cache means the first dispatches
    # pay the sweep compiles on the serving path.
    clustering._ENGINE_CACHE.clear()
    clustering.reset_engine_stats()
    with PlanService(**svc_kw) as svc:
        cold = run_open_loop(svc, subset, cold_rate, seed=1)
    cold_builds = clustering.ENGINE_STATS["builds"]
    if verbose:
        print(f"[serve-latency] cold @ {cold_rate:.0f}/s: "
              f"p99 {cold.latency_ms['p99']:.0f}ms "
              f"({cold_builds} builds on-path)", flush=True)

    with PlanService(**svc_kw) as svc:
        t0 = time.perf_counter()
        warmed = svc.warmup(buckets)
        warmup_s = time.perf_counter() - t0
        # the warm serving path must build ZERO new executables — asserted
        # by the sanitizer guard, not an ad-hoc counter diff
        with recompile_guard(label="warm serving path") as guard:
            warm = run_open_loop(svc, subset, cold_rate, seed=1)
        warm_builds_during_serving = guard.builds
    cold_vs_warm = {
        "offered_per_s": cold_rate, "n_requests": len(subset),
        "warmed_executables": warmed, "warmup_s": warmup_s,
        "cold_builds_on_path": cold_builds,
        "warm_builds_during_serving": warm_builds_during_serving,
        "cold": cold.to_json(), "warm": warm.to_json(),
        "p99_ratio": cold.latency_ms["p99"] / max(warm.latency_ms["p99"], 1e-9),
    }
    if verbose:
        print(f"[serve-latency] warm @ {cold_rate:.0f}/s: "
              f"p99 {warm.latency_ms['p99']:.1f}ms -> cold/warm p99 ratio "
              f"{cold_vs_warm['p99_ratio']:.1f}x "
              f"({warmed} warmed in {warmup_s:.1f}s, "
              f"{warm_builds_during_serving} builds while serving)",
              flush=True)

    # -- capacity probes (closed loop, warm) ---------------------------------
    eng_per_req = PlanEngine(k_max=k_max, iters=iters, max_batch=1)
    eng_batched = PlanEngine(k_max=k_max, iters=iters, max_batch=max_batch)
    per_req_cap = _closed_loop_capacity(eng_per_req, fleet)
    batched_cap = _closed_loop_capacity(eng_batched, fleet)
    capacity = {
        "per_request_plans_per_s": per_req_cap,
        "batched_plans_per_s": batched_cap,
        "batched_over_per_request": batched_cap / max(per_req_cap, 1e-9),
    }
    if verbose:
        print(f"[serve-latency] capacity: per-request {per_req_cap:.0f}/s, "
              f"batched {batched_cap:.0f}/s "
              f"({capacity['batched_over_per_request']:.1f}x)", flush=True)

    # -- load sweep: batcher vs dispatch-per-request -------------------------
    loads = []
    with PlanService(**svc_kw) as svc_b, \
            PlanService(max_batch=1, max_delay_ms=0.0,
                        k_max=k_max, iters=iters) as svc_1:
        for f in load_factors:
            rate = f * per_req_cap
            row = {"factor": float(f), "offered_per_s": rate}
            for name, svc in (("batched", svc_b), ("per_request", svc_1)):
                res = run_open_loop(svc, fleet, rate, seed=int(f * 10) + 2)
                row[name] = res.to_json()
                if verbose:
                    s = res.service
                    print(f"[serve-latency] {f:.1f}x ({rate:.0f}/s) {name}: "
                          f"{res.plans_per_s:.0f} plans/s, "
                          f"p50 {res.latency_ms['p50']:.1f}ms, "
                          f"p99 {res.latency_ms['p99']:.1f}ms, "
                          f"occ {s['batch_occupancy'] or 0:.2f}, "
                          f"queue {s['mean_queue_depth']:.1f}", flush=True)
            row["plans_per_s_ratio"] = (
                row["batched"]["plans_per_s"]
                / max(row["per_request"]["plans_per_s"], 1e-9))
            loads.append(row)
    batching_speedup = loads[-1]["plans_per_s_ratio"]

    # -- parity: served plans vs the sequential reference --------------------
    par = fleet[:6 if fast else 10]
    with PlanService(**svc_kw) as svc:
        plans = [f.result() for f in [svc.submit(r) for r in par]]
    kw = dict(k_max=k_max, iters=iters)
    labels_ok = k_ok = reps_ok = 0
    for req, plan in zip(par, plans):
        labels, info = select_k_and_cluster(
            normalize_embeddings(req.embeddings), seed=req.seed, **kw)
        ref = plan_from_labels(labels, req.seqs, req.method, extra=info)
        labels_ok += int(np.array_equal(ref.labels, plan.labels))
        k_ok += int(info["k"] == plan.extra["k"])
        reps_ok += int(ref.reps == plan.reps)
    parity = {"requests": len(par), "labels_identical": labels_ok,
              "k_identical": k_ok, "reps_identical": reps_ok}
    if verbose:
        print(f"[serve-latency] parity: {labels_ok}/{len(par)} labels, "
              f"{reps_ok}/{len(par)} reps identical", flush=True)

    # -- plan-build overlap on/off (satellite micro-opt) ---------------------
    # Measured on LARGER programs than the serving fleet: the win is bounded
    # by the host representative-scan's share of a chunk's wall time, which
    # is negligible at 20-60 points and a few percent at thousands.
    rng = np.random.default_rng(7)
    reqs = []
    n_lo, n_hi = (400, 900) if fast else (1500, 3500)
    for i in range(12 if fast else 24):
        n = int(rng.integers(n_lo, n_hi))
        k = int(rng.integers(3, 7))
        centers = rng.standard_normal((k, d)) * 40.0
        x = (centers[rng.integers(0, k, n)]
             + rng.standard_normal((n, d)) * 0.5).astype(np.float32)
        reqs.append(PlanRequest(x, np.arange(n), "micro", seed=i))
    micro = {"n_requests": len(reqs), "points": [n_lo, n_hi]}
    for name, flag in (("overlap", True), ("serial", False)):
        eng = PlanEngine(k_max=k_max, iters=iters, max_batch=max_batch,
                         overlap_plan_build=flag)
        eng.plan_many(reqs)  # compile/warm pass, untimed
        times = []
        for _ in range(5):
            t0 = time.perf_counter()
            eng.plan_many(reqs)
            times.append(time.perf_counter() - t0)
        times.sort()
        micro[f"{name}_s"] = times[len(times) // 2]  # median of 5
        micro[f"{name}_min_s"] = times[0]
    micro["speedup"] = micro["serial_s"] / max(micro["overlap_s"], 1e-9)
    if verbose:
        print(f"[serve-latency] plan-build overlap: "
              f"{micro['serial_s'] * 1e3:.0f}ms serial -> "
              f"{micro['overlap_s'] * 1e3:.0f}ms overlapped "
              f"({micro['speedup']:.2f}x)", flush=True)

    doc = {
        "settings": {"n_requests": n_requests, "d": d, "k_max": k_max,
                     "iters": iters, "max_batch": max_batch,
                     "max_delay_ms": max_delay_ms,
                     "load_factors": list(load_factors),
                     "cold_rate": cold_rate},
        "buckets": [list(b) for b in buckets],
        "cold_vs_warm": cold_vs_warm,
        "capacity": capacity,
        "loads": loads,
        "batching_speedup_high_load": batching_speedup,
        "parity": parity,
        "plan_build_overlap": micro,
    }
    if verbose:
        print(f"[serve-latency] headlines: warm-pool p99 "
              f"{cold_vs_warm['p99_ratio']:.1f}x lower, batching "
              f"{batching_speedup:.1f}x plans/s at "
              f"{load_factors[-1]:.1f}x load", flush=True)

    save_results("serve_latency", doc)
    bench_path = os.path.join(REPO_ROOT, "BENCH_serve_latency.json")
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[serve-latency] wrote {bench_path}", flush=True)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_serve_latency")
    ap.add_argument("--n-requests", type=int, default=240)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k-max", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=4.0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, two loads)")
    ap.add_argument("--min-warm-p99-ratio", type=float, default=0.0,
                    help="exit non-zero if cold/warm p99 falls below this")
    ap.add_argument("--min-batch-speedup", type=float, default=0.0,
                    help="exit non-zero if batched/per-request plans/s at "
                         "the highest load falls below this")
    args = ap.parse_args(argv)
    doc = run(n_requests=args.n_requests, d=args.d, k_max=args.k_max,
              iters=args.iters, max_batch=args.max_batch,
              max_delay_ms=args.max_delay_ms, fast=args.smoke)
    bad = []
    r = doc["cold_vs_warm"]["p99_ratio"]
    if args.min_warm_p99_ratio and r < args.min_warm_p99_ratio:
        bad.append(f"warm-pool p99 ratio {r:.2f}x < "
                   f"{args.min_warm_p99_ratio:.2f}x")
    s = doc["batching_speedup_high_load"]
    if args.min_batch_speedup and s < args.min_batch_speedup:
        bad.append(f"batching speedup {s:.2f}x < "
                   f"{args.min_batch_speedup:.2f}x")
    if doc["cold_vs_warm"]["warm_builds_during_serving"] != 0:
        bad.append(f"warm pool leaked "
                   f"{doc['cold_vs_warm']['warm_builds_during_serving']} "
                   f"builds onto the serving path (want 0)")
    p = doc["parity"]
    if (p["labels_identical"] != p["requests"]
            or p["reps_identical"] != p["requests"]):
        bad.append(f"parity broken: {p}")
    if bad:
        print("FAIL: " + "; ".join(bad))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
