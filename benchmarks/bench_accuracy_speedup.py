"""Paper Fig. 4 + Fig. 5 + headline: sampling error and speedup of
GCL-Sampler vs PKA / Sieve / STEM+ROOT across all 11 workloads on P1."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import evaluate, plans_for, save_results
from repro.tracing.programs import PAPER_PROGRAMS

METHODS = ("GCL-Sampler", "PKA", "Sieve", "STEM+ROOT")


def run(programs=None, fast: bool = False, verbose: bool = True):
    programs = programs or PAPER_PROGRAMS
    table = {}
    for prog in programs:
        t0 = time.time()
        plans = plans_for(prog, fast=fast, verbose=verbose)
        table[prog] = {m: evaluate(plans[m], prog, "P1") for m in METHODS}
        if verbose:
            row = " | ".join(
                f"{m}: {table[prog][m]['error_pct']:.2f}% "
                f"{table[prog][m]['speedup']:.1f}x"
                for m in METHODS
            )
            print(f"[fig4/5] {prog:10s} {row} ({time.time() - t0:.0f}s)",
                  flush=True)
    summary = {}
    for m in METHODS:
        errs = [table[p][m]["error_pct"] for p in programs]
        sus = [table[p][m]["speedup"] for p in programs]
        summary[m] = {
            "avg_error_pct": float(np.mean(errs)),
            "avg_speedup": float(np.mean(sus)),
        }
    payload = {"per_program": table, "summary": summary,
               "paper_reference": {
                   "GCL-Sampler": {"avg_error_pct": 0.37, "avg_speedup": 258.94},
                   "PKA": {"avg_error_pct": 20.90, "avg_speedup": 129.23},
                   "Sieve": {"avg_error_pct": 4.10, "avg_speedup": 94.90},
                   "STEM+ROOT": {"avg_error_pct": 0.38, "avg_speedup": 56.57},
               }}
    save_results("fig4_5_accuracy_speedup", payload)
    if verbose:
        print("[fig4/5] averages:")
        for m in METHODS:
            s = summary[m]
            print(f"  {m:12s} err {s['avg_error_pct']:6.2f}%  "
                  f"speedup {s['avg_speedup']:8.2f}x", flush=True)
    return payload


if __name__ == "__main__":
    run()
