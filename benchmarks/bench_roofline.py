"""Deliverable (g): per-(arch x shape) roofline table from the dry-run
artifact (single-pod mesh), markdown-rendered for EXPERIMENTS.md."""

from __future__ import annotations

import json
import os

from benchmarks.common import save_results

DRYRUN = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def run(verbose: bool = True):
    if not os.path.exists(DRYRUN):
        print(f"[roofline] {DRYRUN} missing — run "
              f"`python -m repro.launch.dryrun --all --both-meshes` first")
        return {}
    with open(DRYRUN) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("multi_pod") or r.get("status") != "ok":
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "model_flops": rl["model_flops"],
            "hlo_flops": rl["hlo_flops_total"],
            "useful_flop_ratio": rl["useful_flop_ratio"],
            "roofline_fraction": rl["roofline_fraction"],
            "hbm_args_gb": (r["memory"]["argument_bytes_per_device"] or 0) / 1e9,
            "hbm_temp_gb": (r["memory"]["temp_bytes_per_device"] or 0) / 1e9,
        })
    if verbose:
        hdr = (f"{'arch':18s}{'shape':13s}{'comp_s':>11s}{'mem_s':>11s}"
               f"{'coll_s':>11s} {'dominant':10s}{'useful':>7s}{'roofl':>7s}")
        print(hdr)
        for row in rows:
            print(f"{row['arch']:18s}{row['shape']:13s}"
                  f"{row['compute_s']:11.3e}{row['memory_s']:11.3e}"
                  f"{row['collective_s']:11.3e} {row['dominant']:10s}"
                  f"{100 * (row['useful_flop_ratio'] or 0):6.0f}%"
                  f"{100 * (row['roofline_fraction'] or 0):6.1f}%")
    save_results("roofline_table", rows)
    return rows


if __name__ == "__main__":
    run()
