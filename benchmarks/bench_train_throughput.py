"""Paper §4 Model Configuration: contrastive-training cost — time per 100
kernels (the paper reports ~12 min/100 kernels for phi-2-scale programs on an
A100; ours is a single-CPU-core environment, so we report the measured rate
and the breakdown instead of comparing wall-clocks)."""

from __future__ import annotations

import time

from benchmarks.common import sampler_config, save_results
from repro.core.sampler import GCLSampler
from repro.tracing.programs import get_program


def run(programs=("nw", "3mm"), fast: bool = True, verbose: bool = True):
    table = {}
    for prog_name in programs:
        prog = get_program(prog_name)
        s = GCLSampler(sampler_config(fast))
        t0 = time.time()
        graphs = s.build_graphs(prog)
        t1 = time.time()
        s.train(graphs)
        t2 = time.time()
        emb = s.embed(graphs)
        t3 = time.time()
        n = len(prog)
        table[prog_name] = {
            "kernels": n,
            "graphs_s": t1 - t0,
            "train_s": t2 - t1,
            "embed_s": t3 - t2,
            "s_per_100_kernels": (t3 - t0) / n * 100,
            "train_steps": s.cfg.train.steps,
        }
        if verbose:
            r = table[prog_name]
            print(f"[train-cost] {prog_name}: {n} kernels | graphs "
                  f"{r['graphs_s']:.1f}s train {r['train_s']:.1f}s embed "
                  f"{r['embed_s']:.1f}s -> {r['s_per_100_kernels']:.1f}s/100",
                  flush=True)
    save_results("train_throughput", table)
    return table


if __name__ == "__main__":
    run()
