"""Trainer throughput: the compiled scan engine vs the per-step baseline.

Paper §4 reports contrastive-training cost per 100 kernels; what matters for
the end-to-end speedup story (paper eq. 6) is encoder-fit throughput, so this
benchmark races the two training engines (core/train.py) on the same graphs,
seed-matched:

- ``python``: the pre-engine per-step loop (parity shim) — packs on the
  host, uploads, and blocks on a device->host metrics sync EVERY step, and
  re-jits its step per fit, exactly like the seed trainer;
- ``scan``: pre-packed epoch plan, device staging, fixed-length
  `jax.lax.scan` chunks, metrics synced only at ``log_every`` boundaries,
  compiled chunks cached across fits.

Each engine runs ``n_fits`` sequential fits (the artifact-store / scenario
sweeps refit repeatedly, so the steady-state fit is the operative regime).
Results go to ``benchmarks/results/train_throughput.json`` AND a repo-root
``BENCH_train_throughput.json`` with steps/s, host-sync counts, compile
counts and the cross-engine loss-trajectory divergence.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import save_results
from repro.core.rgcn import RGCNConfig
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import ContrastiveTrainer, GCLTrainConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENGINES = ("python", "scan")


def run(program: str = "3mm", steps: int = 64, batch_size: int = 8,
        cap_instr: int = 64, log_every: int = 50, n_fits: int = 2,
        fast: bool = False, verbose: bool = True) -> dict:
    from repro.tracing.programs import get_program

    if fast:  # benchmarks.run / CI entry point
        steps = min(steps, 32)

    cfg = GCLSamplerConfig(cap_instr=cap_instr)
    graphs = GCLSampler(cfg).build_graphs(get_program(program))

    engines: dict = {}
    for engine in ENGINES:
        tc = GCLTrainConfig(steps=steps, batch_size=batch_size,
                            log_every=log_every, engine=engine)
        trainer = ContrastiveTrainer(RGCNConfig(), tc)
        fits = []
        info = {}
        for i in range(n_fits):
            t0 = time.time()
            _, info = trainer.fit(graphs)
            wall = time.time() - t0
            fits.append({
                "wall_s": wall,
                "steps_per_s": steps / wall,
                # fit() counts the val-loss pull too; the loop criterion is
                # about TRAINING syncs, so report both
                "host_syncs_total": info["host_syncs"],
                "host_syncs_loop": info["host_syncs"]
                - (1 if "val_loss" in info else 0),
                "step_compiles": info["step_compiles"],
            })
            if verbose:
                print(f"[train-throughput] {engine} fit {i}: {wall:.1f}s "
                      f"-> {steps / wall:.2f} steps/s "
                      f"(syncs {info['host_syncs']}, "
                      f"compiles {info['step_compiles']})", flush=True)
        engines[engine] = {
            "fits": fits,
            "cold": fits[0],
            "steady": fits[-1],
            "loss_trajectory": [h["loss"] for h in info["history"]],
            "bucket_keys": [list(k) for k in info["bucket_keys"]],
            **({"scan_chunks": info["scan_chunks"],
                "chunk_len": info["chunk_len"]} if engine == "scan" else {}),
        }

    t_py = np.asarray(engines["python"]["loss_trajectory"])
    t_sc = np.asarray(engines["scan"]["loss_trajectory"])
    parity = float(np.abs(t_py - t_sc).max()) if len(t_py) == len(t_sc) \
        else float("inf")
    log_windows = max(1, -(-steps // log_every))  # ceil
    doc = {
        "settings": {
            "program": program, "steps": steps, "batch_size": batch_size,
            "cap_instr": cap_instr, "log_every": log_every,
            "n_fits": n_fits,
        },
        "engines": engines,
        # headline: steady-state fit throughput (the sweeps' operative
        # regime — the scan engine reuses compiled chunks across fits, the
        # per-step baseline re-jits per fit like the seed trainer)
        "speedup_steady": engines["scan"]["steady"]["steps_per_s"]
        / engines["python"]["steady"]["steps_per_s"],
        "speedup_cold": engines["scan"]["cold"]["steps_per_s"]
        / engines["python"]["cold"]["steps_per_s"],
        "loss_trajectory_max_abs_diff": parity,
        "scan_host_syncs_per_log_window":
            engines["scan"]["steady"]["host_syncs_loop"] / log_windows,
    }
    if verbose:
        print(f"[train-throughput] steady speedup "
              f"{doc['speedup_steady']:.2f}x (cold "
              f"{doc['speedup_cold']:.2f}x), trajectory max|d|={parity:.2e}, "
              f"scan syncs/log-window "
              f"{doc['scan_host_syncs_per_log_window']:.2f}", flush=True)

    save_results("train_throughput", doc)
    bench_path = os.path.join(REPO_ROOT, "BENCH_train_throughput.json")
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[train-throughput] wrote {bench_path}", flush=True)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_train_throughput")
    ap.add_argument("--program", default="3mm")
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--cap-instr", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=50)
    ap.add_argument("--n-fits", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit non-zero if steady speedup falls below this")
    args = ap.parse_args(argv)
    if args.smoke:
        args.steps = min(args.steps, 32)
    doc = run(program=args.program, steps=args.steps,
              batch_size=args.batch_size, cap_instr=args.cap_instr,
              log_every=args.log_every, n_fits=args.n_fits)
    if args.min_speedup and doc["speedup_steady"] < args.min_speedup:
        print(f"FAIL: steady speedup {doc['speedup_steady']:.2f}x < "
              f"{args.min_speedup:.2f}x")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
