"""Beyond-paper ablation study: which GCL-Sampler component earns its keep?

Variants (on nw / lud / AlexNet, the three workloads exercising distinct
failure modes of hand-crafted features):

  full            the paper's configuration
  no_training     untrained RGCN (random-init encoder; contrastive off)
  no_vstats       dynamic-value summaries zeroed (structure-only graphs)
  cf_only         control-flow edges only (no data-flow relations)
  no_dataflow_val both ablations together (closest to a pure opcode-BBV)

Paper's claim under test: structural AND semantic (dynamic-value) signals
both contribute; hand-crafted-feature-like reductions reintroduce the
merging failures of PKA/Sieve.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import metrics_for, save_results
from repro.core.rgcn import RGCNConfig
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.sim.simulate import sampling_error, speedup
from repro.tracing.programs import get_program

PROGRAMS = ("nw", "lud", "AlexNet")

VARIANTS = {
    "full": {},
    "no_training": {"steps": 0},
    "no_vstats": {"rgcn": {"use_vstats": False}},
    "cf_only": {"rgcn": {"relations_used": (0,)}},
    "no_dataflow_val": {"rgcn": {"use_vstats": False, "relations_used": (0,)}},
}


def _config(variant: dict, fast: bool) -> GCLSamplerConfig:
    steps = variant.get("steps", 40 if fast else 120)
    rc = RGCNConfig(**variant.get("rgcn", {}))
    return GCLSamplerConfig(
        cap_instr=64 if fast else 96, rgcn=rc,
        train=GCLTrainConfig(steps=max(steps, 0), batch_size=8 if fast else 16),
    )


def run(programs=PROGRAMS, fast: bool = True, verbose: bool = True):
    table = {}
    for prog_name in programs:
        prog = get_program(prog_name)
        ms = metrics_for(prog_name, "P1")
        table[prog_name] = {}
        for vname, variant in VARIANTS.items():
            t0 = time.time()
            cfg = _config(variant, fast)
            sampler = GCLSampler(cfg)
            graphs = sampler.build_graphs(prog)
            if cfg.train.steps > 0:
                sampler.train(graphs)
            else:  # untrained encoder: random init
                import jax

                from repro.core.rgcn import init_rgcn

                sampler.params = init_rgcn(jax.random.PRNGKey(0), cfg.rgcn)
            emb = sampler.embed(graphs)
            seqs = np.array([k.seq for k in prog.kernels])
            plan = sampler.cluster(emb, seqs)
            table[prog_name][vname] = {
                "k": plan.num_clusters,
                "error_pct": sampling_error(plan, ms),
                "speedup": speedup(plan, ms),
            }
            if verbose:
                r = table[prog_name][vname]
                print(f"[ablate] {prog_name:8s} {vname:16s} K={r['k']:3d} "
                      f"err={r['error_pct']:6.2f}% su={r['speedup']:.1f}x "
                      f"({time.time() - t0:.0f}s)", flush=True)
    save_results("ablations", table)
    return table


if __name__ == "__main__":
    run(fast=False)
