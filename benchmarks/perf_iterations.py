"""§Perf hillclimbing harness (deliverable g): hypothesis -> change ->
re-lower -> re-analyse cycles on the three chosen (arch x shape) pairs.

Chosen pairs (from the baseline roofline table):
  1. qwen2-72b x train_4k      — largest memory-dominated train cell
  2. grok-1-314b x decode_32k  — most collective-bound cell
  3. rgcn x contrastive_train  — the paper's own technique (RGCN InfoNCE
                                 step on the production mesh)

Each experiment is a (name, hypothesis, overrides) triple; the harness
lowers the cell with the overrides applied, extracts the three roofline
terms, and records confirmed/refuted vs the stated hypothesis in
benchmarks/results/perf_iterations.json (narrated in EXPERIMENTS.md §Perf).

Run one pair:  PYTHONPATH=src python -m benchmarks.perf_iterations --pair qwen_train
NOTE: must run in a fresh process (forces 512 host devices via dryrun import).
"""

from __future__ import annotations

import argparse
import json
import os

from repro.launch import dryrun as dr  # sets XLA_FLAGS before jax init

import jax
import jax.numpy as jnp

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "perf_iterations.json")


# ---------------------------------------------------------------------------
# LM cells via the dryrun driver
# ---------------------------------------------------------------------------

QWEN_TRAIN = [
    ("baseline", "paper-faithful baseline (full remat, no SP)", {}),
    ("sp",
     "hypothesis: norms/residual/rope run replicated over the 16-way model "
     "axis; sequence-sharding activations (Megatron-SP) removes the "
     "redundancy -> memory term down 10-25%",
     {"rules_kw": {"seq_shard": True}}),
    ("sp+dots_remat",
     "hypothesis: full remat recomputes every matmul in bwd; saving matmul "
     "outputs (dots policy) cuts recompute -> compute term down ~20%, "
     "memory term down ~10%, at higher resident temp",
     {"rules_kw": {"seq_shard": True}, "cfg_kw": {"remat_policy": "dots"}}),
    ("sp+dots+microbatch8",
     "hypothesis: 8-way gradient accumulation shrinks per-microbatch "
     "activations 8x -> temp memory down toward HBM fit; terms ~unchanged "
     "(same total work)",
     {"rules_kw": {"seq_shard": True},
      "cfg_kw": {"remat_policy": "dots"}, "microbatch": 8}),
]

GROK_DECODE = [
    ("baseline", "paper-faithful baseline (fp32 master params, FSDP, dense "
     "softmax over the seq-sharded KV cache)", {}),
    ("split_softmax16",
     "hypothesis: the 79GB/step collective is GSPMD all-gathering the "
     "seq-sharded KV cache for softmax (dtype-insensitivity of the baseline "
     "proved it isn't weights); flash-decoding split softmax keeps partials "
     "shard-local and merges (B,K,G,16[,hd]) LSE stats -> collective term "
     "down >10x",
     {"cfg_kw": {"decode_split": 16}}),
    ("split16+bf16_params",
     "hypothesis: with the KV gather gone, remaining bytes are weight reads "
     "+ FSDP weight gathers; bf16 serving weights halve them -> memory term "
     "down ~1.5-2x",
     {"cfg_kw": {"decode_split": 16, "param_dtype": "bfloat16"}}),
    ("split16+bf16+no_fsdp",
     "hypothesis: dropping FSDP keeps weights resident (pure 16-way TP): "
     "weight all-gathers disappear -> collective floor; per-device weight "
     "bytes grow 16x (39GB bf16 — needs int8 or a wider model axis to fit "
     "16GB HBM; recorded as the trade-off)",
     {"cfg_kw": {"decode_split": 16, "param_dtype": "bfloat16"},
      "rules_kw": {"fsdp": False}}),
]


EP_PARAM_PREF = ("experts", "vocab", "ffn", "heads", "d_inner", "ssm_heads",
                 "attn_hidden", "embed")
EP_ACT_PREF = ("experts", "vocab", "ffn", "heads", "d_inner", "ssm_heads",
               "cache_seq")

DBRX_TRAIN = [
    ("baseline_tp_moe",
     "TP-MoE baseline: expert d_ff sharded over 'model' (Megatron-style, one "
     "all-reduce after w2); dispatch buffers replicated over model", {}),
    ("expert_parallel",
     "hypothesis: sharding EXPERTS over 'model' (EP) keeps each expert's "
     "FFN fully local (no partial-sum all-reduces) at the cost of "
     "resharding the dispatch buffers across experts (all-to-all-like "
     "gathers) -> collective mix shifts; net direction depends on "
     "capacity*d_model vs d_ff traffic",
     {"rules_kw": {"param_model_pref": EP_PARAM_PREF,
                   "act_model_pref": EP_ACT_PREF}}),
]


def run_lm_pair(arch, shape, experiments, out):
    import time
    rows = []
    for name, hypothesis, ov in experiments:
        t0 = time.time()
        tcfg_kw = {}
        cfg_kw = dict(ov.get("cfg_kw", {}))
        if "microbatch" in ov:
            # plumb microbatch through the train config used by dryrun
            dr.TrainConfigPatch = ov["microbatch"]
            orig = dr._train_config

            def patched(cfg, _orig=orig, mb=ov["microbatch"]):
                t = _orig(cfg)
                from dataclasses import replace
                return replace(t, microbatch=mb)

            dr._train_config = patched
        try:
            rec = dr.lower_cell(arch, shape, multi_pod=False,
                                rules_kw=ov.get("rules_kw"),
                                cfg_kw=cfg_kw or None)
        finally:
            if "microbatch" in ov:
                dr._train_config = orig
        rl = rec["roofline"]
        # gradient accumulation wraps the step in a lax.scan over
        # microbatches, which cost_analysis counts ONCE — scale the per-step
        # terms back up (memory_analysis is unaffected: it reports the real
        # peak, which is exactly what microbatching shrinks).
        scale = ov.get("microbatch", 1)
        terms = {k: rl[k] * scale
                 for k in ("compute_s", "memory_s", "collective_s")}
        row = {
            "experiment": name, "hypothesis": hypothesis,
            **terms,
            "dominant": max(terms, key=terms.get).replace("_s", ""),
            "bound_s": max(terms.values()),
            "temp_gb": (rec["memory"]["temp_bytes_per_device"] or 0) / 1e9,
            "args_gb": (rec["memory"]["argument_bytes_per_device"] or 0) / 1e9,
            "wall_s": round(time.time() - t0, 1),
        }
        rows.append(row)
        print(f"[{arch} x {shape}] {name}: comp {row['compute_s']:.3e} "
              f"mem {row['memory_s']:.3e} coll {row['collective_s']:.3e} "
              f"({row['dominant']}) temp {row['temp_gb']:.1f}GB", flush=True)
    _save(out, {"pair": f"{arch} x {shape}", "iterations": rows})
    return rows


# ---------------------------------------------------------------------------
# RGCN contrastive-training cell (the paper's technique itself)
# ---------------------------------------------------------------------------


def lower_rgcn(batch_global=1024, n_nodes=768, n_edges=1536, warps=2,
               *, batch_axes=("data",), message_dtype="float32"):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.rgcn import RGCNConfig, init_rgcn
    from repro.core.train import ContrastiveTrainer, GCLTrainConfig
    from repro.launch.mesh import make_production_mesh
    from repro.optim import adamw_init

    mesh = make_production_mesh()
    rc = RGCNConfig(message_dtype=message_dtype)
    tc = GCLTrainConfig()
    trainer = ContrastiveTrainer(rc, tc)

    B, N, E = batch_global, n_nodes, n_edges
    bspecs = {
        "node_type": jax.ShapeDtypeStruct((B, N), jnp.int32),
        "token": jax.ShapeDtypeStruct((B, N), jnp.int32),
        "pc_norm": jax.ShapeDtypeStruct((B, N), jnp.float32),
        "vstats": jax.ShapeDtypeStruct((B, N, 8), jnp.float32),
        "warp_id": jax.ShapeDtypeStruct((B, N), jnp.int32),
        "node_mask": jax.ShapeDtypeStruct((B, N), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((B, E), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((B, E), jnp.int32),
        "edge_type": jax.ShapeDtypeStruct((B, E), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((B, E), jnp.float32),
        "n_warps": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    astate = jax.eval_shape(
        lambda k: adamw_init(init_rgcn(k, rc), tc.opt), jax.random.PRNGKey(0)
    )
    akey = jax.eval_shape(lambda: jax.random.PRNGKey(0))

    rep = NamedSharding(mesh, P())
    st_sh = jax.tree_util.tree_map(lambda _: rep, astate)
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    b_sh = {
        k: NamedSharding(mesh, P(ax, *([None] * (len(v.shape) - 1))))
        for k, v in bspecs.items()
    }

    step = trainer._make_step(warps)._fun if hasattr(
        trainer._make_step(warps), "_fun") else None
    # build an unjitted step (the trainer's is already jit'd; re-wrap with
    # explicit shardings for the production mesh)
    from repro.optim import apply_gradients

    def raw_step(state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: trainer._loss(p, batch, warps, rng), has_aux=True
        )(state.params)
        state, om = apply_gradients(state, grads, tc.opt)
        return state, dict(metrics, loss=loss, **om)

    with mesh:
        lowered = jax.jit(
            raw_step, in_shardings=(st_sh, b_sh, rep),
            out_shardings=(st_sh, None), donate_argnums=(0,),
        ).lower(astate, bspecs, akey)
        compiled = lowered.compile()
    return compiled, mesh


RGCN_EXPERIMENTS = [
    ("baseline_dp",
     "paper-faithful: data-parallel only (batch over 'data'); the 16-way "
     "model axis is idle for this small model — expected low utilization",
     {"batch_axes": ("data",)}),
    ("2d_batch",
     "hypothesis: sharding the graph batch over BOTH mesh axes (256-way DP) "
     "uses the idle axis -> per-device compute/memory terms down ~16x; "
     "InfoNCE all-gather of projections grows (global negatives over 256 "
     "shards) but stays tiny (B x 64 floats)",
     {"batch_axes": ("data", "model")}),
    ("2d_batch+bf16_messages",
     "hypothesis: message-passing traffic (gather + segment-sum payloads) "
     "dominates per-device bytes; bf16 messages halve it -> memory term "
     "down ~1.5-2x, fp32 accumulation keeps LayerNorm numerics",
     {"batch_axes": ("data", "model"), "message_dtype": "bfloat16"}),
]


def run_rgcn_pair(out):
    from repro.launch.roofline import roofline_terms

    rows = []
    for name, hypothesis, kw in RGCN_EXPERIMENTS:
        compiled, mesh = lower_rgcn(**kw)
        costs = dr._costs(compiled)
        mem = compiled.memory_analysis()
        rec = {
            "num_devices": int(mesh.devices.size),
            "cost": {"flops_per_device": costs["flops"],
                     "bytes_per_device": costs["bytes"]},
            "collectives": {"per_device_bytes": costs["coll"]},
            "model_flops": 0.0,
        }
        rl = roofline_terms(rec)
        row = {
            "experiment": name, "hypothesis": hypothesis,
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "bound_s": rl["step_time_bound_s"],
            "temp_gb": (getattr(mem, "temp_size_in_bytes", 0) or 0) / 1e9,
        }
        rows.append(row)
        print(f"[rgcn x contrastive_train] {name}: comp {row['compute_s']:.3e} "
              f"mem {row['memory_s']:.3e} coll {row['collective_s']:.3e} "
              f"({row['dominant']})", flush=True)
    _save(out, {"pair": "rgcn x contrastive_train", "iterations": rows})
    return rows


def _save(out, payload):
    data = []
    if os.path.exists(out):
        with open(out) as f:
            data = json.load(f)
    data = [d for d in data if d.get("pair") != payload["pair"]]
    data.append(payload)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(data, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", choices=["qwen_train", "grok_decode", "rgcn",
                                       "dbrx_moe"],
                    required=True)
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()
    if args.pair == "qwen_train":
        run_lm_pair("qwen2-72b", "train_4k", QWEN_TRAIN, args.out)
    elif args.pair == "grok_decode":
        run_lm_pair("grok-1-314b", "decode_32k", GROK_DECODE, args.out)
    elif args.pair == "dbrx_moe":
        run_lm_pair("dbrx-132b", "train_4k", DBRX_TRAIN, args.out)
    else:
        run_rgcn_pair(args.out)


if __name__ == "__main__":
    main()
