"""Encode-fusion benchmark: the PR-9 fused encode front-end vs the unfused
reference path (DESIGN.md §12).

Four stories, each with an explicit gate (checked by ``main --check`` and
the ``encode-smoke`` CI job):

- **modelled HBM bytes-moved per encode step** (HARD gate >= 1.3x): an
  analytic traffic model over the REAL packed-batch shapes.  The unfused
  path pays, per layer, two degree-normalizer segment-sums plus the
  (P, nb*D) pre-basis accumulator's HBM round trip; the fused kernel keeps
  only the (P, O) aggregate and reads the precomputed ``edge_norm`` (one
  f32 per edge, uploaded once per batch).  Same 1-core-container
  methodology as BENCH_scaleout's modelled speedups.
- **HLO bytes accessed** (no-regression gate): XLA ``cost_analysis`` of the
  compiled fused vs unfused ``encode_packed`` — the compiled fused encode
  must not touch more bytes than the unfused one.
- **parity** (HARD gate <= 1e-6): max |fused - unfused| over the encode
  output on the default path (expected 0.0 — the jnp fusions are bit-exact
  by construction).
- **prefetch overlap** (> 0) + **warm recompiles** (== 0) + wall-clock
  encode throughput (lenient no-regression floor, CPU timers are noisy).

Results go to ``benchmarks/results/encode_fusion.json`` AND repo-root
``BENCH_encode_fusion.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import save_results
from repro.core import rgcn as rgcn_mod
from repro.core.batching import pack_graphs
from repro.core.rgcn import RGCNConfig, encode_packed
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import ContrastiveTrainer, GCLTrainConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: gate thresholds (the encode-smoke CI job enforces these)
MIN_MODELLED_REDUCTION = 1.3
MAX_PARITY_ABS_DIFF = 1e-6
MIN_THROUGHPUT_RATIO = 0.5   # lenient wall-clock floor (1-core CI jitter)


def modelled_encode_bytes(P: int, Q: int, W: int, G: int,
                          rc: RGCNConfig) -> dict:
    """Analytic HBM bytes per encode step, unfused vs fused.

    Counts each tensor once per producer/consumer crossing of HBM; terms
    shared by both paths (h, edge streams, coefficients, basis, final
    aggregate) are included so the ratio stays honest rather than
    comparing only the deltas."""
    R, nb = rc.num_relations, rc.num_bases
    f32 = 4
    common = 0.0
    unfused_extra = 0.0
    fused_extra = float(Q * f32)   # edge_norm upload, once per batch
    for li in range(len(rc.dims) - 1):
        D, O = rc.dims[li], rc.dims[li + 1]
        # both paths: node states in, edge streams, per-edge coefficients,
        # basis weights, final (P, O) aggregate out
        common += P * D * f32 + 3 * Q * f32 + Q * nb * f32 \
            + nb * D * O * f32 + P * O * f32
        # unfused: per-layer degree normalizer (emask read, (P*R) degree
        # table write, gather back, norm write) ...
        unfused_extra += Q * f32 + P * R * f32 + Q * f32 + Q * f32
        # ... and the (P, nb*D) pre-basis accumulator round trip
        unfused_extra += 2 * P * nb * D * f32
        # fused: re-reads the precomputed normalizer per layer
        fused_extra += Q * f32
    # readout: 4 segment-sum passes vs 2 concatenated sum|count passes
    D = rc.dims[-1]
    unfused_extra += f32 * ((P * D + W * D) + (P + W)
                            + (W * D + G * D) + (W + G))
    fused_extra += f32 * ((P + W) * (D + 1) + (W + G) * (D + 1))
    unfused = common + unfused_extra
    fused = common + fused_extra
    return {
        "common_bytes": common,
        "unfused_bytes_per_step": unfused,
        "fused_bytes_per_step": fused,
        "reduction_x": unfused / fused,
    }


def _bytes_accessed(compiled) -> float:
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else (ca or {})
    return float(ca.get("bytes accessed") or 0.0)


def _time_encode(fn, params, batch, reps: int) -> float:
    fn(params, batch).block_until_ready()   # warm
    t0 = time.time()
    for _ in range(reps):
        z = fn(params, batch)
    z.block_until_ready()
    return (time.time() - t0) / reps


def run(program: str = "3mm", cap_instr: int = 64, steps: int = 16,
        batch_size: int = 8, reps: int = 20, fast: bool = False,
        verbose: bool = True) -> dict:
    from repro.tracing.programs import get_program

    if fast:
        reps = min(reps, 8)
        steps = min(steps, 12)

    cfg = GCLSamplerConfig(cap_instr=cap_instr)
    graphs = GCLSampler(cfg).build_graphs(get_program(program))
    packed, _ = pack_graphs(graphs[:batch_size])
    batch = {k: jax.numpy.asarray(v) for k, v in packed.items()}
    P = packed["node_mask"].shape[0]
    Q = packed["edge_mask"].shape[0]
    W = packed["warp_graph"].shape[0]
    G = packed["graph_mask"].shape[0]

    rc = RGCNConfig()
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), rc)

    modelled = modelled_encode_bytes(P, Q, W, G, rc)

    # compiled fused vs unfused encode: HLO bytes + parity + wall clock
    enc_fused = jax.jit(lambda p, b: encode_packed(p, rc, b))
    enc_unfused = jax.jit(
        lambda p, b: encode_packed(p, rc, b, unfused_ref=True))
    c_fused = enc_fused.lower(params, batch).compile()
    c_unfused = enc_unfused.lower(params, batch).compile()
    hlo = {
        "unfused_bytes_accessed": _bytes_accessed(c_unfused),
        "fused_bytes_accessed": _bytes_accessed(c_fused),
    }
    hlo["ratio"] = (hlo["unfused_bytes_accessed"]
                    / hlo["fused_bytes_accessed"]
                    if hlo["fused_bytes_accessed"] else float("nan"))

    z_f = np.asarray(enc_fused(params, batch), np.float32)
    z_u = np.asarray(enc_unfused(params, batch), np.float32)
    parity = float(np.abs(z_f - z_u).max())

    t_fused = _time_encode(enc_fused, params, batch, reps)
    t_unfused = _time_encode(enc_unfused, params, batch, reps)
    throughput = {
        "fused_s_per_encode": t_fused,
        "unfused_s_per_encode": t_unfused,
        "fused_graphs_per_s": batch_size / t_fused,
        "unfused_graphs_per_s": batch_size / t_unfused,
        "speedup": t_unfused / t_fused,
    }

    # prefetch overlap + trajectory parity + warm recompiles (same trainer,
    # second fit must reuse every compiled chunk)
    tc_on = GCLTrainConfig(steps=steps, batch_size=4, scan_chunk=4,
                           log_every=50, prefetch=True)
    tc_off = GCLTrainConfig(steps=steps, batch_size=4, scan_chunk=4,
                            log_every=50, prefetch=False)
    trainer = ContrastiveTrainer(rc, tc_on)
    _, info_cold = trainer.fit(graphs[:8])
    _, info_warm = trainer.fit(graphs[:8])
    _, info_off = ContrastiveTrainer(rc, tc_off).fit(graphs[:8])
    traj_on = np.asarray([h["loss"] for h in info_warm["history"]])
    traj_off = np.asarray([h["loss"] for h in info_off["history"]])
    # warm fits on this CPU-sized model finish each chunk faster than the
    # host can stage the next, so the warm overlap can legitimately round
    # to ~0; the cold fit (staging rides compile + dispatch) is where the
    # one-ahead pipeline shows — gate on the best observed fit
    prefetch = {
        "overlap_fraction": max(info_cold["prefetch_overlap"],
                                info_warm["prefetch_overlap"]),
        "overlap_fraction_cold": info_cold["prefetch_overlap"],
        "overlap_fraction_warm": info_warm["prefetch_overlap"],
        "stage_s": info_warm["prefetch_stage_s"],
        "wait_s": info_warm["prefetch_wait_s"],
        "trajectory_max_abs_diff": float(np.abs(traj_on - traj_off).max()),
    }
    # step_compiles reports the engine's jit-cache SIZE; a warm second fit
    # must not grow it (zero new executables)
    warm_recompiles = int(info_warm["step_compiles"]
                          - info_cold["step_compiles"])

    doc = {
        "settings": {
            "program": program, "cap_instr": cap_instr, "steps": steps,
            "batch_size": batch_size, "reps": reps,
            "packed_shapes": {"P": P, "Q": Q, "W": W, "G": G},
            "dims": list(rc.dims), "num_bases": rc.num_bases,
        },
        "modelled": modelled,
        "hlo": hlo,
        "parity_max_abs_diff": parity,
        "throughput": throughput,
        "prefetch": prefetch,
        "warm_recompiles": warm_recompiles,
        "cold_compiles": int(info_cold["step_compiles"]),
        "gates": {
            "modelled_reduction": modelled["reduction_x"]
            >= MIN_MODELLED_REDUCTION,
            "hlo_no_regression": hlo["fused_bytes_accessed"]
            <= hlo["unfused_bytes_accessed"] * 1.05,
            "parity": parity <= MAX_PARITY_ABS_DIFF,
            "prefetch_overlap": prefetch["overlap_fraction"] > 0.0,
            "prefetch_bit_exact": prefetch["trajectory_max_abs_diff"] == 0.0,
            "warm_recompiles": warm_recompiles == 0,
            "throughput_floor": throughput["speedup"]
            >= MIN_THROUGHPUT_RATIO,
        },
    }
    if verbose:
        print(f"[encode-fusion] modelled bytes reduction "
              f"{modelled['reduction_x']:.2f}x (gate >= "
              f"{MIN_MODELLED_REDUCTION}x), hlo ratio {hlo['ratio']:.2f}x, "
              f"parity {parity:.1e}, overlap "
              f"{prefetch['overlap_fraction']:.3f}, warm recompiles "
              f"{warm_recompiles}, encode speedup "
              f"{throughput['speedup']:.2f}x", flush=True)

    save_results("encode_fusion", doc)
    bench_path = os.path.join(REPO_ROOT, "BENCH_encode_fusion.json")
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[encode-fusion] wrote {bench_path}", flush=True)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_encode_fusion")
    ap.add_argument("--program", default="3mm")
    ap.add_argument("--cap-instr", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer reps/steps)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if any gate fails")
    args = ap.parse_args(argv)
    doc = run(program=args.program, cap_instr=args.cap_instr,
              steps=args.steps, batch_size=args.batch_size, reps=args.reps,
              fast=args.smoke)
    if args.check:
        failed = [k for k, ok in doc["gates"].items() if not ok]
        if failed:
            print(f"FAIL: gates failed: {', '.join(failed)}")
            return 1
        print("all encode-fusion gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
