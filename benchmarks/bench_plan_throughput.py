"""Plan-serving throughput: the compiled multi-K sweep vs the sequential
per-K loop, over a fleet of programs.

The serving path (paper §3.4: embeddings -> silhouette K-selection ->
representatives) is raced two ways on the same synthetic embedding fleet
(sizes spread across power-of-two buckets, like the scenario grid):

- ``sequential``: `select_k_and_cluster` — one jitted K-Means fit plus an
  O(n^2) silhouette per candidate K, per program (the pre-engine path,
  kept as the parity reference);
- ``engine``: `repro.sampling.PlanEngine` — size-bucketed batches, every
  candidate K of every program in a chunk evaluated in ONE compiled
  vmapped sweep, executables cached process-wide.

Each side runs ``n_rounds`` passes over the fleet (cold + steady).  The
timing model's `simulate_batch` vs scalar `simulate_kernel` is raced too
(the other half of the serving path).  Results go to
``benchmarks/results/plan_throughput.json`` AND a repo-root
``BENCH_plan_throughput.json`` with plans/s, compile counts (engine builds
+ sequential executable cache growth), the zero-recompile check on the
second program of a bucket, and sweep-vs-sequential parity deltas.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import save_results
from repro.core import clustering
from repro.core.clustering import select_k_and_cluster
from repro.sampling.engine import PlanEngine, PlanRequest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fleet(n_programs: int, d: int, seed: int = 0):
    """Synthetic per-program embedding matrices: blob-structured (so K
    selection has signal), sizes spread across pow2 buckets like the
    scenario grid's generated programs."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_programs):
        k_true = int(rng.integers(2, 7))
        n_per = int(rng.integers(12, 60))
        centers = rng.standard_normal((k_true, d)) * 40.0
        x = np.concatenate(
            [c + rng.standard_normal((n_per, d)) * 0.5 for c in centers]
        ).astype(np.float32)
        fleet.append(x)
    return fleet


def run(n_programs: int = 16, d: int = 64, k_max: int = 16, iters: int = 25,
        n_rounds: int = 2, fast: bool = False, verbose: bool = True) -> dict:
    if fast:  # benchmarks.run / CI entry point
        n_programs, k_max, iters = min(n_programs, 8), min(k_max, 12), 15

    fleet = _fleet(n_programs, d)
    seqs = [np.arange(len(x)) for x in fleet]
    kw = dict(k_max=k_max, iters=iters)

    sides: dict = {}
    # -- sequential reference ------------------------------------------------
    seq_execs0 = (clustering._kmeans_run._cache_size()
                  + clustering._silhouette_jit._cache_size())
    rounds = []
    seq_out = None
    for r in range(n_rounds):
        t0 = time.time()
        seq_out = [select_k_and_cluster(x, seed=i, **kw)
                   for i, x in enumerate(fleet)]
        wall = time.time() - t0
        rounds.append({"wall_s": wall, "plans_per_s": n_programs / wall})
        if verbose:
            print(f"[plan-throughput] sequential round {r}: {wall:.2f}s "
                  f"-> {n_programs / wall:.2f} plans/s", flush=True)
    sides["sequential"] = {
        "rounds": rounds, "cold": rounds[0], "steady": rounds[-1],
        "executables": (clustering._kmeans_run._cache_size()
                        + clustering._silhouette_jit._cache_size()
                        - seq_execs0),
    }

    # -- compiled engine -----------------------------------------------------
    clustering.reset_engine_stats()
    engine = PlanEngine(k_max=k_max, iters=iters)
    rounds = []
    eng_out = None
    for r in range(n_rounds):
        t0 = time.time()
        plans = engine.plan_many([
            PlanRequest(x, s, "bench", seed=i)
            for i, (x, s) in enumerate(zip(fleet, seqs))])
        wall = time.time() - t0
        eng_out = [(p.labels, p.extra) for p in plans]
        rounds.append({"wall_s": wall, "plans_per_s": n_programs / wall})
        if verbose:
            print(f"[plan-throughput] engine     round {r}: {wall:.2f}s "
                  f"-> {n_programs / wall:.2f} plans/s", flush=True)
    st = engine.engine_stats()
    # zero-recompile check AFTER the timed rounds (probe compiles must not
    # pollute the round build counts): two DISTINCT same-bucket programs,
    # planned one after the other — the second may build nothing
    rng = np.random.default_rng(99)
    probe = [rng.standard_normal((n, d)).astype(np.float32)
             for n in (40, 45)]  # both in the 64-point bucket
    assert (clustering.bucket_points(len(probe[0]))
            == clustering.bucket_points(len(probe[1])))
    engine.cluster(probe[0], seed=0)
    builds_after_first = clustering.ENGINE_STATS["builds"]
    engine.cluster(probe[1], seed=1)
    second_program_builds = (clustering.ENGINE_STATS["builds"]
                             - builds_after_first)
    sides["engine"] = {
        "rounds": rounds, "cold": rounds[0], "steady": rounds[-1],
        "builds": st["builds"], "dispatches": st["dispatches"],
        "bucket_hist": st["bucket_hist"],
        "second_program_builds": second_program_builds,
    }

    # -- parity --------------------------------------------------------------
    label_match = [bool(np.array_equal(a[0], b[0]))
                   for a, b in zip(seq_out, eng_out)]
    k_match = [a[1]["k"] == b[1]["k"] for a, b in zip(seq_out, eng_out)]
    sil_delta = max(abs(a[1]["sil"] - b[1]["sil"])
                    for a, b in zip(seq_out, eng_out))
    parity = {
        "programs": n_programs,
        "labels_identical": int(sum(label_match)),
        "k_identical": int(sum(k_match)),
        "max_sil_delta": float(sil_delta),
    }

    # -- vectorized timing model vs the scalar shim --------------------------
    from repro.sim.hardware import P1
    from repro.sim.timing import (
        _METRIC_FIELDS, _simulate_kernel_scalar, simulate_batch, stack_stats,
    )
    from repro.tracing.programs import get_program

    prog = get_program("3mm" if fast else "AlexNet")
    stats = [k.stats("P1") for k in prog.kernels]
    t0 = time.time()
    batch = simulate_batch(stack_stats(stats), P1)
    batch_s = time.time() - t0
    t0 = time.time()
    scalar = [_simulate_kernel_scalar(s, P1) for s in stats]
    scalar_s = time.time() - t0
    sim_delta = max(
        abs(getattr(batch[i], f) - getattr(m, f))
        / max(abs(getattr(m, f)), 1e-12)
        for i, m in enumerate(scalar) for f in _METRIC_FIELDS)
    timing_model = {
        "program": prog.name, "kernels": len(stats),
        "batch_s": batch_s, "scalar_s": scalar_s,
        "kernels_per_s_batch": len(stats) / max(batch_s, 1e-9),
        "kernels_per_s_scalar": len(stats) / max(scalar_s, 1e-9),
        "speedup": scalar_s / max(batch_s, 1e-9),
        "max_rel_delta": float(sim_delta),
    }

    doc = {
        "settings": {"n_programs": n_programs, "d": d, "k_max": k_max,
                     "iters": iters, "n_rounds": n_rounds},
        "sides": sides,
        "parity": parity,
        "timing_model": timing_model,
        # headline: steady-state plan throughput (sweeps replan the same
        # buckets over and over; the engine's executables are already hot)
        "speedup_steady": (sides["engine"]["steady"]["plans_per_s"]
                           / sides["sequential"]["steady"]["plans_per_s"]),
        "speedup_cold": (sides["engine"]["cold"]["plans_per_s"]
                         / sides["sequential"]["cold"]["plans_per_s"]),
        "second_program_builds": second_program_builds,
    }
    if verbose:
        print(f"[plan-throughput] steady speedup {doc['speedup_steady']:.2f}x "
              f"(cold {doc['speedup_cold']:.2f}x), parity "
              f"{parity['labels_identical']}/{n_programs} labels identical, "
              f"second-program builds {second_program_builds}", flush=True)

    save_results("plan_throughput", doc)
    bench_path = os.path.join(REPO_ROOT, "BENCH_plan_throughput.json")
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[plan-throughput] wrote {bench_path}", flush=True)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_plan_throughput")
    ap.add_argument("--n-programs", type=int, default=16)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--k-max", type=int, default=16)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--n-rounds", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer/smaller programs)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit non-zero if steady speedup falls below this")
    args = ap.parse_args(argv)
    doc = run(n_programs=args.n_programs, d=args.d, k_max=args.k_max,
              iters=args.iters, n_rounds=args.n_rounds, fast=args.smoke)
    bad = []
    if args.min_speedup and doc["speedup_steady"] < args.min_speedup:
        bad.append(f"steady speedup {doc['speedup_steady']:.2f}x < "
                   f"{args.min_speedup:.2f}x")
    if doc["second_program_builds"] != 0:
        bad.append(f"second program compiled "
                   f"{doc['second_program_builds']} executables (want 0)")
    p = doc["parity"]
    if p["labels_identical"] != p["programs"] or p["k_identical"] != p["programs"]:
        bad.append(f"parity broken: {p}")
    if bad:
        print("FAIL: " + "; ".join(bad))
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
