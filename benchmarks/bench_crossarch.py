"""Paper Table 3: cross-architecture robustness — clustering decisions made
on P1 (Turing) applied to ground truth on P2 (Ampere) and P3 (Ada)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import evaluate, plans_for, save_results
from repro.tracing.programs import PAPER_PROGRAMS


def run(programs=None, fast: bool = False, verbose: bool = True):
    programs = programs or PAPER_PROGRAMS
    table = {}
    for prog in programs:
        plan = plans_for(prog, fast=fast, verbose=verbose)["GCL-Sampler"]
        table[prog] = {
            plat: evaluate(plan, prog, plat) for plat in ("P1", "P2", "P3")
        }
        if verbose:
            row = " | ".join(
                f"{plat}: {table[prog][plat]['error_pct']:.2f}% "
                f"{table[prog][plat]['speedup']:.1f}x"
                for plat in ("P1", "P2", "P3")
            )
            print(f"[table3] {prog:10s} {row}", flush=True)
    summary = {
        plat: {
            "avg_error_pct": float(np.mean(
                [table[p][plat]["error_pct"] for p in programs])),
            "avg_speedup": float(np.mean(
                [table[p][plat]["speedup"] for p in programs])),
        }
        for plat in ("P1", "P2", "P3")
    }
    payload = {"per_program": table, "summary": summary,
               "paper_reference": {
                   "P1": {"avg_error_pct": 0.37, "avg_speedup": 258.94},
                   "P2": {"avg_error_pct": 1.50, "avg_speedup": 203.97},
                   "P3": {"avg_error_pct": 1.22, "avg_speedup": 203.64},
               }}
    save_results("table3_crossarch", payload)
    if verbose:
        for plat, s in summary.items():
            print(f"[table3] {plat}: avg err {s['avg_error_pct']:.2f}% "
                  f"avg speedup {s['avg_speedup']:.1f}x", flush=True)
    return payload


if __name__ == "__main__":
    run()
