"""Ingestion benchmark: the parallel trace->graph engine vs the sequential
loop-oracle reference, proven on real-model-scale traces (DESIGN.md §13).

Four stories, each with an explicit gate (checked by ``main --check`` and
the ``ingest-smoke`` CI job):

- **cold ingestion throughput** (HARD gate >= 3x): kernels/s through
  ``IngestEngine`` (vectorized tracer + dedup memo + worker pool) vs the
  pre-PR sequential reference (``trace_kernel_loop`` per invocation, no
  dedup) on a model-zoo program at its REAL trace window.  On the 1-core
  CI container the speedup comes from the vectorized tracer and the dedup
  memo, not thread scaling — the hypothesis suite separately proves the
  worker pool bit-exact at any width.
- **parity** (HARD gate == 0.0): max |engine - reference| over every
  node/edge array of every graph — the vectorized tracer replays the
  oracle's exact RNG stream, so the diff is identically zero.
- **warm-cache zero-retrace** (HARD gate == 0): a fresh engine over the
  populated ``GraphStore`` re-traces nothing (``stats["traced"] == 0``).
- **pipeline overlap** (gate > 0): fraction of ingest build time hidden
  behind the consuming stream_pack stage (1 - wait/build).

Plus the end-to-end proof: >= 3 ``model:<config>`` programs resolve from
PROGRAMS and flow through ``embed_stream`` on ingested graphs.

Results go to ``benchmarks/results/ingest.json`` AND repo-root
``BENCH_ingest.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import save_results
from repro.core.graphs import build_kernel_graph
from repro.ingest import GraphStore, IngestConfig, IngestEngine
from repro.tracing.programs import Program, get_program
from repro.workloads.streaming import stream_pack

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: gate thresholds (the ingest-smoke CI job enforces these)
MIN_COLD_SPEEDUP = 3.0
MAX_PARITY_ABS_DIFF = 0.0

_GRAPH_FIELDS = ("node_type", "token", "pc_norm", "vstats", "warp_id",
                 "edge_src", "edge_dst", "edge_type")

EMBED_PROGRAMS = ("model:llama3.2-3b:prefill", "model:mamba2-780m:decode",
                  "model:dbrx-132b:prefill")


def _truncate(program: Program, n: int) -> Program:
    if n and len(program.kernels) > n:
        return Program(program.name, program.kernels[:n],
                       fingerprint_extra=program.fingerprint_extra
                       + f"|bench-trunc{n}",
                       trace_caps=program.trace_caps)
    return program


def _graph_parity(a, b) -> float:
    """Max abs diff across every array (inf on shape/layout mismatch)."""
    worst = 0.0
    for f in _GRAPH_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        if x.shape != y.shape or x.dtype != y.dtype:
            return float("inf")
        if x.size:
            worst = max(worst, float(
                np.abs(np.asarray(x, np.float64)
                       - np.asarray(y, np.float64)).max()))
    return worst


def _reference_ingest(program: Program, caps) -> tuple[list, float]:
    """The pre-engine path: loop-oracle tracer, one kernel at a time, no
    dedup, no cache — what every run used to pay."""
    t0 = time.perf_counter()
    graphs = [build_kernel_graph(inv.trace(*caps, loop=True))
              for inv in program.kernels]
    return graphs, time.perf_counter() - t0


def run(n_kernels: int = 8, workers: int = 2, embed_kernels: int = 6,
        train_steps: int = 8, fast: bool = True, verbose: bool = True):
    from repro.config import resolve_trace_caps

    zoo = _truncate(get_program("model:llama3.2-3b:prefill"),
                    n_kernels if fast else max(n_kernels, 32))
    caps = resolve_trace_caps(None, None, zoo)

    # cold throughput + parity: engine vs the sequential loop reference
    ref_graphs, ref_s = _reference_ingest(zoo, caps)
    eng_cold = IngestEngine(IngestConfig(workers=workers))
    t0 = time.perf_counter()
    eng_graphs = list(eng_cold.iter_graphs(zoo))
    eng_s = time.perf_counter() - t0
    parity = max((_graph_parity(a, b)
                  for a, b in zip(eng_graphs, ref_graphs)), default=0.0)
    n = len(zoo.kernels)
    throughput = {
        "program": zoo.name, "kernels": n,
        "trace_caps": list(caps),
        "reference_s": ref_s, "engine_s": eng_s,
        "reference_kernels_per_s": n / ref_s,
        "engine_kernels_per_s": n / eng_s,
        "cold_speedup": ref_s / eng_s,
        "unique_traced": eng_cold.stats["traced"],
        "memo_hits": eng_cold.stats["memo_hits"],
    }

    # warm-cache zero-retrace + pipeline overlap: cold populate the store,
    # then a FRESH engine streams through stream_pack (pack work is the
    # consumer the ingest workers hide behind)
    with tempfile.TemporaryDirectory(prefix="bench_ingest_") as tmp:
        store = GraphStore(tmp)
        populate = IngestEngine(IngestConfig(workers=workers), store)
        list(populate.iter_graphs(zoo))
        warm_eng = IngestEngine(IngestConfig(workers=workers), store)
        t0 = time.perf_counter()
        packed_batches = sum(
            1 for _ in stream_pack(warm_eng.iter_graphs(zoo)))
        warm_s = time.perf_counter() - t0
        warm = {
            "retraced": warm_eng.stats["traced"],
            "store_hits": warm_eng.stats["store_hits"],
            "memo_hits": warm_eng.stats["memo_hits"],
            "corrupt": warm_eng.stats["corrupt"],
            "warm_s": warm_s,
            "packed_batches": packed_batches,
            "manifest_warm": store.warm(zoo, *caps),
        }
    overlap = {
        "cold_overlap_fraction": eng_cold.overlap_fraction,
        "cold_build_s": eng_cold.stats["build_s"],
        "cold_wait_s": eng_cold.stats["wait_s"],
    }

    # >= 3 model programs end-to-end through embed_stream (one encoder
    # trained on the first program's graphs, reused to embed all three)
    from repro.core.rgcn import RGCNConfig
    from repro.core.sampler import GCLSampler, GCLSamplerConfig
    from repro.core.train import GCLTrainConfig

    cfg = GCLSamplerConfig(
        train=GCLTrainConfig(steps=train_steps, batch_size=4, scan_chunk=4,
                             log_every=100),
        rgcn=RGCNConfig(),
        ingest=IngestConfig(workers=workers),
    )
    sampler = GCLSampler(cfg)
    programs = [_truncate(get_program(name), embed_kernels if fast else 0)
                for name in EMBED_PROGRAMS]
    sampler.train_stream(sampler.iter_graphs(programs[0]),
                         n_total=len(programs[0]))
    embed = {}
    for prog in programs:
        t0 = time.perf_counter()
        emb = sampler.embed_stream(sampler.iter_graphs(prog))
        embed[prog.name] = {
            "kernels": len(prog), "embedded": int(emb.shape[0]),
            "dim": int(emb.shape[1]), "finite": bool(np.isfinite(emb).all()),
            "embed_s": time.perf_counter() - t0,
        }
    embed_ok = (len(embed) >= 3
                and all(v["embedded"] == v["kernels"] and v["finite"]
                        for v in embed.values()))

    doc = {
        "settings": {
            "fast": fast, "workers": workers, "n_kernels": n,
            "embed_kernels": embed_kernels, "train_steps": train_steps,
        },
        "throughput": throughput,
        "parity_max_abs_diff": parity,
        "warm": warm,
        "overlap": overlap,
        "embed_stream": embed,
        "gates": {
            "cold_speedup": throughput["cold_speedup"] >= MIN_COLD_SPEEDUP,
            "parity": parity <= MAX_PARITY_ABS_DIFF,
            "warm_zero_retrace": warm["retraced"] == 0,
            "overlap": overlap["cold_overlap_fraction"] > 0.0,
            "model_zoo_embed": embed_ok,
        },
    }
    if verbose:
        print(f"[ingest] cold {throughput['cold_speedup']:.1f}x vs loop "
              f"reference (gate >= {MIN_COLD_SPEEDUP}x), parity "
              f"{parity:.1e}, warm retraced {warm['retraced']}, overlap "
              f"{overlap['cold_overlap_fraction']:.2f}, "
              f"{len(embed)} model programs embedded", flush=True)

    save_results("ingest", doc)
    bench_path = os.path.join(REPO_ROOT, "BENCH_ingest.json")
    with open(bench_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[ingest] wrote {bench_path}", flush=True)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_ingest")
    ap.add_argument("--kernels", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--embed-kernels", type=int, default=6)
    ap.add_argument("--train-steps", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (truncated programs)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if any gate fails")
    args = ap.parse_args(argv)
    doc = run(n_kernels=args.kernels, workers=args.workers,
              embed_kernels=args.embed_kernels, train_steps=args.train_steps,
              fast=args.smoke or args.kernels <= 8)
    if args.check:
        failed = [k for k, ok in doc["gates"].items() if not ok]
        if failed:
            print(f"FAIL: gates failed: {', '.join(failed)}")
            return 1
        print("all ingest gates passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
