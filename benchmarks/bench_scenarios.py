"""Streaming vs materialized trace->graph ingestion on a scenario population.

The streaming path (`repro.workloads.streaming`) holds at most one
micro-batch of graphs resident while the materialized path builds every
graph up front — on a scenario population the peak residency gap is the
whole point (hundreds of programs cannot be materialized at once), and the
content-hash cache keeps the streaming path's throughput competitive.

    PYTHONPATH=src python -m benchmarks.bench_scenarios [--smoke]

Writes benchmarks/results/scenarios[_suffix].json:
  peak_resident_{graphs,nodes} for both paths, embed wall time, and the
  residency-reduction factor.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save_results
from repro.core.rgcn import RGCNConfig
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.workloads import (
    ScenarioSpec, build_scenario, iter_program_graphs, materialized_peak,
    scenario_families,
)


def scenario_population(smoke: bool):
    phases, phase_len = (2, 6) if smoke else (3, 12)
    seeds = (0,) if smoke else (0, 1)
    return [
        build_scenario(ScenarioSpec(f, seed=s, phases=phases,
                                    phase_len=phase_len))
        for f in scenario_families()
        for s in seeds
    ]


def run(smoke: bool = False, verbose: bool = True):
    programs = scenario_population(smoke)
    cfg = GCLSamplerConfig(
        cap_instr=48 if smoke else 96,
        train=GCLTrainConfig(steps=6 if smoke else 40, batch_size=4),
        rgcn=RGCNConfig(),
    )
    sampler = GCLSampler(cfg)
    # one encoder for the whole population (the fit-once idiom)
    sampler.train_stream(iter_program_graphs(programs[0], cfg.cap_warps,
                                             cfg.cap_instr),
                         n_total=len(programs[0]))

    def all_graphs_iter():
        for prog in programs:
            yield from iter_program_graphs(prog, cfg.cap_warps, cfg.cap_instr)

    t0 = time.time()
    emb_stream = sampler.embed_stream(all_graphs_iter())
    t_stream = time.time() - t0
    stream_stats = dict(sampler.trainer.embed_stats)

    sampler.trainer._embed_cache.clear()  # fair second pass
    t0 = time.time()
    graphs = list(all_graphs_iter())
    mat_peak = materialized_peak(graphs)
    emb_mat = sampler.embed(graphs)
    t_mat = time.time() - t0

    assert emb_stream.shape == emb_mat.shape
    max_dev = float(np.abs(emb_stream - emb_mat).max())
    residency_x = mat_peak["peak_resident_graphs"] / max(
        stream_stats["peak_resident_graphs"], 1)
    out = {
        "programs": len(programs),
        "invocations": int(emb_stream.shape[0]),
        "stream": {
            "time_s": t_stream,
            "peak_resident_graphs": stream_stats["peak_resident_graphs"],
            "peak_resident_nodes": stream_stats["peak_resident_nodes"],
            "cache_hits": stream_stats["cache_hits"],
            "microbatches": stream_stats["microbatches"],
        },
        "materialized": {
            "time_s": t_mat,
            "peak_resident_graphs": mat_peak["peak_resident_graphs"],
            "peak_resident_nodes": mat_peak["peak_resident_nodes"],
        },
        "residency_reduction_x": residency_x,
        "max_embedding_dev": max_dev,
    }
    if verbose:
        print(f"population: {out['programs']} programs, "
              f"{out['invocations']} invocations")
        print(f"stream:       {t_stream:6.1f}s  peak graphs "
              f"{stream_stats['peak_resident_graphs']:5d}  peak nodes "
              f"{stream_stats['peak_resident_nodes']}")
        print(f"materialized: {t_mat:6.1f}s  peak graphs "
              f"{mat_peak['peak_resident_graphs']:5d}  peak nodes "
              f"{mat_peak['peak_resident_nodes']}")
        print(f"residency reduction: {residency_x:.1f}x  "
              f"(max embedding dev {max_dev:.2e})")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_scenarios")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke)
    name = "scenarios_smoke" if args.smoke else "scenarios"
    path = save_results(name, out)
    print(f"results: {path}")
    return 0


if __name__ == "__main__":
    main()
