"""Shared benchmark plumbing: fit all four methods on a program through the
unified `repro.sampling` registry, evaluate error/speedup on a platform, and
cache plans across benchmarks (training is the expensive step and Table 3
reuses Fig 4/5's clustering)."""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.sampler import GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.sampling import available_methods, evaluate_metrics, get_method
from repro.sim.simulate import simulate_program
from repro.tracing.programs import get_program

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS_DIR, exist_ok=True)

_plan_cache: dict = {}
_metrics_cache: dict = {}


def sampler_config(fast: bool = False) -> GCLSamplerConfig:
    if fast:
        return GCLSamplerConfig(
            cap_instr=64, train=GCLTrainConfig(steps=40, batch_size=8))
    return GCLSamplerConfig(
        cap_instr=96, train=GCLTrainConfig(steps=120, batch_size=16))


def metrics_for(program_name: str, platform: str):
    key = (program_name, platform)
    if key not in _metrics_cache:
        _metrics_cache[key] = simulate_program(get_program(program_name), platform)
    return _metrics_cache[key]


def plans_for(program_name: str, fast: bool = False, verbose: bool = True):
    """All four methods' plans (clustering decisions made on P1, as in the
    paper's cross-architecture protocol)."""
    key = (program_name, fast)
    if key in _plan_cache:
        return _plan_cache[key]
    prog = get_program(program_name)
    plans = {}
    for method_id in available_methods():
        kwargs = {"cfg": sampler_config(fast)} if method_id == "gcl" else {}
        method = get_method(method_id, **kwargs)
        t0 = time.time()
        plan, _ = method.run(prog)
        if verbose and method_id == "gcl":
            print(f"  [gcl] {program_name}: K={plan.num_clusters} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        plans[plan.method] = plan
    _plan_cache[key] = plans
    return plans


def evaluate(plan, program_name: str, platform: str = "P1"):
    ms = metrics_for(program_name, platform)
    res = evaluate_metrics(plan, ms, program=program_name, platform=platform)
    return {
        "error_pct": res.error_pct["cycles"],
        "speedup": res.speedup,
        "clusters": res.num_clusters,
        "reps": res.num_reps,
    }


def save_results(name: str, payload):
    # fast/CI runs write *_fast.json so they never clobber the paper-sized
    # artifacts that render_experiments.py reads (set by benchmarks.run).
    name += os.environ.get("REPRO_RESULTS_SUFFIX", "")
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def geomean(xs):
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))
