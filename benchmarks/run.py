"""Benchmark runner — one entry per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``            fast mode (CI-sized)
``PYTHONPATH=src python -m benchmarks.run --full``     paper-sized runs

Prints ``name,us_per_call,derived`` CSV rows summarizing each benchmark,
and writes detailed JSON under benchmarks/results/.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized runs (all 11 programs, long training)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig45,table3,fig6,e2e,traincost,"
                         "encode,ingest,plans,serve,scaleout,roofline")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None
    if fast:  # keep the paper-sized artifacts (EXPERIMENTS.md inputs) intact
        import os

        os.environ.setdefault("REPRO_RESULTS_SUFFIX", "_fast")

    # fast mode trims the program list to keep CPU runtime sane; --full runs
    # the paper's 11-program suite.
    programs = (
        ["nw", "backprop", "3mm", "bfs", "lud", "AlexNet"] if fast else None
    )

    rows = []

    def bench(name, fn, **kw):
        if only and name not in only:
            return
        t0 = time.time()
        out = fn(**kw)
        dt = time.time() - t0
        derived = _derive(name, out)
        rows.append((name, f"{dt * 1e6:.0f}", derived))
        print(f"[run] {name} done in {dt:.0f}s -> {derived}", flush=True)

    from benchmarks import (
        bench_ablations, bench_accuracy_speedup, bench_crossarch,
        bench_e2e_sim, bench_encode_fusion, bench_ingest, bench_microarch,
        bench_plan_throughput, bench_roofline, bench_scaleout,
        bench_serve_latency, bench_train_throughput,
    )

    bench("fig45", bench_accuracy_speedup.run, programs=programs, fast=fast)
    bench("table3", bench_crossarch.run, programs=programs, fast=fast)
    bench("fig6", bench_microarch.run, fast=fast)
    bench("e2e", bench_e2e_sim.run,
          programs=("nw", "lud") if fast else bench_e2e_sim.PROGRAMS,
          fast=fast)
    bench("traincost", bench_train_throughput.run, fast=fast)
    bench("encode", bench_encode_fusion.run, fast=fast)
    bench("ingest", bench_ingest.run, fast=fast,
          n_kernels=8 if fast else 32)
    bench("plans", bench_plan_throughput.run, fast=fast)
    bench("serve", bench_serve_latency.run, fast=fast)
    # re-execs itself: --xla_force_host_platform_device_count must be set
    # before jax initializes, and this process already imported jax
    bench("scaleout", bench_scaleout.run, fast=fast)
    if args.full or (only and "ablations" in only):
        bench("ablations", bench_ablations.run, fast=True)
    bench("roofline", bench_roofline.run)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def _derive(name, out) -> str:
    try:
        if name == "fig45":
            s = out["summary"]["GCL-Sampler"]
            return (f"gcl_err={s['avg_error_pct']:.2f}%"
                    f";gcl_speedup={s['avg_speedup']:.1f}x")
        if name == "table3":
            return ";".join(
                f"{p}_err={out['summary'][p]['avg_error_pct']:.2f}%"
                for p in ("P1", "P2", "P3")
            )
        if name == "fig6":
            errs = [v["error_pct"] for prog in out.values() for v in prog.values()]
            return f"max_metric_err={max(errs):.2f}%"
        if name == "e2e":
            sus = [v["sim_speedup"] for v in out.values()]
            return f"max_sim_speedup={max(sus):.1f}x"
        if name == "traincost":
            rates = [v["s_per_100_kernels"] for v in out.values()]
            return f"s_per_100_kernels={max(rates):.1f}"
        if name == "encode":
            return (f"bytes_reduction="
                    f"{out['modelled']['reduction_x']:.2f}x"
                    f";parity={out['parity_max_abs_diff']:.1e}"
                    f";overlap={out['prefetch']['overlap_fraction']:.2f}"
                    f";warm_recompiles={out['warm_recompiles']}")
        if name == "ingest":
            return (f"cold_speedup={out['throughput']['cold_speedup']:.1f}x"
                    f";parity={out['parity_max_abs_diff']:.1e}"
                    f";warm_retraced={out['warm']['retraced']}"
                    f";overlap={out['overlap']['cold_overlap_fraction']:.2f}"
                    f";model_programs={len(out['embed_stream'])}")
        if name == "ablations":
            worst = max(
                r["error_pct"] for prog in out.values() for r in prog.values()
            )
            full_err = max(r["full"]["error_pct"] for r in out.values())
            return f"full_err={full_err:.2f}%;worst_ablation_err={worst:.2f}%"
        if name == "serve":
            return (f"warm_p99_ratio={out['cold_vs_warm']['p99_ratio']:.1f}x"
                    f";batch_speedup="
                    f"{out['batching_speedup_high_load']:.1f}x")
        if name == "scaleout":
            h = out["headline"]
            return (f"train_speedup={h['train_modelled_speedup']:.1f}x"
                    f";plan_speedup={h['plan_modelled_speedup']:.1f}x"
                    f";warm_recompiles={h['warm_recompiles']}"
                    f";compress_bytes="
                    f"{h['grad_compress_bytes_reduction']:.1f}x")
        if name == "roofline":
            n = len(out)
            dom = {}
            for r in out:
                dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
            return f"cells={n};" + ";".join(f"{k}={v}" for k, v in sorted(dom.items()))
    except Exception as e:  # pragma: no cover
        return f"derive_error={e!r}"
    return ""


if __name__ == "__main__":
    main()
