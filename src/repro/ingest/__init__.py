"""Parallel, deterministic, cache-backed trace->graph ingestion.

The host-side front door of the sampler: `IngestEngine` traces kernels and
builds their HRGs through a worker pool with deterministic output order and
bounded peak residency, while `GraphStore` persists packed graphs on disk so
warm runs skip tracing entirely (DESIGN.md §13).
"""

from repro.ingest.engine import IngestConfig, IngestEngine
from repro.ingest.store import GRAPH_SCHEMA, GraphStore, kernel_graph_key

__all__ = [
    "GRAPH_SCHEMA",
    "GraphStore",
    "IngestConfig",
    "IngestEngine",
    "kernel_graph_key",
]
