"""On-disk content-hash store for built kernel graphs.

Layout (atomic publish like ArtifactStore: write tmp, then rename):

    <root>/kernels/<kk[:2]>/<kk>.npz      # one entry per UNIQUE kernel
    <root>/programs/<fp>-cw..-ci..-g..-p...json   # ordered key manifest

A kernel entry is keyed on everything that determines the traced graph's
bits: (template, params, seed) — the `_rng_for` inputs — plus the trace
window (`cap_warps`/`cap_instr`) and the graph/pack schema versions, so a
cached graph can never be replayed across differing trace caps or a packing
change (ISSUE satellite: caps folded into the cache key derivation).
Kernel name/seq are deliberately NOT in the key: two invocations of the
same (template, params, seed) share one entry, which is exactly the dedup
the ingest engine exploits.

Every entry carries a sha1 checksum over its array bytes; a short read,
bit-flip, or truncated npz is rejected on load (counted in ``stats``) and
the caller falls back to re-tracing — a corrupt cache can slow a run down
but never change its output.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from typing import Optional

import numpy as np

from repro.core.batching import PACK_SCHEMA
from repro.core.graphs import KernelGraph

#: bump when KernelGraph's array layout changes (invalidates every entry)
GRAPH_SCHEMA = 1

_FIELDS = ("node_type", "token", "pc_norm", "vstats", "warp_id",
           "edge_src", "edge_dst", "edge_type")


def kernel_graph_key(inv, cap_warps: int, cap_instr: int) -> str:
    """Content key for one kernel's graph: trace identity x window x schema."""
    h = hashlib.sha1(
        f"{inv.template}|{sorted(inv.params.items())}|{inv.seed}"
        f"|cw{int(cap_warps)}|ci{int(cap_instr)}"
        f"|g{GRAPH_SCHEMA}|p{PACK_SCHEMA}".encode()
    )
    return h.hexdigest()[:20]


def _digest(arrays: dict) -> str:
    h = hashlib.sha1()
    for f in _FIELDS + ("n_warps",):
        a = np.ascontiguousarray(arrays[f])
        h.update(f.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class GraphStore:
    """Save/load built `KernelGraph`s under a run directory.

    ``stats`` counts ``hits`` / ``misses`` / ``corrupt`` / ``writes`` —
    the warm-run acceptance gate is ``traced == 0`` on the engine side,
    which this store makes possible."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "kernels"), exist_ok=True)
        os.makedirs(os.path.join(root, "programs"), exist_ok=True)
        self._lock = threading.Lock()  # ingest workers share one store
        self.stats = {"hits": 0, "misses": 0, "corrupt": 0, "writes": 0}

    def _bump(self, field: str):
        with self._lock:
            self.stats[field] += 1

    # -- kernel entries ------------------------------------------------------
    def _kernel_path(self, key: str) -> str:
        return os.path.join(self.root, "kernels", key[:2], f"{key}.npz")

    def has_kernel(self, key: str) -> bool:
        return os.path.exists(self._kernel_path(key))

    def save_kernel(self, key: str, g: KernelGraph) -> str:
        path = self._kernel_path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        arrays = {f: getattr(g, f) for f in _FIELDS}
        arrays["n_warps"] = np.asarray(g.n_warps, np.int64)
        arrays["checksum"] = np.asarray(_digest(arrays))
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path)  # concurrent writers race benignly:
        except BaseException:      # same key -> same bytes, last rename wins
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._bump("writes")
        return path

    def load_kernel(self, key: str) -> Optional[KernelGraph]:
        """None on miss OR on a corrupt entry (caller re-traces)."""
        path = self._kernel_path(key)
        if not os.path.exists(path):
            self._bump("misses")
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                arrays = {f: z[f] for f in _FIELDS}
                arrays["n_warps"] = z["n_warps"]
                stored = str(z["checksum"][()])
        except Exception:
            self._bump("corrupt")
            return None
        if _digest(arrays) != stored:
            self._bump("corrupt")
            return None
        self._bump("hits")
        return KernelGraph(
            **{f: arrays[f] for f in _FIELDS}, n_warps=int(arrays["n_warps"])
        )

    # -- program manifests ---------------------------------------------------
    def _manifest_path(self, program, cap_warps: int, cap_instr: int) -> str:
        from repro.sampling.store import program_fingerprint  # lazy: no cycle

        fp = program_fingerprint(program)
        return os.path.join(
            self.root, "programs",
            f"{fp}-cw{int(cap_warps)}-ci{int(cap_instr)}"
            f"-g{GRAPH_SCHEMA}-p{PACK_SCHEMA}.json",
        )

    def save_manifest(self, program, cap_warps: int, cap_instr: int,
                      keys: list[str]) -> str:
        path = self._manifest_path(program, cap_warps, cap_instr)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"keys": list(keys)}, f)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def load_manifest(self, program, cap_warps: int,
                      cap_instr: int) -> Optional[list[str]]:
        path = self._manifest_path(program, cap_warps, cap_instr)
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                return list(json.load(f)["keys"])
        except Exception:
            return None

    def warm(self, program, cap_warps: int, cap_instr: int) -> bool:
        """True when a completed ingest of this program at these caps is on
        disk (manifest present and every kernel entry exists)."""
        keys = self.load_manifest(program, cap_warps, cap_instr)
        if keys is None:
            return False
        return all(self.has_kernel(k) for k in keys)
