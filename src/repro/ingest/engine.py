"""Worker-pool trace->graph ingestion with deterministic output order.

`IngestEngine.iter_graphs` is a drop-in replacement for
`core.graphs.iter_kernel_graphs`: it yields one built `KernelGraph` per
kernel invocation IN PROGRAM ORDER, but traces up to ``workers`` kernels
concurrently with a bounded look-ahead window, so peak residency stays at
``workers + depth`` graphs no matter how long the program is.  Output is
bit-identical to sequential ingestion at any worker count: the tracer's
RNG is keyed per (template, params, seed, warp) — never shared mutable
state — and results are collected FIFO (the hypothesis suite enforces it).

Tracing is numpy-heavy (the vectorized `trace_kernel` spends its time
inside BLAS-free numpy ops that release the GIL), so a thread pool gives
real concurrency without pickling traces across processes.

Two caches stack underneath:
  - an in-process bounded LRU memo over the content key — duplicate
    invocations of one kernel (same template/params/seed at the same caps)
    build once per engine;
  - an optional on-disk `GraphStore` — warm runs load npz entries and
    re-trace NOTHING (``stats["traced"] == 0``), and a corrupt entry is
    rejected, re-traced, and overwritten.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Iterator, Optional

from repro.config import resolve_trace_caps
from repro.core.graphs import KernelGraph, build_kernel_graph
from repro.ingest.store import GraphStore, kernel_graph_key


@dataclass(frozen=True)
class IngestConfig:
    #: concurrent trace workers; 0 = sequential inline (the parity baseline)
    workers: int = 0
    #: extra look-ahead submissions beyond the workers — peak residency is
    #: bounded by ``workers + depth`` in-flight graphs
    depth: int = 2
    #: consult/populate the attached GraphStore
    cache: bool = True
    #: in-process dedup memo capacity (unique kernels kept resident)
    memo: int = 128


class IngestEngine:
    """Parallel deterministic ingestion over a Program's kernels."""

    def __init__(self, config: Optional[IngestConfig] = None,
                 store: Optional[GraphStore] = None):
        self.config = config or IngestConfig()
        self.store = store
        self._lock = threading.Lock()  # guards _memo + stats (workers race)
        self._memo: OrderedDict[str, KernelGraph] = OrderedDict()
        self.stats = {
            "kernels": 0,        # invocations ingested
            "traced": 0,         # actually traced+built (warm run: 0)
            "memo_hits": 0,      # in-process dedup hits
            "store_hits": 0,     # on-disk cache hits
            "corrupt": 0,        # store entries rejected (then re-traced)
            "build_s": 0.0,      # worker seconds tracing/building/loading
            "wait_s": 0.0,       # consumer seconds blocked on a result
        }

    @property
    def overlap_fraction(self) -> float:
        """1 - wait/build: how much ingestion hid behind the consumer."""
        if self.stats["build_s"] <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.stats["wait_s"] / self.stats["build_s"])

    # -- single kernel -------------------------------------------------------
    def _memo_put(self, key: str, g: KernelGraph):
        memo = self._memo  # caller holds self._lock
        memo[key] = g
        memo.move_to_end(key)
        while len(memo) > self.config.memo:
            memo.popitem(last=False)

    def _bump(self, field: str, by=1):
        with self._lock:
            self.stats[field] += by

    def _build_one(self, inv, cap_warps: int, cap_instr: int) -> KernelGraph:
        key = kernel_graph_key(inv, cap_warps, cap_instr)
        t0 = time.perf_counter()
        try:
            with self._lock:
                g = self._memo.get(key)
                if g is not None:
                    self.stats["memo_hits"] += 1
                    return g
            store = self.store if self.config.cache else None
            if store is not None:
                existed = store.has_kernel(key)
                g = store.load_kernel(key)
                if g is not None:
                    with self._lock:
                        self.stats["store_hits"] += 1
                        self._memo_put(key, g)
                    return g
                if existed:  # present on disk but rejected -> corrupt entry
                    self._bump("corrupt")
            g = build_kernel_graph(inv.trace(cap_warps, cap_instr))
            self._bump("traced")
            if store is not None:
                store.save_kernel(key, g)
            with self._lock:
                self._memo_put(key, g)
            return g
        finally:
            self._bump("build_s", time.perf_counter() - t0)

    # -- program stream ------------------------------------------------------
    def iter_graphs(self, program, cap_warps: Optional[int] = None,
                    cap_instr: Optional[int] = None) -> Iterator[KernelGraph]:
        """Yield one graph per invocation, in program order.

        Draining the iterator to completion publishes the program's
        manifest to the GraphStore, marking the ingest as complete for
        `warm()` checks."""
        cap_warps, cap_instr = resolve_trace_caps(cap_warps, cap_instr,
                                                  program)
        kernels = list(program.kernels)
        self.stats["kernels"] += len(kernels)
        workers = max(0, int(self.config.workers))
        if workers == 0:
            for inv in kernels:
                t0 = time.perf_counter()
                g = self._build_one(inv, cap_warps, cap_instr)
                self.stats["wait_s"] += time.perf_counter() - t0
                yield g
        else:
            from concurrent.futures import ThreadPoolExecutor

            window = workers + max(1, int(self.config.depth))
            with ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix="ingest"
            ) as pool:
                pending: deque = deque()
                it = iter(kernels)
                for inv in it:
                    pending.append(
                        pool.submit(self._build_one, inv, cap_warps,
                                    cap_instr))
                    if len(pending) >= window:
                        break
                while pending:
                    t0 = time.perf_counter()
                    g = pending.popleft().result()  # FIFO: program order
                    self.stats["wait_s"] += time.perf_counter() - t0
                    nxt = next(it, None)
                    if nxt is not None:
                        pending.append(
                            pool.submit(self._build_one, nxt, cap_warps,
                                        cap_instr))
                    yield g
        if self.store is not None and self.config.cache and kernels:
            keys = [kernel_graph_key(k, cap_warps, cap_instr)
                    for k in kernels]
            if all(self.store.has_kernel(k) for k in keys):
                self.store.save_manifest(program, cap_warps, cap_instr, keys)

    def ingest(self, program, cap_warps: Optional[int] = None,
               cap_instr: Optional[int] = None) -> list[KernelGraph]:
        """Materialize every graph (benchmarks / small programs only —
        streaming consumers should use `iter_graphs`)."""
        return list(self.iter_graphs(program, cap_warps, cap_instr))
