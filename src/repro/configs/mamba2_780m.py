"""mamba2-780m [ssm] — attention-free SSD (state-space duality) model.

[arXiv:2405.21060; unverified].  48L, d_model=1536, attn-free, d_ff=0
(mamba blocks only), vocab=50280, ssm_state=128, expand=2, headdim=64
(-> d_inner=3072, 48 SSD heads), conv window 4.

Runs the long_500k shape (sub-quadratic; O(1)-state decode).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    tie_embeddings=True,
)
