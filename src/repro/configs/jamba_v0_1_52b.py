"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf].  32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=65536, MoE 16 experts top-2.  Period-8 block: attention at in-block
position 4 (1:7 attn:mamba), MoE FFN every other layer.

Adaptation note (DESIGN.md §3): Jamba v0.1 uses Mamba-1 mixers; our SSM layer
is the Mamba-2/SSD form (the TPU-native chunked formulation shared with
mamba2-780m).  State size kept at 128.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    block_size=8,
    attn_positions=(4,),
    moe_positions=(1, 3, 5, 7),
)
