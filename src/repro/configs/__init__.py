"""Assigned-architecture registry: ``--arch <id>`` resolves here.

One module per architecture (public config, with [source] notes inline).
``get_arch(id)`` returns the full published config; ``smoke_arch(id)`` returns
a reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses

from repro.config import ModelConfig
from repro.utils.registry import Registry

from repro.configs import (
    jamba_v0_1_52b,
    musicgen_medium,
    granite_3_2b,
    llama3_2_3b,
    qwen2_72b,
    yi_34b,
    mamba2_780m,
    grok_1_314b,
    dbrx_132b,
    paligemma_3b,
)

ARCHS: Registry[ModelConfig] = Registry("architecture")

for _mod in (
    jamba_v0_1_52b,
    musicgen_medium,
    granite_3_2b,
    llama3_2_3b,
    qwen2_72b,
    yi_34b,
    mamba2_780m,
    grok_1_314b,
    dbrx_132b,
    paligemma_3b,
):
    ARCHS.add(_mod.CONFIG.arch_id, _mod.CONFIG)


def get_arch(arch_id: str) -> ModelConfig:
    return ARCHS.get(arch_id)


def list_archs() -> list[str]:
    return ARCHS.names()


def smoke_arch(arch_id: str) -> ModelConfig:
    """Reduced same-family config: small widths, few experts, tiny vocab.

    Preserves the structural skeleton (block layout, mixer kinds, MoE top-k,
    GQA grouping, frontend) so smoke tests exercise the same code paths as the
    full config.
    """
    cfg = get_arch(arch_id)
    num_kv = min(cfg.num_kv_heads, 2) if cfg.num_heads else 0
    num_heads = 4 if cfg.num_heads else 0
    kw = dict(
        num_layers=cfg.block_size,  # one block
        d_model=64,
        num_heads=num_heads,
        num_kv_heads=max(1, num_kv) if num_heads else 0,
        head_dim=16 if num_heads else 0,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=32,
        attn_chunk=64,
        attn_chunk_threshold=128,
        loss_chunk=64,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        param_dtype="float32",
        compute_dtype="float32",
    )
    # keep MQA archs MQA
    if cfg.num_kv_heads == 1:
        kw["num_kv_heads"] = 1
    return dataclasses.replace(cfg, **kw)
