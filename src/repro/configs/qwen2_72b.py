"""qwen2-72b [dense] — large GQA transformer with QKV bias.

[arXiv:2407.10671; hf].  80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568,
vocab=152064, QKV bias, rope_theta=1e6.

Scale note: 72B params -> the train_4k dry-run uses bf16 optimizer moments
(TrainConfig.opt_dtype='bfloat16' in the launcher for >30B archs) to fit
HBM; recorded in EXPERIMENTS.md §Dry-run.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
