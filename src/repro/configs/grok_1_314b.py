"""grok-1-314b [moe] — 8-expert top-2 MoE transformer.

[hf:xai-org/grok-1; unverified].  64L, d_model=6144, 48 heads (GQA kv=8),
d_ff=32768 per expert, vocab=131072, MoE 8 experts top-2.

Scale note: 314B params.  Expert count (8) does not divide the model axis
(16), so the sharding rules shard expert d_ff over 'model' (TP-MoE) and the
expert stack over the FSDP axes; bf16 optimizer moments for the train cell.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    top_k=2,
)
