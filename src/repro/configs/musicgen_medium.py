"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf].  48L, d_model=1536, 24 heads (kv=24, i.e. MHA),
d_ff=6144, vocab=2048.  The EnCodec frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, S, d_model); the backbone consumes
them alongside token embeddings.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
)
