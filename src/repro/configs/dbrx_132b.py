"""dbrx-132b [moe] — fine-grained 16-expert top-4 MoE transformer.

[hf:databricks/dbrx-base; unverified].  40L, d_model=6144, 48 heads
(GQA kv=8), d_ff=10752 per expert, vocab=100352, MoE 16 experts top-4.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    top_k=4,
)
