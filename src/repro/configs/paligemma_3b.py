"""paligemma-3b [vlm] — SigLIP vision frontend + gemma decoder backbone.

[arXiv:2407.07726; hf].  Backbone: 18L, d_model=2048, 8 heads (GQA kv=1,
i.e. MQA), head_dim=256, d_ff=16384, vocab=257216.

The SigLIP frontend is a STUB: ``input_specs()`` provides 256 precomputed
patch embeddings (B, 256, d_model) that the backbone prepends to the token
embeddings (prefix-LM style; the dry-run subject is the transformer backbone).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="vision",
    frontend_tokens=256,
)
