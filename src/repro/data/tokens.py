"""Deterministic, seeded, host-sharded token pipeline.

Determinism is a fault-tolerance feature: batch b is a pure function of
(seed, step, host), so restart-from-checkpoint resumes the exact stream with
``skip(step)`` — no data replay bookkeeping, no inter-host coordination.

The synthetic distribution is a Zipf-over-vocab Markov chain (repeated
n-grams), which gives a learnable next-token structure for the example
training drivers without any external dataset.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 *, seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 frontend=None, d_model: int = 0, frontend_tokens: int = 0):
        assert batch_size % num_hosts == 0
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.local_batch = batch_size // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.step = 0
        self.frontend = frontend
        self.d_model = d_model
        self.frontend_tokens = frontend_tokens
        # fixed Markov transition: each token prefers a small successor set
        rng = np.random.default_rng(seed)
        self._succ = rng.integers(0, vocab_size, size=(vocab_size, 4))

    def skip(self, steps: int):
        """Fast-forward (checkpoint resume) — O(1), no data generated."""
        self.step = steps

    def _rng(self, step):
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.host_id
        )

    def next(self) -> dict:
        rng = self._rng(self.step)
        self.step += 1
        B, S = self.local_batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, B)
        choice = rng.integers(0, 4, (B, S))
        noise = rng.random((B, S)) < 0.1
        rand = rng.integers(0, self.vocab, (B, S))
        for t in range(S):
            nxt = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        if self.frontend == "vision":
            ft = self.frontend_tokens
            st = S - ft  # text portion; total model seq = ft + st = S
            out = {
                "tokens": toks[:, :st],
                "labels": np.concatenate(
                    [np.full((B, ft), -1, np.int32), toks[:, 1 : st + 1]],
                    axis=1,
                ),
                "frontend": rng.standard_normal((B, ft, self.d_model)).astype(
                    np.float32
                ),
            }
        else:
            out = {"tokens": toks[:, :S], "labels": toks[:, 1:].copy()}
            if self.frontend == "audio":
                out["frontend"] = rng.standard_normal(
                    (B, S, self.d_model)
                ).astype(np.float32)
        return out
