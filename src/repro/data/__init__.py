from repro.data.tokens import TokenStream
