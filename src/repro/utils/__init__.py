from repro.utils.registry import Registry
from repro.utils.trees import tree_size_bytes, tree_param_count
