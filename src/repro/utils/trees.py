"""Pytree utilities."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def tree_size_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_paths(tree) -> list[str]:
    """Flattened '/'-joined key paths for every leaf (used by sharding rules)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append(path_str(path))
    return out


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def map_with_paths(fn, tree):
    """tree_map where fn receives (path_str, leaf)."""
    return jax.tree_util.tree_map_with_path(lambda p, l: fn(path_str(p), l), tree)
