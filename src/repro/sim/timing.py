"""Stall-aware cycle-approximate GPU timing model (HyFiSS-flavored).

Interval model: per-SM issue throughput is bounded by warp-level parallelism
via Little's law (active_warps x ILP / weighted latency), and the kernel is
bounded by the max of compute issue, L2 and DRAM service times.  Cache hit
rates come from an analytic reuse/capacity model over the kernel's working
set and access pattern.  Deterministic in (KernelStats, HardwareConfig).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.hardware import HardwareConfig
from repro.tracing.isa import CLASS_IDS, INSTR_CLASSES
from repro.tracing.tracer import KernelStats

# per-class issue latencies (cycles) and throughput weights
CLASS_LATENCY = {
    "mem_load": 1.0, "mem_store": 1.0, "smem": 1.0, "fp": 1.0, "alu": 1.0,
    "sfu": 4.0, "tensor": 2.0, "control": 1.0, "barrier": 2.0, "shuffle": 1.0,
}
# execution-dependency latency per class (for Little's law)
CLASS_EXEC_LATENCY = {
    "mem_load": 1.0,  # replaced by cfg.mem_latency scaled by miss ratio
    "mem_store": 8.0, "smem": 25.0, "fp": 4.0, "alu": 4.0, "sfu": 16.0,
    "tensor": 16.0, "control": 6.0, "barrier": 25.0, "shuffle": 10.0,
}

COALESCE_FACTOR = {"coalesced": 1.0, "strided": 3.0, "random": 8.0}


@dataclass
class KernelMetrics:
    cycles: float
    time_s: float          # native execution time
    ipc: float             # per-SM instructions/cycle
    l1_hit: float
    l2_hit: float
    occupancy: float
    dram_bytes: float
    sim_time_s: float      # simulator wall time to model this kernel


def _occupancy(stats: KernelStats, hw: HardwareConfig):
    warps_per_cta = (stats.threads_per_cta + 31) // 32
    regs_per_cta = stats.regs_per_thread * stats.threads_per_cta
    lim_regs = max(1, hw.regs_per_sm // max(regs_per_cta, 1))
    lim_smem = max(1, hw.smem_per_sm // max(stats.smem_per_cta, 1)) if stats.smem_per_cta else 64
    lim_warps = max(1, hw.max_warps_per_sm // warps_per_cta)
    ctas_per_sm = min(lim_regs, lim_smem, lim_warps, 32)
    # can't exceed the grid itself spread over SMs
    ctas_per_sm = min(ctas_per_sm, max(1, int(np.ceil(stats.ctas / hw.num_sms))))
    active_warps = ctas_per_sm * warps_per_cta
    return min(active_warps, hw.max_warps_per_sm), ctas_per_sm


def _cache_hits(stats: KernelStats, hw: HardwareConfig, ctas_per_sm: int):
    """Analytic reuse/capacity model."""
    potential = max(0.0, 1.0 - 1.0 / stats.reuse_factor)
    # L1: per-SM slice of the working set must fit
    sms_used = min(hw.num_sms, max(stats.ctas, 1))
    ws_per_sm = stats.working_set / max(sms_used, 1) * max(ctas_per_sm, 1) ** 0.5
    l1_cap = min(1.0, (hw.l1_kb_per_sm * 1024.0) / max(ws_per_sm, 1.0))
    pattern_pen = {"coalesced": 1.0, "strided": 0.7, "random": 0.25}[stats.pattern]
    l1_hit = potential * l1_cap ** 0.5 * pattern_pen
    # L2: whole working set vs L2 capacity
    l2_cap = min(1.0, (hw.l2_mb * 1e6) / max(stats.working_set, 1.0))
    resid_potential = max(0.0, potential - l1_hit) + 0.3 * (1 - potential)
    l2_hit = min(0.95, resid_potential * l2_cap ** 0.5 + 0.15 * l2_cap)
    return float(np.clip(l1_hit, 0.0, 0.98)), float(np.clip(l2_hit, 0.0, 0.98))


def simulate_kernel(stats: KernelStats, hw: HardwareConfig) -> KernelMetrics:
    active_warps, ctas_per_sm = _occupancy(stats, hw)
    occupancy = active_warps / hw.max_warps_per_sm
    l1_hit, l2_hit = _cache_hits(stats, hw, ctas_per_sm)

    mix = stats.instr_mix  # (num_classes,)
    # effective average execution latency per instruction
    lat = 0.0
    for cls in INSTR_CLASSES:
        w = mix[CLASS_IDS[cls]]
        if cls == "mem_load":
            miss_lat = hw.mem_latency_cycles
            eff = 30.0 * l1_hit + miss_lat * (1 - l1_hit) * (0.4 * l2_hit + (1 - l2_hit))
            lat += w * eff
        else:
            lat += w * CLASS_EXEC_LATENCY[cls]
    lat = max(lat, 2.0)

    # issue cost per instruction (tensor/sfu lower throughput)
    issue_cost = sum(mix[CLASS_IDS[c]] * CLASS_LATENCY[c] for c in INSTR_CLASSES)

    # Little's law: sustainable IPC per SM
    wlp_ipc = active_warps * stats.ilp / lat
    peak_ipc = hw.schedulers_per_sm / max(issue_cost, 1e-6)
    div_pen = 1.0 - 0.5 * stats.divergence
    ipc = max(min(wlp_ipc, peak_ipc) * div_pen, 0.05)

    sms_used = min(hw.num_sms, max(stats.ctas, 1))
    instr_per_sm = stats.warp_instructions / sms_used
    compute_cycles = instr_per_sm / ipc

    # memory service times
    coal = COALESCE_FACTOR[stats.pattern]
    dram_bytes = stats.bytes_accessed * coal * (1 - l1_hit) * (1 - l2_hit)
    l2_bytes = stats.bytes_accessed * coal * (1 - l1_hit)
    dram_cycles = dram_bytes / hw.dram_gbps / 1e9 * hw.clock_ghz * 1e9
    l2_cycles = l2_bytes / hw.l2_gbps / 1e9 * hw.clock_ghz * 1e9

    cycles = max(compute_cycles, dram_cycles, l2_cycles) + 2000.0  # launch
    time_s = cycles / (hw.clock_ghz * 1e9)
    eff_ipc = instr_per_sm / cycles

    # simulator wall-time model (cycle-approximate simulators run ~1e5-1e6
    # warp-instructions/sec); constant per-kernel overhead for setup/teardown
    sim_time_s = stats.warp_instructions / 4.0e5 + 0.05
    return KernelMetrics(
        cycles=float(cycles), time_s=float(time_s), ipc=float(eff_ipc),
        l1_hit=l1_hit, l2_hit=l2_hit, occupancy=float(occupancy),
        dram_bytes=float(dram_bytes), sim_time_s=float(sim_time_s),
    )
