"""Stall-aware cycle-approximate GPU timing model (HyFiSS-flavored).

Interval model: per-SM issue throughput is bounded by warp-level parallelism
via Little's law (active_warps x ILP / weighted latency), and the kernel is
bounded by the max of compute issue, L2 and DRAM service times.  Cache hit
rates come from an analytic reuse/capacity model over the kernel's working
set and access pattern.  Deterministic in (KernelStats, HardwareConfig).

The model is evaluated structure-of-arrays: :func:`stack_stats` packs a
program's per-kernel :class:`KernelStats` into a :class:`StackedKernelStats`
and :func:`simulate_batch` times EVERY kernel in one vectorized numpy pass
(no per-kernel Python dispatch).  :func:`simulate_kernel` survives as a
single-kernel shim over the batch path; ``_simulate_kernel_scalar`` keeps
the original per-kernel arithmetic as the parity reference (tests pin
batch == scalar to float64 exactness).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.hardware import HardwareConfig
from repro.tracing.isa import CLASS_IDS, INSTR_CLASSES
from repro.tracing.tracer import KernelStats

# per-class issue latencies (cycles) and throughput weights
CLASS_LATENCY = {
    "mem_load": 1.0, "mem_store": 1.0, "smem": 1.0, "fp": 1.0, "alu": 1.0,
    "sfu": 4.0, "tensor": 2.0, "control": 1.0, "barrier": 2.0, "shuffle": 1.0,
}
# execution-dependency latency per class (for Little's law)
CLASS_EXEC_LATENCY = {
    "mem_load": 1.0,  # replaced by cfg.mem_latency scaled by miss ratio
    "mem_store": 8.0, "smem": 25.0, "fp": 4.0, "alu": 4.0, "sfu": 16.0,
    "tensor": 16.0, "control": 6.0, "barrier": 25.0, "shuffle": 10.0,
}

COALESCE_FACTOR = {"coalesced": 1.0, "strided": 3.0, "random": 8.0}

#: access-pattern string -> dense id for the SoA representation
PATTERNS = ("coalesced", "strided", "random")
PATTERN_IDS = {p: i for i, p in enumerate(PATTERNS)}
_COALESCE_BY_ID = np.array([COALESCE_FACTOR[p] for p in PATTERNS])
_L1_PATTERN_PEN = np.array([1.0, 0.7, 0.25])  # coalesced / strided / random


@dataclass
class KernelMetrics:
    cycles: float
    time_s: float          # native execution time
    ipc: float             # per-SM instructions/cycle
    l1_hit: float
    l2_hit: float
    occupancy: float
    dram_bytes: float
    sim_time_s: float      # simulator wall time to model this kernel


_METRIC_FIELDS = ("cycles", "time_s", "ipc", "l1_hit", "l2_hit", "occupancy",
                  "dram_bytes", "sim_time_s")


@dataclass
class BatchKernelMetrics:
    """Structure-of-arrays metrics for a whole program: every field is an
    (n,) float64 array.  Supports the sequence protocol (len / indexing /
    iteration yields :class:`KernelMetrics`) so per-kernel call sites keep
    working, while vectorized consumers (reconstruct / evaluate / speedup)
    read the arrays directly."""
    cycles: np.ndarray
    time_s: np.ndarray
    ipc: np.ndarray
    l1_hit: np.ndarray
    l2_hit: np.ndarray
    occupancy: np.ndarray
    dram_bytes: np.ndarray
    sim_time_s: np.ndarray

    def __len__(self) -> int:
        return len(self.cycles)

    def __getitem__(self, i):
        if isinstance(i, (int, np.integer)):
            return KernelMetrics(**{f: float(getattr(self, f)[i])
                                    for f in _METRIC_FIELDS})
        return BatchKernelMetrics(**{f: getattr(self, f)[i]
                                     for f in _METRIC_FIELDS})

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]

    def tolist(self) -> list[KernelMetrics]:
        return [self[i] for i in range(len(self))]

    @classmethod
    def from_list(cls, metrics) -> "BatchKernelMetrics":
        return cls(**{f: np.array([getattr(m, f) for m in metrics],
                                  np.float64) for f in _METRIC_FIELDS})


@dataclass
class StackedKernelStats:
    """SoA view over a list of :class:`KernelStats` (one row per kernel)."""
    warp_instructions: np.ndarray   # (n,) f64
    class_counts: np.ndarray        # (n, num_classes) f64
    bytes_accessed: np.ndarray      # (n,) f64
    working_set: np.ndarray         # (n,) f64
    reuse_factor: np.ndarray        # (n,) f64
    pattern_id: np.ndarray          # (n,) int
    ctas: np.ndarray                # (n,) int
    threads_per_cta: np.ndarray     # (n,) int
    regs_per_thread: np.ndarray     # (n,) int
    smem_per_cta: np.ndarray        # (n,) int
    ilp: np.ndarray                 # (n,) f64
    divergence: np.ndarray          # (n,) f64

    def __len__(self) -> int:
        return len(self.warp_instructions)


def stack_stats(stats: list) -> StackedKernelStats:
    """Pack per-kernel :class:`KernelStats` into the SoA form."""
    return StackedKernelStats(
        warp_instructions=np.array([s.warp_instructions for s in stats],
                                   np.float64),
        class_counts=np.stack([np.asarray(s.class_counts, np.float64)
                               for s in stats]) if stats
        else np.zeros((0, len(INSTR_CLASSES))),
        bytes_accessed=np.array([s.bytes_accessed for s in stats],
                                np.float64),
        working_set=np.array([s.working_set for s in stats], np.float64),
        reuse_factor=np.array([s.reuse_factor for s in stats], np.float64),
        pattern_id=np.array([PATTERN_IDS[s.pattern] for s in stats], int),
        ctas=np.array([s.ctas for s in stats], int),
        threads_per_cta=np.array([s.threads_per_cta for s in stats], int),
        regs_per_thread=np.array([s.regs_per_thread for s in stats], int),
        smem_per_cta=np.array([s.smem_per_cta for s in stats], int),
        ilp=np.array([s.ilp for s in stats], np.float64),
        divergence=np.array([s.divergence for s in stats], np.float64),
    )


def _occupancy_batch(st: StackedKernelStats, hw: HardwareConfig):
    warps_per_cta = (st.threads_per_cta + 31) // 32
    regs_per_cta = st.regs_per_thread * st.threads_per_cta
    lim_regs = np.maximum(1, hw.regs_per_sm // np.maximum(regs_per_cta, 1))
    lim_smem = np.where(
        st.smem_per_cta > 0,
        np.maximum(1, hw.smem_per_sm // np.maximum(st.smem_per_cta, 1)), 64)
    lim_warps = np.maximum(1, hw.max_warps_per_sm // warps_per_cta)
    ctas_per_sm = np.minimum(np.minimum(lim_regs, lim_smem),
                             np.minimum(lim_warps, 32))
    # can't exceed the grid itself spread over SMs
    grid_cap = np.maximum(1, np.ceil(st.ctas / hw.num_sms).astype(int))
    ctas_per_sm = np.minimum(ctas_per_sm, grid_cap)
    active_warps = ctas_per_sm * warps_per_cta
    return np.minimum(active_warps, hw.max_warps_per_sm), ctas_per_sm


def _cache_hits_batch(st: StackedKernelStats, hw: HardwareConfig,
                      ctas_per_sm: np.ndarray):
    """Analytic reuse/capacity model (vectorized)."""
    potential = np.maximum(0.0, 1.0 - 1.0 / st.reuse_factor)
    # L1: per-SM slice of the working set must fit
    sms_used = np.minimum(hw.num_sms, np.maximum(st.ctas, 1))
    ws_per_sm = (st.working_set / np.maximum(sms_used, 1)
                 * np.maximum(ctas_per_sm, 1) ** 0.5)
    l1_cap = np.minimum(1.0, (hw.l1_kb_per_sm * 1024.0)
                        / np.maximum(ws_per_sm, 1.0))
    pattern_pen = _L1_PATTERN_PEN[st.pattern_id]
    l1_hit = potential * l1_cap ** 0.5 * pattern_pen
    # L2: whole working set vs L2 capacity
    l2_cap = np.minimum(1.0, (hw.l2_mb * 1e6)
                        / np.maximum(st.working_set, 1.0))
    resid_potential = (np.maximum(0.0, potential - l1_hit)
                       + 0.3 * (1 - potential))
    l2_hit = np.minimum(0.95, resid_potential * l2_cap ** 0.5 + 0.15 * l2_cap)
    return np.clip(l1_hit, 0.0, 0.98), np.clip(l2_hit, 0.0, 0.98)


def simulate_batch(st: StackedKernelStats,
                   hw: HardwareConfig) -> BatchKernelMetrics:
    """Vectorized interval model: one numpy pass over every kernel of a
    program (same arithmetic, same accumulation order as the scalar
    reference — results are float64-identical)."""
    active_warps, ctas_per_sm = _occupancy_batch(st, hw)
    occupancy = active_warps / hw.max_warps_per_sm
    l1_hit, l2_hit = _cache_hits_batch(st, hw, ctas_per_sm)

    tot = np.maximum(st.class_counts.sum(axis=1), 1.0)
    mix = st.class_counts / tot[:, None]            # (n, num_classes)
    # effective average execution latency per instruction (class order
    # preserved so the accumulation matches the scalar loop bit-for-bit)
    lat = np.zeros(len(st))
    for cls in INSTR_CLASSES:
        w = mix[:, CLASS_IDS[cls]]
        if cls == "mem_load":
            miss_lat = hw.mem_latency_cycles
            eff = (30.0 * l1_hit
                   + miss_lat * (1 - l1_hit) * (0.4 * l2_hit + (1 - l2_hit)))
            lat += w * eff
        else:
            lat += w * CLASS_EXEC_LATENCY[cls]
    lat = np.maximum(lat, 2.0)

    # issue cost per instruction (tensor/sfu lower throughput)
    issue_cost = np.zeros(len(st))
    for cls in INSTR_CLASSES:
        issue_cost += mix[:, CLASS_IDS[cls]] * CLASS_LATENCY[cls]

    # Little's law: sustainable IPC per SM
    wlp_ipc = active_warps * st.ilp / lat
    peak_ipc = hw.schedulers_per_sm / np.maximum(issue_cost, 1e-6)
    div_pen = 1.0 - 0.5 * st.divergence
    ipc = np.maximum(np.minimum(wlp_ipc, peak_ipc) * div_pen, 0.05)

    sms_used = np.minimum(hw.num_sms, np.maximum(st.ctas, 1))
    instr_per_sm = st.warp_instructions / sms_used
    compute_cycles = instr_per_sm / ipc

    # memory service times
    coal = _COALESCE_BY_ID[st.pattern_id]
    dram_bytes = st.bytes_accessed * coal * (1 - l1_hit) * (1 - l2_hit)
    l2_bytes = st.bytes_accessed * coal * (1 - l1_hit)
    dram_cycles = dram_bytes / hw.dram_gbps / 1e9 * hw.clock_ghz * 1e9
    l2_cycles = l2_bytes / hw.l2_gbps / 1e9 * hw.clock_ghz * 1e9

    cycles = np.maximum(np.maximum(compute_cycles, dram_cycles),
                        l2_cycles) + 2000.0  # launch
    time_s = cycles / (hw.clock_ghz * 1e9)
    eff_ipc = instr_per_sm / cycles

    # simulator wall-time model (cycle-approximate simulators run ~1e5-1e6
    # warp-instructions/sec); constant per-kernel overhead for setup/teardown
    sim_time_s = st.warp_instructions / 4.0e5 + 0.05
    return BatchKernelMetrics(
        cycles=cycles, time_s=time_s, ipc=eff_ipc, l1_hit=l1_hit,
        l2_hit=l2_hit, occupancy=occupancy.astype(np.float64),
        dram_bytes=dram_bytes, sim_time_s=sim_time_s,
    )


def simulate_kernel(stats: KernelStats, hw: HardwareConfig) -> KernelMetrics:
    """Single-kernel shim over :func:`simulate_batch` (kept for per-kernel
    call sites; program-level paths should stack + batch)."""
    return simulate_batch(stack_stats([stats]), hw)[0]


def _simulate_kernel_scalar(stats: KernelStats,
                            hw: HardwareConfig) -> KernelMetrics:
    """The original per-kernel arithmetic, kept verbatim as the parity
    reference for `simulate_batch` (tests/test_plan_engine.py)."""
    active_warps, ctas_per_sm = _occupancy(stats, hw)
    occupancy = active_warps / hw.max_warps_per_sm
    l1_hit, l2_hit = _cache_hits(stats, hw, ctas_per_sm)

    mix = stats.instr_mix  # (num_classes,)
    # effective average execution latency per instruction
    lat = 0.0
    for cls in INSTR_CLASSES:
        w = mix[CLASS_IDS[cls]]
        if cls == "mem_load":
            miss_lat = hw.mem_latency_cycles
            eff = 30.0 * l1_hit + miss_lat * (1 - l1_hit) * (0.4 * l2_hit + (1 - l2_hit))
            lat += w * eff
        else:
            lat += w * CLASS_EXEC_LATENCY[cls]
    lat = max(lat, 2.0)

    # issue cost per instruction (tensor/sfu lower throughput)
    issue_cost = sum(mix[CLASS_IDS[c]] * CLASS_LATENCY[c] for c in INSTR_CLASSES)

    # Little's law: sustainable IPC per SM
    wlp_ipc = active_warps * stats.ilp / lat
    peak_ipc = hw.schedulers_per_sm / max(issue_cost, 1e-6)
    div_pen = 1.0 - 0.5 * stats.divergence
    ipc = max(min(wlp_ipc, peak_ipc) * div_pen, 0.05)

    sms_used = min(hw.num_sms, max(stats.ctas, 1))
    instr_per_sm = stats.warp_instructions / sms_used
    compute_cycles = instr_per_sm / ipc

    # memory service times
    coal = COALESCE_FACTOR[stats.pattern]
    dram_bytes = stats.bytes_accessed * coal * (1 - l1_hit) * (1 - l2_hit)
    l2_bytes = stats.bytes_accessed * coal * (1 - l1_hit)
    dram_cycles = dram_bytes / hw.dram_gbps / 1e9 * hw.clock_ghz * 1e9
    l2_cycles = l2_bytes / hw.l2_gbps / 1e9 * hw.clock_ghz * 1e9

    cycles = max(compute_cycles, dram_cycles, l2_cycles) + 2000.0  # launch
    time_s = cycles / (hw.clock_ghz * 1e9)
    eff_ipc = instr_per_sm / cycles

    # simulator wall-time model (cycle-approximate simulators run ~1e5-1e6
    # warp-instructions/sec); constant per-kernel overhead for setup/teardown
    sim_time_s = stats.warp_instructions / 4.0e5 + 0.05
    return KernelMetrics(
        cycles=float(cycles), time_s=float(time_s), ipc=float(eff_ipc),
        l1_hit=float(l1_hit), l2_hit=float(l2_hit),
        occupancy=float(occupancy), dram_bytes=float(dram_bytes),
        sim_time_s=float(sim_time_s),
    )


def _occupancy(stats: KernelStats, hw: HardwareConfig):
    warps_per_cta = (stats.threads_per_cta + 31) // 32
    regs_per_cta = stats.regs_per_thread * stats.threads_per_cta
    lim_regs = max(1, hw.regs_per_sm // max(regs_per_cta, 1))
    lim_smem = max(1, hw.smem_per_sm // max(stats.smem_per_cta, 1)) if stats.smem_per_cta else 64
    lim_warps = max(1, hw.max_warps_per_sm // warps_per_cta)
    ctas_per_sm = min(lim_regs, lim_smem, lim_warps, 32)
    # can't exceed the grid itself spread over SMs
    ctas_per_sm = min(ctas_per_sm, max(1, int(np.ceil(stats.ctas / hw.num_sms))))
    active_warps = ctas_per_sm * warps_per_cta
    return min(active_warps, hw.max_warps_per_sm), ctas_per_sm


def _cache_hits(stats: KernelStats, hw: HardwareConfig, ctas_per_sm: int):
    """Analytic reuse/capacity model."""
    potential = max(0.0, 1.0 - 1.0 / stats.reuse_factor)
    # L1: per-SM slice of the working set must fit
    sms_used = min(hw.num_sms, max(stats.ctas, 1))
    ws_per_sm = stats.working_set / max(sms_used, 1) * max(ctas_per_sm, 1) ** 0.5
    l1_cap = min(1.0, (hw.l1_kb_per_sm * 1024.0) / max(ws_per_sm, 1.0))
    pattern_pen = {"coalesced": 1.0, "strided": 0.7, "random": 0.25}[stats.pattern]
    l1_hit = potential * l1_cap ** 0.5 * pattern_pen
    # L2: whole working set vs L2 capacity
    l2_cap = min(1.0, (hw.l2_mb * 1e6) / max(stats.working_set, 1.0))
    resid_potential = max(0.0, potential - l1_hit) + 0.3 * (1 - potential)
    l2_hit = min(0.95, resid_potential * l2_cap ** 0.5 + 0.15 * l2_cap)
    return float(np.clip(l1_hit, 0.0, 0.98)), float(np.clip(l2_hit, 0.0, 0.98))
