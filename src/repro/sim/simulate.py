"""Full vs sampled simulation: run the timing model over a program, apply a
SamplingPlan (clusters + representatives + weights), reconstruct full-workload
metrics, and compute the paper's error (eq. 5) and speedup (eq. 6).

The program path is vectorized end to end: :func:`simulate_program` stacks
the per-kernel stats (SoA) and times the WHOLE program in one
:func:`~repro.sim.timing.simulate_batch` pass, returning a
:class:`~repro.sim.timing.BatchKernelMetrics` (sequence-compatible with the
old ``list[KernelMetrics]``).  Reconstruction / speedup / wall-time read the
metric arrays directly instead of looping kernels.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.sim.hardware import PLATFORMS
from repro.sim.timing import BatchKernelMetrics, simulate_batch, stack_stats
from repro.tracing.programs import Program

METRIC_NAMES = ("cycles", "ipc", "l1_hit", "l2_hit", "occupancy")


@dataclass
class SamplingPlan:
    """labels[i] = cluster of invocation i; reps[c] = representative
    invocation indices (usually one; STEM+ROOT may pick several)."""
    labels: np.ndarray               # (n_kernels,) int
    reps: dict[int, list[int]]       # cluster -> kernel indices
    method: str = ""
    extra: dict = field(default_factory=dict)

    @property
    def num_clusters(self) -> int:
        return len(self.reps)

    def rep_indices(self) -> list[int]:
        out = set()
        for v in self.reps.values():
            out.update(v)
        return sorted(out)


def simulate_program(program: Program,
                     platform: str = "P1") -> BatchKernelMetrics:
    """Time every kernel of `program` in ONE vectorized pass.  The result
    supports the old list protocol (len / [i] / iteration) on top of the
    SoA metric arrays."""
    hw = PLATFORMS[platform]
    return simulate_batch(
        stack_stats([k.stats(platform) for k in program.kernels]), hw)


def _metric_arrays(metrics):
    """(cycles, per-metric arrays) for list-of-KernelMetrics or
    BatchKernelMetrics inputs — the batch form is a zero-copy view."""
    if not isinstance(metrics, BatchKernelMetrics):
        metrics = BatchKernelMetrics.from_list(list(metrics))
    return metrics


def _weighted_metrics(metrics, weights, indices=None):
    """Aggregate: cycles = weighted sum; rates/IPC = cycle-weighted mean."""
    m = _metric_arrays(metrics)
    cycles = m.cycles if indices is None else m.cycles[indices]
    w = np.asarray(weights, np.float64)
    tot_cycles = float(np.sum(cycles * w))
    cw = cycles * w
    denom = max(tot_cycles, 1e-12)
    out = {"cycles": tot_cycles}
    for name in ("ipc", "l1_hit", "l2_hit", "occupancy"):
        vals = getattr(m, name)
        if indices is not None:
            vals = vals[indices]
        out[name] = float(np.sum(vals * cw) / denom)
    return out


def reconstruct(plan: SamplingPlan, metrics):
    """Sampled estimate: each cluster contributes the mean of its
    representatives' metrics scaled by the cluster's invocation count."""
    reps, weights = [], []
    for c, rep_idx in plan.reps.items():
        count = int(np.sum(plan.labels == c))
        share = count / len(rep_idx)
        for r in rep_idx:
            reps.append(r)
            weights.append(share)
    return _weighted_metrics(metrics, weights, indices=np.asarray(reps, int))


def full_metrics(metrics):
    return _weighted_metrics(metrics, np.ones(len(metrics)))


def sampling_error(plan: SamplingPlan, metrics, name="cycles"):
    """Paper eq. 5: |full - sampled| / full * 100%."""
    full = full_metrics(metrics)[name]
    sampled = reconstruct(plan, metrics)[name]
    return abs(full - sampled) / max(abs(full), 1e-12) * 100.0


def speedup(plan: SamplingPlan, metrics) -> float:
    """Paper eq. 6: full kernel execution time / representative exec time."""
    m = _metric_arrays(metrics)
    # sequential sums (not np pairwise) keep the golden fixture bit-stable
    full_t = sum(m.time_s.tolist())
    rep_t = sum(m.time_s[plan.rep_indices()].tolist())
    return full_t / max(rep_t, 1e-12)


def sim_wall_time(metrics, indices=None) -> float:
    """End-to-end simulator wall-time (§5.4) for all or selected kernels."""
    m = _metric_arrays(metrics)
    if indices is None:
        return sum(m.sim_time_s.tolist())
    return sum(m.sim_time_s[np.asarray(list(indices), int)].tolist())
