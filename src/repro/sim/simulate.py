"""Full vs sampled simulation: run the timing model over a program, apply a
SamplingPlan (clusters + representatives + weights), reconstruct full-workload
metrics, and compute the paper's error (eq. 5) and speedup (eq. 6)."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.sim.hardware import PLATFORMS, HardwareConfig
from repro.sim.timing import KernelMetrics, simulate_kernel
from repro.tracing.programs import Program

METRIC_NAMES = ("cycles", "ipc", "l1_hit", "l2_hit", "occupancy")


@dataclass
class SamplingPlan:
    """labels[i] = cluster of invocation i; reps[c] = representative
    invocation indices (usually one; STEM+ROOT may pick several)."""
    labels: np.ndarray               # (n_kernels,) int
    reps: dict[int, list[int]]       # cluster -> kernel indices
    method: str = ""
    extra: dict = field(default_factory=dict)

    @property
    def num_clusters(self) -> int:
        return len(self.reps)

    def rep_indices(self) -> list[int]:
        out = set()
        for v in self.reps.values():
            out.update(v)
        return sorted(out)


def simulate_program(program: Program, platform: str = "P1") -> list[KernelMetrics]:
    hw = PLATFORMS[platform]
    return [simulate_kernel(k.stats(platform), hw) for k in program.kernels]


def _weighted_metrics(metrics, weights):
    """Aggregate: cycles = weighted sum; rates/IPC = cycle-weighted mean."""
    cycles = np.array([m.cycles for m in metrics])
    w = np.asarray(weights, np.float64)
    tot_cycles = float(np.sum(cycles * w))
    cw = cycles * w
    denom = max(tot_cycles, 1e-12)
    out = {"cycles": tot_cycles}
    for name in ("ipc", "l1_hit", "l2_hit", "occupancy"):
        vals = np.array([getattr(m, name) for m in metrics])
        out[name] = float(np.sum(vals * cw) / denom)
    return out


def reconstruct(plan: SamplingPlan, metrics: list[KernelMetrics]):
    """Sampled estimate: each cluster contributes the mean of its
    representatives' metrics scaled by the cluster's invocation count."""
    reps, weights = [], []
    for c, rep_idx in plan.reps.items():
        count = int(np.sum(plan.labels == c))
        share = count / len(rep_idx)
        for r in rep_idx:
            reps.append(metrics[r])
            weights.append(share)
    return _weighted_metrics(reps, weights)


def full_metrics(metrics: list[KernelMetrics]):
    return _weighted_metrics(metrics, np.ones(len(metrics)))


def sampling_error(plan: SamplingPlan, metrics: list[KernelMetrics], name="cycles"):
    """Paper eq. 5: |full - sampled| / full * 100%."""
    full = full_metrics(metrics)[name]
    sampled = reconstruct(plan, metrics)[name]
    return abs(full - sampled) / max(abs(full), 1e-12) * 100.0


def speedup(plan: SamplingPlan, metrics: list[KernelMetrics]) -> float:
    """Paper eq. 6: full kernel execution time / representative exec time."""
    full_t = sum(m.time_s for m in metrics)
    rep_t = sum(metrics[i].time_s for i in plan.rep_indices())
    return full_t / max(rep_t, 1e-12)


def sim_wall_time(metrics: list[KernelMetrics], indices=None) -> float:
    """End-to-end simulator wall-time (§5.4) for all or selected kernels."""
    if indices is None:
        return sum(m.sim_time_s for m in metrics)
    return sum(metrics[i].sim_time_s for i in indices)
