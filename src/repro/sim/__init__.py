from repro.sim.hardware import PLATFORMS, HardwareConfig
from repro.sim.timing import (
    BatchKernelMetrics, KernelMetrics, StackedKernelStats, simulate_batch,
    simulate_kernel, stack_stats,
)
from repro.sim.simulate import (
    simulate_program, reconstruct, sampling_error, speedup, SamplingPlan,
)
