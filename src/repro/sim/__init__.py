from repro.sim.hardware import PLATFORMS, HardwareConfig
from repro.sim.timing import simulate_kernel, KernelMetrics
from repro.sim.simulate import (
    simulate_program, reconstruct, sampling_error, speedup, SamplingPlan,
)
