"""Hardware configs for the three evaluation platforms (paper Table 2) and
the TPU-v5e roofline constants used by the dry-run analysis."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareConfig:
    name: str
    arch: str
    num_sms: int
    clock_ghz: float
    max_warps_per_sm: int
    schedulers_per_sm: int
    regs_per_sm: int
    smem_per_sm: int          # bytes
    l1_kb_per_sm: int
    l2_mb: float
    dram_gbps: float
    l2_gbps: float
    fp32_tflops: float
    tensor_tflops: float
    mem_latency_cycles: int


# RTX 2080 Ti (Turing TU102)
P1 = HardwareConfig(
    name="P1", arch="Turing", num_sms=68, clock_ghz=1.545,
    max_warps_per_sm=32, schedulers_per_sm=4, regs_per_sm=65536,
    smem_per_sm=65536, l1_kb_per_sm=64, l2_mb=5.5, dram_gbps=616.0,
    l2_gbps=1800.0, fp32_tflops=13.4, tensor_tflops=107.0,
    mem_latency_cycles=420,
)

# RTX 3080 Ti (Ampere GA102)
P2 = HardwareConfig(
    name="P2", arch="Ampere", num_sms=80, clock_ghz=1.665,
    max_warps_per_sm=48, schedulers_per_sm=4, regs_per_sm=65536,
    smem_per_sm=102400, l1_kb_per_sm=128, l2_mb=6.0, dram_gbps=912.0,
    l2_gbps=2400.0, fp32_tflops=34.1, tensor_tflops=136.0,
    mem_latency_cycles=400,
)

# RTX 4090 (Ada AD102)
P3 = HardwareConfig(
    name="P3", arch="Ada", num_sms=128, clock_ghz=2.52,
    max_warps_per_sm=48, schedulers_per_sm=4, regs_per_sm=65536,
    smem_per_sm=102400, l1_kb_per_sm=128, l2_mb=72.0, dram_gbps=1008.0,
    l2_gbps=5000.0, fp32_tflops=82.6, tensor_tflops=330.0,
    mem_latency_cycles=380,
)

PLATFORMS = {"P1": P1, "P2": P2, "P3": P3}

# TPU v5e single-chip roofline constants (dry-run analysis; see §Roofline)
TPU_V5E = {
    "peak_bf16_flops": 197e12,   # FLOP/s per chip
    "hbm_gbps": 819e9,           # bytes/s per chip
    "ici_link_gbps": 50e9,       # bytes/s per link
}
