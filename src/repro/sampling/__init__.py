"""repro.sampling — the one public API for sampled GPU simulation.

    from repro.sampling import get_method, evaluate, ArtifactStore

    method = get_method("gcl", steps=60)
    plan, artifacts = method.run(program, store=ArtifactStore("runs/a"))
    result = evaluate(plan, program, platform="P1")

Methods (``available_methods()``): ``gcl``, ``pka``, ``sieve``,
``stem_root`` — all implementing the :class:`SamplingMethod` protocol.
The full method x program x platform grid: ``python -m repro.launch.sample``.

NOTE: method classes register lazily on first ``get_method`` /
``available_methods`` call, so importing this package never pulls in the
trainer stack.
"""

from repro.sampling.base import (
    Artifacts, SamplingMethod, config_hash, plan_from_labels,
)
from repro.sampling.engine import PlanEngine, PlanEngineConfig, PlanRequest
from repro.sampling.evaluate import EvalResult, evaluate, evaluate_metrics
from repro.sampling.registry import (
    SAMPLING_METHODS, available_methods, get_method, register_method,
)
from repro.sampling.store import (
    ArtifactStore, flatten_tree, program_fingerprint, unflatten_tree,
)

__all__ = [
    "Artifacts", "ArtifactStore", "EvalResult", "PlanEngine",
    "PlanEngineConfig", "PlanRequest", "SAMPLING_METHODS", "SamplingMethod",
    "available_methods", "config_hash", "evaluate", "evaluate_metrics",
    "flatten_tree", "get_method", "plan_from_labels", "program_fingerprint",
    "register_method", "unflatten_tree",
]
