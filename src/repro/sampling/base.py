"""The one public surface for sampled-simulation methods.

A *sampling method* turns a traced :class:`~repro.tracing.programs.Program`
into a :class:`~repro.sim.simulate.SamplingPlan` in two stages:

    prepare(program) -> Artifacts     # the expensive, cacheable stage
    plan(program, artifacts) -> SamplingPlan

``prepare`` owns everything worth persisting (trained RGCN params, kernel
embeddings, profiled features, per-stage timings); ``plan`` is cheap and
deterministic given the artifacts.  The split is what lets the
:class:`~repro.sampling.store.ArtifactStore` replay a trained GCL encoder
across programs and runs instead of refitting per call site.

All four paper methods (gcl / pka / sieve / stem_root) implement this
protocol and are registered under string keys in
:mod:`repro.sampling.registry`.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program


def plan_from_labels(
    labels: np.ndarray,
    seqs: np.ndarray,
    method: str,
    extra: Optional[dict] = None,
    *,
    priority: Optional[np.ndarray] = None,
    rep_selector: Optional[Callable[[int, np.ndarray], list]] = None,
) -> SamplingPlan:
    """Shared representative selection for every clustering-based method.

    Default rule (GCL-Sampler, PKA): representative = first invocation
    (min ``seq``) in each cluster.

    ``priority``: per-invocation score; candidates are restricted to the
    cluster members attaining the maximum priority, then min ``seq`` breaks
    ties (Sieve's "first kernel with the max CTA count" rule).

    ``rep_selector(cluster, members) -> list[int]``: full override returning
    one or MORE representative indices for a cluster (STEM+ROOT's
    error-model sample sizes).  Mutually exclusive with ``priority``.
    """
    if priority is not None and rep_selector is not None:
        raise ValueError("pass either priority or rep_selector, not both")
    labels = np.asarray(labels)
    seqs = np.asarray(seqs)
    reps: dict[int, list[int]] = {}
    for c in np.unique(labels):
        members = np.nonzero(labels == c)[0]
        if rep_selector is not None:
            chosen = rep_selector(int(c), members)
            reps[int(c)] = sorted({int(r) for r in chosen})
            continue
        if priority is not None:
            p = np.asarray(priority)[members]
            members = members[p == p.max()]
        first = members[np.argmin(seqs[members])]
        reps[int(c)] = [int(first)]
    return SamplingPlan(labels=labels, reps=reps, method=method,
                        extra=extra or {})


@dataclass
class Artifacts:
    """Everything a method's ``prepare`` stage produced, in storable form.

    ``payload`` values are numpy arrays or pytrees of arrays (nested
    dict/list, e.g. trained RGCN params); ``meta`` must be JSON-safe.
    ``provenance`` disambiguates artifacts whose content depends on state
    beyond (config, program) — e.g. a GCL encoder trained on a DIFFERENT
    program and reused here.
    """
    method: str                      # registry id, e.g. "gcl"
    program: str                     # program fingerprint (see store)
    config_hash: str                 # hash of the method's config()
    payload: dict[str, Any] = field(default_factory=dict)
    timings: dict[str, float] = field(default_factory=dict)
    meta: dict[str, Any] = field(default_factory=dict)
    provenance: str = ""             # extra key component (see docstring)

    @property
    def key(self) -> str:
        """Content key: same method + config + program (+ provenance) ->
        same artifacts."""
        base = f"{self.config_hash}-{self.program}"
        return f"{base}-{self.provenance}" if self.provenance else base


class SamplingMethod(abc.ABC):
    """Protocol every sampling method implements (see module docstring).

    Subclasses set ``id`` (registry key) and ``display_name`` (the
    ``SamplingPlan.method`` string used in tables/plots).
    """

    id: str = ""
    display_name: str = ""

    @abc.abstractmethod
    def config(self) -> dict:
        """JSON-safe configuration; hashed into the artifact content key."""

    @abc.abstractmethod
    def prepare(self, program: Program) -> Artifacts:
        """The expensive stage: train / profile / featurize."""

    @abc.abstractmethod
    def plan(self, program: Program, artifacts: Artifacts) -> SamplingPlan:
        """Cheap + deterministic given ``artifacts``."""

    def artifact_key(self, program: Program) -> str:
        """The content key ``prepare(program)`` would produce — the single
        source of truth shared by ``run``'s lookup and ``Artifacts.key``.
        Methods whose artifacts depend on instance state (e.g. a reused
        encoder) must override this consistently with their ``prepare``."""
        from repro.sampling.store import program_fingerprint

        return f"{config_hash(self.config())}-{program_fingerprint(program)}"

    def attach_store(self, store) -> None:
        """Hook: called by ``run`` before prepare/load so methods with
        store-adjacent state (e.g. the GCL method's fit checkpoints under
        ``store.checkpoint_dir``) can pick the store up.  Default: nothing."""

    def run_prepare(self, program: Program, store=None) -> Artifacts:
        """The prepare half of ``run``: load-or-prepare(-and-save) through
        the store.  Exposed so grid drivers can prepare a whole program axis
        first and then serve every plan through ``plan_batch``."""
        artifacts = None
        if store is not None:
            self.attach_store(store)
            artifacts = store.load(self.id, self.artifact_key(program))
        if artifacts is None:
            artifacts = self.prepare(program)
            if store is not None:
                store.save(artifacts)
        else:
            self.adopt(artifacts)
        return artifacts

    def plan_request(self, program: Program, artifacts: Artifacts):
        """Engine-backed methods return the
        :class:`~repro.sampling.engine.PlanRequest` their ``plan`` would
        serve through the PlanEngine (embeddings + seqs + seed), letting a
        server coalesce requests across methods and tenants
        (``repro.serving.PlanService.submit_program``).  Methods that do
        not plan through the engine return None — servers fall back to
        their own ``plan``.  Default: None."""
        return None

    def plan_batch(self, items: list) -> list[SamplingPlan]:
        """Plan MANY prepared programs: ``items`` is [(program, artifacts)].

        Default: a plain loop over ``plan``.  Engine-backed methods (gcl,
        pka) override this to serve every program of a batch through one
        compiled multi-K sweep dispatch per size bucket
        (:class:`repro.sampling.engine.PlanEngine`).
        """
        return [self.plan(p, a) for p, a in items]

    def run(self, program: Program, store=None) -> tuple[SamplingPlan, Artifacts]:
        """prepare + plan, with content-hash reuse through ``store``.

        When a store is given and already holds artifacts for
        (method, config, program), ``prepare`` is skipped entirely and the
        stored artifacts are replayed.
        """
        artifacts = self.run_prepare(program, store)
        return self.plan(program, artifacts), artifacts

    def adopt(self, artifacts: Artifacts) -> None:
        """Hook: absorb replayed artifacts into instance state (e.g. the GCL
        method picks up trained encoder params).  Default: nothing."""


def config_hash(cfg: dict) -> str:
    """Stable short hash of a JSON-safe config dict."""
    import hashlib
    import json

    blob = json.dumps(cfg, sort_keys=True, default=str).encode()
    return hashlib.sha1(blob).hexdigest()[:12]
