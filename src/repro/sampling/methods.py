"""The four paper methods behind one protocol.

| id          | display name | prepare() artifact            | plan()                          |
|-------------|--------------|-------------------------------|---------------------------------|
| `gcl`       | GCL-Sampler  | trained RGCN params + z_k     | silhouette K-Means on z_k       |
| `pka`       | PKA          | 12-d profiled feature matrix  | silhouette K-Means on features  |
| `sieve`     | Sieve        | name/CoV strata + CTA counts  | max-CTA representative          |
| `stem_root` | STEM+ROOT    | profiled execution times      | STEM strata + ROOT multi-rep    |

Every method is constructible through ``repro.sampling.get_method(id,
**overrides)`` with identical `prepare`/`plan`/`run` signatures, making the
full method x program x platform sweep (``repro.launch.sample``) a plain
loop over registry ids.
"""

from __future__ import annotations

import time
from dataclasses import asdict, replace
from typing import Optional

import numpy as np

from repro.core.baselines.pka import pka_features
from repro.core.baselines.sieve import sieve_partition
from repro.core.baselines.stem_root import stem_root_partition, stem_root_times
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.sampling.base import (
    Artifacts, SamplingMethod, config_hash, plan_from_labels,
)
from repro.sampling.engine import PlanEngine, PlanRequest
from repro.sampling.registry import register_method
from repro.sampling.store import program_fingerprint
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program


def _seqs(program: Program) -> np.ndarray:
    return np.array([k.seq for k in program.kernels])


def _artifacts(method: SamplingMethod, program: Program, payload: dict,
               timings: dict, meta: Optional[dict] = None,
               provenance: str = "") -> Artifacts:
    return Artifacts(
        method=method.id, program=program_fingerprint(program),
        config_hash=config_hash(method.config()), payload=payload,
        timings=timings, meta=meta or {}, provenance=provenance,
    )


@register_method
class GCLMethod(SamplingMethod):
    """The paper's contribution, wrapping :class:`GCLSampler`.

    The trained encoder lives on the instance: the first ``prepare`` fits
    the RGCN contrastively, subsequent programs (or replayed artifacts via
    ``adopt``) reuse it and only pay for graph building + embedding.
    """

    id = "gcl"
    display_name = "GCL-Sampler"

    #: auto-streaming threshold: programs with at least this many
    #: invocations use the bounded-memory trace->graph path by default
    STREAM_THRESHOLD = 512

    def __init__(self, cfg: Optional[GCLSamplerConfig] = None, *,
                 steps: Optional[int] = None,
                 batch_size: Optional[int] = None,
                 cap_instr: Optional[int] = None,
                 k_max: Optional[int] = None,
                 seed: Optional[int] = None,
                 streaming: Optional[bool] = None,
                 engine: Optional[str] = None,
                 checkpoint_every: Optional[int] = None,
                 ingest_workers: Optional[int] = None,
                 graph_cache: Optional[bool] = None,
                 resume: bool = True):
        #: None = auto (stream iff len(program) >= STREAM_THRESHOLD);
        #: True/False force the streaming / materialized ingestion path
        self.streaming = streaming
        #: False = ignore existing fit checkpoints and refit from scratch
        self.resume = resume
        cfg = cfg or GCLSamplerConfig()
        train_kw = {k: v for k, v in
                    [("steps", steps), ("batch_size", batch_size),
                     ("seed", seed), ("engine", engine),
                     ("checkpoint_every", checkpoint_every)]
                    if v is not None}
        cfg_kw = {k: v for k, v in
                  [("cap_instr", cap_instr), ("k_max", k_max)]
                  if v is not None}
        if train_kw:
            cfg_kw["train"] = replace(cfg.train, **train_kw)
        ingest_kw = {k: v for k, v in
                     [("workers", ingest_workers), ("cache", graph_cache)]
                     if v is not None}
        if ingest_kw:
            cfg_kw["ingest"] = replace(cfg.ingest, **ingest_kw)
        self.cfg = replace(cfg, **cfg_kw) if cfg_kw else cfg
        self.sampler = GCLSampler(self.cfg)
        self._trained_on: Optional[str] = None  # program fp of the fit
        self._store = None                      # set by attach_store / run

    def config(self) -> dict:
        """JSON-safe config hashed into the artifact content key.  The
        checkpoint cadence and the ingest config are EXCLUDED: cadence
        changes when snapshots are taken and ingest changes how fast graphs
        arrive (workers/depth/cache) — neither ever changes the fitted
        encoder or the embeddings (ingestion is bit-identical at any worker
        count), so runs differing only there must share artifacts."""
        cfg = asdict(self.cfg)
        cfg["train"].pop("checkpoint_every", None)
        cfg.pop("ingest", None)
        return dict(cfg, streaming=self.streaming)

    def attach_store(self, store) -> None:
        """Remember the store so ``prepare`` can place fit checkpoints under
        ``store.checkpoint_dir`` (an interrupted prepare then resumes from
        the last snapshot instead of refitting), and back the sampler's
        ingestion engine with the run's on-disk graph cache — warm runs
        (and `PlanService.submit_program` tenants) skip tracing entirely."""
        self._store = store
        if self.cfg.ingest.cache and hasattr(store, "graph_store"):
            self.sampler.attach_graph_store(store.graph_store())

    def _fit_checkpoint_dir(self, program: Program) -> Optional[str]:
        if self._store is None or self.cfg.train.checkpoint_every <= 0:
            return None
        # artifact_key is the single source of truth for content keys; a
        # fit only happens with no adopted encoder, so the provenance
        # suffix is empty and this equals the artifact's own key
        return self._store.checkpoint_dir(self.id, self.artifact_key(program))

    def _use_streaming(self, program: Program) -> bool:
        if self.streaming is not None:
            return self.streaming
        return len(program) >= self.STREAM_THRESHOLD

    def _encoder_provenance(self, program_fp: str) -> str:
        """Non-empty when the encoder was fit on a DIFFERENT program: the
        artifact content then depends on that program too, so it must be
        part of the content key (keeps replayed results independent of
        store history / grid order)."""
        if self._trained_on and self._trained_on != program_fp:
            return f"enc-{self._trained_on}"
        return ""

    def artifact_key(self, program: Program) -> str:
        base = super().artifact_key(program)
        prov = self._encoder_provenance(program_fingerprint(program))
        return f"{base}-{prov}" if prov else base

    def prepare(self, program: Program) -> Artifacts:
        stream = self._use_streaming(program)
        t0 = time.time()
        graphs = None if stream else self.sampler.build_graphs(program)
        t1 = time.time()
        meta: dict = {"streaming": stream}
        if self.sampler.params is None:
            ckpt = dict(checkpoint_dir=self._fit_checkpoint_dir(program),
                        resume=self.resume)
            if stream:
                # n_total makes the training subset identical to the
                # materialized path: streaming changes memory, not results
                info = self.sampler.train_stream(
                    self.sampler.iter_graphs(program),
                    n_total=len(program), **ckpt)
            else:
                info = self.sampler.train(graphs, **ckpt)
            self._trained_on = program_fingerprint(program)
            meta["train"] = {
                k: info[k] for k in
                ("val_loss", "val_acc", "trunc_nodes", "step_compiles",
                 "engine", "resumed_from", "checkpoint_saves", "host_syncs")
                if k in info
            }
        else:
            meta["encoder_reused"] = True
        meta["trained_on"] = self._trained_on
        t2 = time.time()
        if stream:
            # second lazy pass: graphs flow through pack/encode one
            # micro-batch at a time (bounded peak residency; the
            # content-hash cache de-dupes repeated invocations)
            emb = self.sampler.embed_stream(self.sampler.iter_graphs(program))
            meta["embed"] = {
                k: v for k, v in self.sampler.trainer.embed_stats.items()
                if k in ("cache_hits", "encoded", "microbatches",
                         "peak_resident_graphs", "peak_resident_nodes")
            }
        else:
            emb = self.sampler.embed(graphs)
        t3 = time.time()
        ing = self.sampler.ingest
        meta["ingest"] = {
            "workers": self.cfg.ingest.workers,
            "kernels": ing.stats["kernels"], "traced": ing.stats["traced"],
            "memo_hits": ing.stats["memo_hits"],
            "store_hits": ing.stats["store_hits"],
            "corrupt": ing.stats["corrupt"],
            "overlap_fraction": round(ing.overlap_fraction, 4),
        }
        payload = {
            "params": self.sampler.params,
            "embeddings": emb,
            "seqs": _seqs(program),
        }
        timings = {"graphs_s": t1 - t0, "train_s": t2 - t1,
                   "embed_s": t3 - t2}
        return _artifacts(
            self, program, payload, timings, meta,
            provenance=self._encoder_provenance(program_fingerprint(program)))

    def plan(self, program: Program, artifacts: Artifacts) -> SamplingPlan:
        return self.plan_batch([(program, artifacts)])[0]

    def plan_request(self, program: Program,
                     artifacts: Artifacts) -> PlanRequest:
        """The engine-ready request ``plan`` serves (repro.serving): same
        embeddings/seqs/seed, artifact timings + meta riding in ``extra``."""
        return PlanRequest(
            np.asarray(artifacts.payload["embeddings"]),
            np.asarray(artifacts.payload["seqs"]), self.display_name,
            seed=self.cfg.train.seed,
            extra=dict(artifacts.meta, timings=dict(artifacts.timings)))

    def plan_batch(self, items: list) -> list[SamplingPlan]:
        """All programs of the batch through the compiled planning engine:
        one multi-K sweep dispatch per embedding-size bucket, `use_pallas`
        threaded through from the RGCN config."""
        t0 = time.time()
        engine = self.sampler.plan_engine()
        plans = engine.plan_many([
            PlanRequest(np.asarray(a.payload["embeddings"]),
                        np.asarray(a.payload["seqs"]), self.display_name)
            for _, a in items])
        cluster_s = (time.time() - t0) / max(len(items), 1)
        for (_, artifacts), plan in zip(items, plans):
            plan.extra["timings"] = dict(artifacts.timings,
                                         cluster_s=cluster_s)
            plan.extra.update(artifacts.meta)
        return plans

    def adopt(self, artifacts: Artifacts) -> None:
        params = artifacts.payload.get("params")
        if params is not None:
            self.sampler.params = params
            self._trained_on = artifacts.meta.get("trained_on",
                                                  artifacts.program)


@register_method
class PKAMethod(SamplingMethod):
    id = "pka"
    display_name = "PKA"

    def __init__(self, platform: str = "P1", k_max: int = 48, seed: int = 0):
        self.platform = platform
        self.k_max = k_max
        self.seed = seed

    def config(self) -> dict:
        return {"platform": self.platform, "k_max": self.k_max,
                "seed": self.seed}

    def prepare(self, program: Program) -> Artifacts:
        t0 = time.time()
        x = pka_features(program, self.platform)
        return _artifacts(self, program, {"features": x},
                          {"features_s": time.time() - t0})

    def plan(self, program: Program, artifacts: Artifacts) -> SamplingPlan:
        return self.plan_batch([(program, artifacts)])[0]

    def plan_request(self, program: Program,
                     artifacts: Artifacts) -> PlanRequest:
        return PlanRequest(
            np.asarray(artifacts.payload["features"]), _seqs(program),
            self.display_name, seed=self.seed,
            extra={"timings": dict(artifacts.timings)})

    def plan_batch(self, items: list) -> list[SamplingPlan]:
        t0 = time.time()
        engine = PlanEngine(k_max=self.k_max, seed=self.seed)
        plans = engine.plan_many([
            PlanRequest(np.asarray(a.payload["features"]), _seqs(p),
                        self.display_name)
            for p, a in items])
        cluster_s = (time.time() - t0) / max(len(items), 1)
        for (_, artifacts), plan in zip(items, plans):
            plan.extra["timings"] = dict(artifacts.timings,
                                         cluster_s=cluster_s)
        return plans


@register_method
class SieveMethod(SamplingMethod):
    id = "sieve"
    display_name = "Sieve"

    def __init__(self, platform: str = "P1"):
        self.platform = platform

    def config(self) -> dict:
        return {"platform": self.platform}

    def prepare(self, program: Program) -> Artifacts:
        t0 = time.time()
        labels, ctas = sieve_partition(program, self.platform)
        return _artifacts(self, program, {"labels": labels, "priority": ctas},
                          {"partition_s": time.time() - t0})

    def plan(self, program: Program, artifacts: Artifacts) -> SamplingPlan:
        plan = plan_from_labels(
            np.asarray(artifacts.payload["labels"]), _seqs(program),
            self.display_name,
            priority=np.asarray(artifacts.payload["priority"]))
        plan.extra["timings"] = dict(artifacts.timings)
        return plan


@register_method
class StemRootMethod(SamplingMethod):
    id = "stem_root"
    display_name = "STEM+ROOT"

    def __init__(self, platform: str = "P1", eps: float = 0.25):
        self.platform = platform
        self.eps = eps

    def config(self) -> dict:
        return {"platform": self.platform, "eps": self.eps}

    def prepare(self, program: Program) -> Artifacts:
        t0 = time.time()
        times = stem_root_times(program, self.platform)
        return _artifacts(self, program, {"times": times},
                          {"profile_s": time.time() - t0})

    def plan(self, program: Program, artifacts: Artifacts) -> SamplingPlan:
        names = [k.name for k in program.kernels]
        labels, rep_selector = stem_root_partition(
            np.asarray(artifacts.payload["times"]), names, self.eps)
        plan = plan_from_labels(labels, _seqs(program), self.display_name,
                                rep_selector=rep_selector)
        plan.extra["timings"] = dict(artifacts.timings)
        return plan
