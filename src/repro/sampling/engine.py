"""PlanEngine — batched, compiled plan serving for every clustering method.

The paper's serving path (§3.4: embeddings -> silhouette K-Means ->
representatives) used to run one program at a time through a host-bound
Python loop over candidate Ks.  The engine instead:

- buckets plan requests by embedding-matrix size (PR 1-style power-of-two
  points buckets, exact feature dim) so nearby program sizes share one
  executable;
- dispatches MANY programs per compiled K-sweep
  (:func:`repro.core.clustering.sweep_cluster_stack`): all candidate Ks of
  all programs in a bucket chunk evaluated in a single device trace;
- falls back to the same host paths as the sequential reference for
  trivial/tiny programs, so results are identical request-for-request.

Executables are cached process-wide in :mod:`repro.core.clustering`
(`ENGINE_STATS`), so a PlanEngine is cheap to construct — methods make one
per plan call with their own (k_max, seed, use_pallas) and still share
compiled sweeps.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import numpy as np

from repro.core.clustering import (
    bucket_points, engine_stats, select_k_and_cluster, sweep_cluster_stack,
)
from repro.sampling.base import plan_from_labels
from repro.sim.simulate import SamplingPlan


@dataclass(frozen=True)
class PlanEngineConfig:
    """Clustering knobs (mirrors `select_k_and_cluster`) + engine policy."""
    k_max: int = 48
    seed: int = 0
    sil_floor: float = 0.20
    tie_tol: float = 0.02
    tiny_n: int = 4
    sil_cap: int = 1200
    iters: int = 50
    use_pallas: bool = False     # fused kmeans_assign / silhouette kernels
    init: str = "host"           # 'host' numpy kmeans++ | 'device' fold-in
    engine: str = "sweep"        # 'sweep' | 'sequential' (parity reference)
    max_batch: int = 8           # programs per compiled dispatch


@dataclass
class PlanRequest:
    """One program's plan inputs: kernel embeddings + invocation seqs."""
    embeddings: np.ndarray
    seqs: np.ndarray
    method: str = ""
    seed: Optional[int] = None   # overrides the engine seed per request
    extra: dict = field(default_factory=dict)


class PlanEngine:
    def __init__(self, cfg: Optional[PlanEngineConfig] = None, **overrides):
        cfg = cfg or PlanEngineConfig()
        self.cfg = replace(cfg, **overrides) if overrides else cfg
        #: per-instance serving counters (process-wide compile counters
        #: live in repro.core.clustering.ENGINE_STATS)
        self.stats = {"programs": 0, "dispatches": 0, "bucket_hist": {}}

    # -- clustering ---------------------------------------------------------
    def _cluster_kwargs(self) -> dict:
        c = self.cfg
        return dict(k_max=c.k_max, sil_floor=c.sil_floor, tie_tol=c.tie_tol,
                    tiny_n=c.tiny_n, sil_cap=c.sil_cap, iters=c.iters,
                    use_pallas=c.use_pallas, init=c.init)

    def cluster_many(self, embs: list, seeds: Optional[list] = None):
        """Cluster many programs' embeddings; returns aligned
        [(labels, info)].  Requests are grouped by (points-bucket, dim) —
        the sweep's OWN padding unit, so grouped programs share both the
        executable and the padded shape — and chunked to `max_batch`
        programs per compiled dispatch."""
        seeds = ([self.cfg.seed] * len(embs) if seeds is None
                 else [self.cfg.seed if s is None else s for s in seeds])
        out: list = [None] * len(embs)
        if self.cfg.engine == "sequential":
            for i, x in enumerate(embs):
                out[i] = select_k_and_cluster(
                    np.asarray(x, np.float32), seed=seeds[i],
                    **self._cluster_kwargs())
            self.stats["programs"] += len(embs)
            self.stats["dispatches"] += len(embs)
            return out

        groups: dict[tuple, list[int]] = {}
        for i, x in enumerate(embs):
            x = np.asarray(x)
            d = x.shape[1] if x.ndim == 2 else 0
            key = (bucket_points(len(x)), d)
            groups.setdefault(key, []).append(i)
        # use_pallas sweeps stay unbatched: pallas_call inside vmap leans on
        # batching rules we don't exercise elsewhere — the cached executable
        # is still shared across programs
        cap = 1 if self.cfg.use_pallas else max(1, self.cfg.max_batch)
        for key, idxs in sorted(groups.items()):
            hist = self.stats["bucket_hist"]
            hist[str(key)] = hist.get(str(key), 0) + len(idxs)
            for lo in range(0, len(idxs), cap):
                chunk = idxs[lo:lo + cap]
                res = sweep_cluster_stack(
                    [np.asarray(embs[i], np.float32) for i in chunk],
                    seed=[seeds[i] for i in chunk],
                    **self._cluster_kwargs())
                for i, r in zip(chunk, res):
                    out[i] = r
                self.stats["dispatches"] += 1
        self.stats["programs"] += len(embs)
        return out

    def cluster(self, emb: np.ndarray, seed: Optional[int] = None):
        return self.cluster_many([emb], [seed])[0]

    # -- plans --------------------------------------------------------------
    def plan_many(self, requests: list[PlanRequest]) -> list[SamplingPlan]:
        """Serve MANY programs' SamplingPlans per compiled dispatch."""
        results = self.cluster_many([r.embeddings for r in requests],
                                    [r.seed for r in requests])
        plans = []
        for req, (labels, info) in zip(requests, results):
            extra = dict(info, **req.extra)
            plans.append(plan_from_labels(labels, req.seqs, req.method,
                                          extra=extra))
        return plans

    def plan(self, embeddings: np.ndarray, seqs: np.ndarray, method: str = "",
             seed: Optional[int] = None, extra: Optional[dict] = None
             ) -> SamplingPlan:
        return self.plan_many([PlanRequest(embeddings, seqs, method,
                                           seed=seed, extra=extra or {})])[0]

    def engine_stats(self) -> dict:
        """Instance counters + the process-wide compile counters (the
        process-wide dispatch counter keeps its own key so it never shadows
        this instance's)."""
        g = engine_stats()
        return dict(self.stats, builds=g["builds"],
                    cache_entries=g["cache_entries"],
                    process_dispatches=g["dispatches"])
