"""PlanEngine — batched, compiled plan serving for every clustering method.

The paper's serving path (§3.4: embeddings -> silhouette K-Means ->
representatives) used to run one program at a time through a host-bound
Python loop over candidate Ks.  The engine instead:

- buckets plan requests by embedding-matrix size (PR 1-style power-of-two
  points buckets, exact feature dim) so nearby program sizes share one
  executable;
- dispatches MANY programs per compiled K-sweep
  (:func:`repro.core.clustering.sweep_cluster_stack`): all candidate Ks of
  all programs in a bucket chunk evaluated in a single device trace;
- falls back to the same host paths as the sequential reference for
  trivial/tiny programs, so results are identical request-for-request.

Executables are cached process-wide in :mod:`repro.core.clustering`
(`ENGINE_STATS`), so a PlanEngine is cheap to construct — methods make one
per plan call with their own (k_max, seed, use_pallas) and still share
compiled sweeps.

Serving hooks (DESIGN.md §9; consumed by :mod:`repro.serving`):

- :meth:`PlanEngine.warmup` pre-builds the executables for an expected
  bucket set, taking cold-start compiles off the serving path;
- ``cluster_many(..., on_chunk=...)`` surfaces results per dispatched
  chunk, which ``plan_many`` uses to overlap host-side plan building with
  the next chunk's device dispatch;
- ``errors="isolate"`` turns a poison request into an Exception entry in
  the result list instead of killing the whole batch;
- ``record_timings`` stamps per-request dispatch telemetry into the plan
  ``extra`` so a server can account batch occupancy and service time.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.clustering import (
    bucket_batch, bucket_points, engine_stats, select_k_and_cluster,
    sweep_cluster_stack, warm_sweep,
)
from repro.distributed.fault import DeviceLost
from repro.sampling.base import plan_from_labels
from repro.sim.simulate import SamplingPlan


@dataclass(frozen=True)
class PlanEngineConfig:
    """Clustering knobs (mirrors `select_k_and_cluster`) + engine policy."""
    k_max: int = 48
    seed: int = 0
    sil_floor: float = 0.20
    tie_tol: float = 0.02
    tiny_n: int = 4
    sil_cap: int = 1200
    iters: int = 50
    use_pallas: bool = False     # fused kmeans_assign / silhouette kernels
    init: str = "host"           # 'host' numpy kmeans++ | 'device' fold-in
    engine: str = "sweep"        # 'sweep' | 'sequential' (parity reference)
    max_batch: int = 8           # programs per compiled dispatch PER DEVICE
    record_timings: bool = False  # stamp per-request dispatch telemetry
    overlap_plan_build: bool = True  # build plans while the next chunk runs
    #: program-axis device count for sharded dispatches: one dispatch then
    #: serves data_devices x max_batch programs.  0 = every device the
    #: backend exposes; 1 = single-device (the pre-scale-out behavior)
    data_devices: int = 0


@dataclass
class PlanRequest:
    """One program's plan inputs: kernel embeddings + invocation seqs."""
    embeddings: np.ndarray
    seqs: np.ndarray
    method: str = ""
    seed: Optional[int] = None   # overrides the engine seed per request
    extra: dict = field(default_factory=dict)


def normalize_embeddings(x) -> np.ndarray:
    """Engine-wide input normalization: float32, 2-D.  1-D vectors are a
    single scalar feature per point -> (n, 1); scalars/ragged inputs raise
    the numpy conversion error (isolated per request under
    ``errors="isolate"``)."""
    x = np.asarray(x, np.float32)
    if x.ndim == 1:
        x = x[:, None]
    if x.ndim != 2:
        raise ValueError(f"embeddings must be (n, d) or (n,), got {x.shape}")
    return x


def bucket_key(x) -> tuple[int, int]:
    """The ``(points-bucket, dim)`` grouping key for one request — the
    sweep's own padding unit, shared by PlanEngine and the serving
    batcher so both agree on which requests coalesce."""
    x = normalize_embeddings(x)
    return (bucket_points(len(x)), x.shape[1])


class PlanEngine:
    def __init__(self, cfg: Optional[PlanEngineConfig] = None, **overrides):
        cfg = cfg or PlanEngineConfig()
        self.cfg = replace(cfg, **overrides) if overrides else cfg
        #: per-instance serving counters (process-wide compile counters
        #: live in repro.core.clustering.ENGINE_STATS)
        self.stats = self._fresh_stats()
        #: program-axis shard width for sweep dispatches.  Starts at the
        #: configured device count and only ever SHRINKS (halves) when a
        #: dispatch raises DeviceLost — degrade, don't abort.
        self._data_shards = max(1, self.cfg.data_devices or jax.device_count())
        #: scale-out fault injection point: called before every compiled
        #: dispatch; raise DeviceLost from it to exercise the degradation
        #: path (halve shards, retry the same chunk)
        self.fault_hook: Optional[Callable[[], None]] = None

    @staticmethod
    def _fresh_stats() -> dict:
        return {"programs": 0, "dispatches": 0, "errors": 0,
                "warmed_executables": 0, "degraded_dispatches": 0,
                "bucket_hist": []}

    def reset_stats(self) -> None:
        """Zero the INSTANCE counters (long-lived servers window their
        telemetry with this).  Process-wide compile counters — shared by
        every engine — stay put; see
        :func:`repro.core.clustering.reset_engine_stats`."""
        self.stats = self._fresh_stats()

    def _bump_bucket(self, key: tuple[int, int], n: int) -> None:
        """bucket_hist entries are structured
        ``{"points_bucket": p, "dim": d, "count": n}`` (JSON-ready — no
        stringified tuple keys)."""
        for entry in self.stats["bucket_hist"]:
            if (entry["points_bucket"], entry["dim"]) == key:
                entry["count"] += n
                return
        self.stats["bucket_hist"].append(
            {"points_bucket": key[0], "dim": key[1], "count": n})

    # -- warm pool -----------------------------------------------------------
    def warmup(self, buckets, batch_sizes: Optional[list] = None) -> int:
        """Pre-build the compiled sweeps for an expected bucket set, taking
        cold-start compiles OFF the serving path.

        ``buckets``: iterable of ``(points, dim)`` pairs or
        ``{"points_bucket": p, "dim": d}`` dicts; points are rounded up to
        their power-of-two bucket.  ``batch_sizes`` defaults to every
        power-of-two chunk size the engine can dispatch (1..max_batch;
        just 1 under ``use_pallas``, which never batches).  Returns the
        number of NEW executables built — 0 means the pool was already
        warm."""
        c = self.cfg
        if batch_sizes is None:
            if c.use_pallas:
                batch_sizes = [1]
            else:
                batch_sizes, b = [], 1
                while b <= bucket_batch(max(1, c.max_batch)):
                    batch_sizes.append(b)
                    b <<= 1
        built = 0
        for bucket in buckets:
            if isinstance(bucket, dict):
                points, dim = bucket["points_bucket"], bucket["dim"]
            else:
                points, dim = bucket
            for b in batch_sizes:
                built += warm_sweep(
                    int(b), int(points), int(dim), k_max=c.k_max,
                    iters=c.iters, use_pallas=c.use_pallas, init=c.init,
                    data_shards=self._data_shards)
        self.stats["warmed_executables"] += built
        return built

    # -- clustering ---------------------------------------------------------
    def _cluster_kwargs(self) -> dict:
        c = self.cfg
        return dict(k_max=c.k_max, sil_floor=c.sil_floor, tie_tol=c.tie_tol,
                    tiny_n=c.tiny_n, sil_cap=c.sil_cap, iters=c.iters,
                    use_pallas=c.use_pallas, init=c.init)

    def _dispatch_chunk(self, xs: list, seeds: list):
        """One compiled sweep dispatch, with scale-out degradation: a
        DeviceLost — raised by the injected ``fault_hook`` or the sharded
        dispatch itself — halves the program-axis shard width and retries
        the SAME chunk, so a lost/straggling participant shrinks
        throughput instead of dropping requests.  Requests are only at a
        chunk boundary here (nothing is half-served), matching the
        training engine's checkpoint-boundary contract."""
        while True:
            try:
                if self.fault_hook is not None:
                    self.fault_hook()
                return sweep_cluster_stack(
                    xs, seed=seeds, data_shards=self._data_shards,
                    **self._cluster_kwargs())
            except DeviceLost:
                if self._data_shards <= 1:
                    raise
                self._data_shards //= 2
                self.stats["degraded_dispatches"] += 1

    def _stamp(self, results: list, key, chunk: int, dispatch_s: float):
        """record_timings hook: dispatch telemetry on every info dict (flows
        into plan.extra), so a server can account occupancy + service."""
        for r in results:
            if isinstance(r, Exception):
                continue
            r[1]["serve"] = {
                "points_bucket": key[0], "dim": key[1], "batch": chunk,
                "dispatch_s": dispatch_s,
            }

    def cluster_many(self, embs: list, seeds: Optional[list] = None,
                     errors: str = "raise",
                     on_chunk: Optional[Callable] = None):
        """Cluster many programs' embeddings; returns aligned
        [(labels, info)].  Requests are grouped by (points-bucket, dim) —
        the sweep's OWN padding unit, so grouped programs share both the
        executable and the padded shape — and chunked to `max_batch`
        programs per compiled dispatch.

        ``errors="isolate"``: a failing request becomes an Exception entry
        (the chunk retries its siblings one-by-one through the sequential
        reference, so one poison request never drops a batch).
        ``on_chunk(indices, results)`` fires after every dispatched chunk —
        the overlap hook ``plan_many`` builds plans on."""
        if errors not in ("raise", "isolate"):
            raise ValueError(f"errors must be 'raise'|'isolate': {errors!r}")
        out: list = [None] * len(embs)
        if not embs:
            return out
        seeds = ([self.cfg.seed] * len(embs) if seeds is None
                 else [self.cfg.seed if s is None else s for s in seeds])
        norm: list = [None] * len(embs)
        for i, x in enumerate(embs):
            try:
                norm[i] = normalize_embeddings(x)
            except Exception as e:
                if errors == "raise":
                    raise
                out[i] = e
                self.stats["errors"] += 1
        live = [i for i in range(len(embs)) if norm[i] is not None]

        if self.cfg.engine == "sequential":
            for i in live:
                t0 = time.perf_counter()
                try:
                    res = select_k_and_cluster(norm[i], seed=seeds[i],
                                               **self._cluster_kwargs())
                except Exception as e:
                    if errors == "raise":
                        raise
                    res = e
                    self.stats["errors"] += 1
                if self.cfg.record_timings:
                    self._stamp([res], bucket_key(norm[i]), 1,
                                time.perf_counter() - t0)
                out[i] = res
                self.stats["dispatches"] += 1
                if on_chunk is not None:
                    on_chunk([i], [res])
            self.stats["programs"] += len(embs)
            return out

        groups: dict[tuple, list[int]] = {}
        for i in live:
            groups.setdefault(
                (bucket_points(len(norm[i])), norm[i].shape[1]), []).append(i)
        # use_pallas sweeps stay unbatched: pallas_call inside vmap leans on
        # batching rules we don't exercise elsewhere — the cached executable
        # is still shared across programs.  Sharded dispatches scale the cap
        # by the mesh width: one dispatch serves data_shards x max_batch
        # programs, each device sweeping its own max_batch slice.
        cap = (1 if self.cfg.use_pallas
               else max(1, self.cfg.max_batch) * max(1, self._data_shards))
        for key, idxs in sorted(groups.items()):
            self._bump_bucket(key, len(idxs))
            for lo in range(0, len(idxs), cap):
                chunk = idxs[lo:lo + cap]
                t0 = time.perf_counter()
                try:
                    res = self._dispatch_chunk(
                        [norm[i] for i in chunk],
                        [seeds[i] for i in chunk])
                except Exception:
                    if errors == "raise":
                        raise
                    # err-isolated dispatch: retry one-by-one through the
                    # sequential reference so siblings still get served
                    res = []
                    for i in chunk:
                        try:
                            res.append(select_k_and_cluster(
                                norm[i], seed=seeds[i],
                                **self._cluster_kwargs()))
                        except Exception as e:
                            res.append(e)
                            self.stats["errors"] += 1
                if self.cfg.record_timings:
                    self._stamp(res, key, len(chunk),
                                time.perf_counter() - t0)
                for i, r in zip(chunk, res):
                    out[i] = r
                self.stats["dispatches"] += 1
                if on_chunk is not None:
                    on_chunk(chunk, res)
        self.stats["programs"] += len(embs)
        return out

    def cluster(self, emb: np.ndarray, seed: Optional[int] = None):
        return self.cluster_many([emb], [seed])[0]

    # -- plans --------------------------------------------------------------
    def plan_many(self, requests: list[PlanRequest],
                  errors: str = "raise") -> list:
        """Serve MANY programs' SamplingPlans per compiled dispatch.

        Host-side plan building (`plan_from_labels`) is OVERLAPPED with the
        next chunk's device dispatch on a worker thread
        (``cfg.overlap_plan_build``) — the representative scan for chunk i
        runs while chunk i+1 is on the device, so the dispatch queue never
        blocks on it.  With ``errors="isolate"`` failed requests come back
        as Exception entries, aligned with their request."""
        if not requests:
            return []
        plans: list = [None] * len(requests)

        def build(idxs, results):
            for i, r in zip(idxs, results):
                if isinstance(r, Exception):
                    plans[i] = r
                    continue
                labels, info = r
                req = requests[i]
                try:
                    plans[i] = plan_from_labels(
                        labels, req.seqs, req.method,
                        extra=dict(info, **req.extra))
                except Exception as e:
                    if errors == "raise":
                        raise
                    self.stats["errors"] += 1
                    plans[i] = e

        embs = [r.embeddings for r in requests]
        seeds = [r.seed for r in requests]
        if self.cfg.overlap_plan_build:
            with ThreadPoolExecutor(max_workers=1) as pool:
                futs = []
                results = self.cluster_many(
                    embs, seeds, errors=errors,
                    on_chunk=lambda idxs, res: futs.append(
                        pool.submit(build, idxs, res)))
                for f in futs:
                    f.result()
            # normalization failures never reach a chunk — pick the
            # isolated Exception entries up from the aligned result list
            for i, r in enumerate(results):
                if plans[i] is None:
                    build([i], [r])
        else:
            results = self.cluster_many(embs, seeds, errors=errors)
            build(range(len(requests)), results)
        return plans

    def plan(self, embeddings: np.ndarray, seqs: np.ndarray, method: str = "",
             seed: Optional[int] = None, extra: Optional[dict] = None
             ) -> SamplingPlan:
        return self.plan_many([PlanRequest(embeddings, seqs, method,
                                           seed=seed, extra=extra or {})])[0]

    def engine_stats(self) -> dict:
        """Instance counters + the process-wide compile counters (the
        process-wide dispatch counter keeps its own key so it never shadows
        this instance's)."""
        g = engine_stats()
        return dict(self.stats, builds=g["builds"],
                    cache_entries=g["cache_entries"],
                    process_dispatches=g["dispatches"],
                    data_shards=self._data_shards)
