"""Single evaluation harness for every sampling method.

Owns the full-vs-sampled comparison that callers used to re-derive by hand
from :mod:`repro.sim.simulate` primitives: weighted reconstruction, the
paper's error (eq. 5) over every metric, kernel-time speedup (eq. 6), and
simulator wall-time reduction (§5.4) — one call, one result object,
JSON-ready.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field

from repro.sim.simulate import (
    METRIC_NAMES, SamplingPlan, _metric_arrays, full_metrics, reconstruct,
    sim_wall_time, simulate_program,
)
from repro.tracing.programs import Program


@dataclass
class EvalResult:
    method: str                      # display name (plan.method)
    program: str
    platform: str
    num_kernels: int
    num_clusters: int
    num_reps: int
    error_pct: dict[str, float]      # eq. 5 per metric (cycles, ipc, ...)
    speedup: float                   # eq. 6 (kernel execution time)
    sim_time_full_s: float           # §5.4 simulator wall time
    sim_time_sampled_s: float
    full: dict[str, float]           # reconstructed full-workload metrics
    sampled: dict[str, float]
    timings: dict[str, float] = field(default_factory=dict)

    @property
    def sim_speedup(self) -> float:
        return self.sim_time_full_s / max(self.sim_time_sampled_s, 1e-12)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["sim_speedup"] = self.sim_speedup
        return d


def evaluate_metrics(plan: SamplingPlan, metrics,
                     program: str = "", platform: str = "") -> EvalResult:
    """Evaluate a plan against already-simulated per-kernel metrics
    (``BatchKernelMetrics`` from the vectorized path, or a legacy
    ``list[KernelMetrics]``)."""
    m = _metric_arrays(metrics)
    full = full_metrics(m)
    sampled = reconstruct(plan, m)
    reps = plan.rep_indices()
    error = {
        name: abs(full[name] - sampled[name]) / max(abs(full[name]), 1e-12)
        * 100.0
        for name in METRIC_NAMES
    }
    # sequential sums (not np pairwise) keep the golden fixture bit-stable
    full_t = sum(m.time_s.tolist())
    rep_t = sum(m.time_s[reps].tolist())
    return EvalResult(
        method=plan.method, program=program, platform=platform,
        num_kernels=len(metrics), num_clusters=plan.num_clusters,
        num_reps=len(reps), error_pct=error,
        speedup=full_t / max(rep_t, 1e-12),
        sim_time_full_s=sim_wall_time(metrics),
        sim_time_sampled_s=sim_wall_time(metrics, reps),
        full=full, sampled=sampled,
        timings=dict(plan.extra.get("timings", {})),
    )


def evaluate(plan: SamplingPlan, program: Program,
             platform: str = "P1") -> EvalResult:
    """Simulate `program` on `platform` and evaluate `plan` against it."""
    metrics = simulate_program(program, platform)
    return evaluate_metrics(plan, metrics, program=program.name,
                            platform=platform)
