"""String-keyed registry of sampling methods (gcl / pka / sieve / stem_root).

Built on :class:`repro.utils.registry.Registry`.  Method classes register
themselves in :mod:`repro.sampling.methods`, which is imported lazily here
so that core modules can depend on :mod:`repro.sampling.base` without a
circular import.
"""

from __future__ import annotations

from typing import Type

from repro.sampling.base import SamplingMethod
from repro.utils.registry import Registry

SAMPLING_METHODS: Registry = Registry("sampling method")


def register_method(cls: Type[SamplingMethod]) -> Type[SamplingMethod]:
    """Class decorator: register under the class's ``id``."""
    if not cls.id:
        raise ValueError(f"{cls.__name__} must set a non-empty `id`")
    SAMPLING_METHODS.add(cls.id, cls)
    return cls


def _ensure_loaded() -> None:
    import repro.sampling.methods  # noqa: F401  (registration side effect)


def available_methods() -> list[str]:
    _ensure_loaded()
    return SAMPLING_METHODS.names()


def get_method(name: str, **kwargs) -> SamplingMethod:
    """Instantiate a registered method: ``get_method("gcl", steps=40)``.

    kwargs are forwarded to the method class constructor; every class
    accepts keyword-only overrides of its defaults.
    """
    _ensure_loaded()
    cls = SAMPLING_METHODS.get(name)
    return cls(**kwargs)
