"""Content-addressed artifact + plan store for the sampling API.

Layout (one directory per artifact, atomic publish like the checkpoint
manager: write to ``<dir>.tmp`` then rename):

    <root>/<method>/<config_hash>-<program_fp>/
        meta.json        # method, program, config_hash, timings, meta,
                         # payload manifest (tree paths + shapes/dtypes)
        payload.npz      # every array leaf, keyed by "<name>/<tree path>"

    <root>/plans/<method>-<program_fp>-<config_hash>/
        plan.json        # reps, method string, json-safe extra
        plan.npz         # labels

Payload values may be numpy arrays or pytrees of arrays (nested dict/list
— e.g. trained RGCN params); they are flattened to '/'-joined key paths and
rebuilt on load, so no pickling is involved.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
from typing import Any, Optional

import numpy as np

from repro.sampling.base import Artifacts
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program

_SEP = "/"


def program_fingerprint(program: Program) -> str:
    """Stable CONTENT id for a traced program.

    Hashes name + per-kernel (name, seq, template, params, seed) + the
    program's `fingerprint_extra` (generated programs put their
    ScenarioSpec hash there).  Kernel names alone are not enough: two
    generated programs can share every name while differing in params or
    trace seed, and their artifacts must not collide in the store.
    The human-readable prefix is sanitized for filesystem use (scenario
    names contain ':' / '=' / ',').
    """
    h = hashlib.sha1(program.name.encode())
    for k in program.kernels:
        params = sorted(getattr(k, "params", {}).items())
        h.update(
            f"{k.name}:{k.seq}:{getattr(k, 'template', '')}"
            f":{params}:{getattr(k, 'seed', '')};".encode()
        )
    extra = getattr(program, "fingerprint_extra", "")
    if extra:
        h.update(f"|{extra}".encode())
    safe_name = re.sub(r"[^A-Za-z0-9_.-]", "_", program.name)
    return f"{safe_name}-{h.hexdigest()[:10]}"


# -- pytree <-> flat arrays ---------------------------------------------------

def flatten_tree(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """Flatten nested dict/list/array pytrees to {path: array}."""
    out: dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in tree:
            if _SEP in str(k):
                raise ValueError(f"tree key {k!r} contains {_SEP!r}")
            out.update(flatten_tree(tree[k], f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_tree(v, f"{prefix}{i}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = np.asarray(tree)
    return out


def unflatten_tree(flat: dict[str, np.ndarray]) -> Any:
    """Inverse of flatten_tree: digit-only key levels rebuild lists."""
    if list(flat) == [""]:
        return flat[""]
    nest: dict = {}
    for path, arr in flat.items():
        parts = path.split(_SEP)
        node = nest
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.isdigit() for k in node):
            return [rebuild(node[str(i)]) for i in range(len(node))]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(nest)


def _json_safe(obj: Any) -> Any:
    """Best-effort conversion of `extra`-style dicts to JSON-safe values;
    drops entries that cannot be represented."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return repr(obj)


class ArtifactStore:
    """Save/load `Artifacts` and `SamplingPlan`s under a run directory.

    ``cache=True`` keeps every saved/loaded artifact in an in-process map,
    so a long-lived server replaying the same tenant's encoder
    (``run_prepare`` -> ``load``) skips the npz round-trip after the first
    hit (repro.serving turns this on).  Cached loads return the SAME
    object — treat replayed artifacts as read-only (the save/load path
    already does).  ``cache_stats`` counts hits/misses for serving
    telemetry.  Default OFF: batch runs keep the disk as the only source
    of truth.
    """

    def __init__(self, root: str, cache: bool = False):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._cache: Optional[dict[tuple[str, str], Artifacts]] = (
            {} if cache else None)
        self._graph_store = None
        self.cache_stats = {"hits": 0, "misses": 0}

    def graph_store(self):
        """The run's packed-graph cache (`repro.ingest.GraphStore`), living
        beside the artifacts under ``<root>/graphs/`` — one per store, so
        every method/program sharing this run directory shares traced
        graphs."""
        if self._graph_store is None:
            from repro.ingest.store import GraphStore  # lazy: no cycle

            self._graph_store = GraphStore(os.path.join(self.root, "graphs"))
        return self._graph_store

    # -- artifacts -----------------------------------------------------------
    def _artifact_dir(self, method: str, key: str) -> str:
        return os.path.join(self.root, method, key)

    def checkpoint_dir(self, method: str, key: str) -> str:
        """Directory for a method's in-flight fit checkpoints (the trainer
        resume protocol, DESIGN.md §6).  Lives NEXT TO the artifacts under
        the same content key, so an interrupted ``prepare`` resumed later
        finds its snapshots; once the finished artifact is published the
        checkpoints are just a warm cache for refits."""
        return os.path.join(self.root, "checkpoints", method, key)

    def has(self, method: str, key: str) -> bool:
        return os.path.exists(
            os.path.join(self._artifact_dir(method, key), "meta.json"))

    def save(self, artifacts: Artifacts) -> str:
        final = self._artifact_dir(artifacts.method, artifacts.key)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)

        flat: dict[str, np.ndarray] = {}
        manifest = {}
        for name, value in artifacts.payload.items():
            sub = flatten_tree(value, f"{name}{_SEP}")
            manifest[name] = sorted(sub)
            flat.update(sub)
        if flat:
            np.savez(os.path.join(tmp, "payload.npz"), **flat)
        meta = {
            "method": artifacts.method,
            "program": artifacts.program,
            "config_hash": artifacts.config_hash,
            "provenance": artifacts.provenance,
            "timings": _json_safe(artifacts.timings),
            "meta": _json_safe(artifacts.meta),
            "payload_manifest": manifest,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
        shutil.rmtree(final, ignore_errors=True)
        os.makedirs(os.path.dirname(final), exist_ok=True)
        os.rename(tmp, final)
        if self._cache is not None:
            self._cache[(artifacts.method, artifacts.key)] = artifacts
        return final

    def load(self, method: str, key: str) -> Optional[Artifacts]:
        """Returns None when absent (the prepare-or-replay idiom)."""
        if self._cache is not None and (method, key) in self._cache:
            self.cache_stats["hits"] += 1
            return self._cache[(method, key)]
        d = self._artifact_dir(method, key)
        if not self.has(method, key):
            return None
        self.cache_stats["misses"] += 1
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        payload: dict[str, Any] = {}
        npz_path = os.path.join(d, "payload.npz")
        flat: dict[str, np.ndarray] = {}
        if os.path.exists(npz_path):
            with np.load(npz_path) as z:
                flat = {k: z[k] for k in z.files}
        for name, paths in meta["payload_manifest"].items():
            sub = {p[len(name) + 1:]: flat[p] for p in paths}
            payload[name] = unflatten_tree(sub)
        art = Artifacts(
            method=meta["method"], program=meta["program"],
            config_hash=meta["config_hash"], payload=payload,
            timings=meta["timings"], meta=meta["meta"],
            provenance=meta.get("provenance", ""),
        )
        if self._cache is not None:
            self._cache[(method, key)] = art
        return art

    # -- plans ---------------------------------------------------------------
    def _plan_dir(self, method: str, key: str) -> str:
        return os.path.join(self.root, "plans", f"{method}-{key}")

    def save_plan(self, plan: SamplingPlan, method: str, key: str) -> str:
        final = self._plan_dir(method, key)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "plan.npz"), labels=plan.labels)
        doc = {
            "method": plan.method,
            "reps": {str(c): [int(i) for i in v] for c, v in plan.reps.items()},
            "extra": _json_safe(plan.extra),
        }
        with open(os.path.join(tmp, "plan.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        shutil.rmtree(final, ignore_errors=True)
        os.makedirs(os.path.dirname(final), exist_ok=True)
        os.rename(tmp, final)
        return final

    def load_plan(self, method: str, key: str) -> Optional[SamplingPlan]:
        d = self._plan_dir(method, key)
        if not os.path.exists(os.path.join(d, "plan.json")):
            return None
        with open(os.path.join(d, "plan.json")) as f:
            doc = json.load(f)
        with np.load(os.path.join(d, "plan.npz")) as z:
            labels = z["labels"]
        return SamplingPlan(
            labels=labels,
            reps={int(c): list(v) for c, v in doc["reps"].items()},
            method=doc["method"], extra=doc["extra"],
        )
