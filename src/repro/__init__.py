"""repro: GCL-Sampler — sampled GPU simulation via graph contrastive learning.

A production-grade JAX framework reproducing and extending
"GCL-Sampler: Discovering Kernel Similarity for Sampled GPU Simulation via
Graph Contrastive Learning" (CS.PF 2026).

Layers (bottom-up):
  tracing      SASS-like workload/trace substrate (the simulation *subject*)
  sim          stall-aware cycle-approximate GPU timing model (ground truth)
  core         the paper's contribution: HRG + RGCN contrastive sampler
  models       assigned LM architecture zoo (GQA / MoE / SSM / hybrid)
  kernels      Pallas TPU kernels for compute hot-spots
  distributed  sharding rules, collectives, fault tolerance
  launch       mesh / dryrun / train / serve entry points
"""

__version__ = "0.1.0"
