"""Config system: model / shape / train / mesh configs.

Every assigned architecture is a `ModelConfig` registered under its public id
(``--arch <id>``).  Shapes are the four LM-family cells assigned to this paper.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Trace-window caps: the single source of truth for the graph subject's
# bounded per-warp window (paper §3.1).  Every trace call site —
# ``KernelInvocation.trace``, ``core.graphs.iter_kernel_graphs``, the
# ingestion engine and the graph cache key — resolves omitted caps here, so
# two paths can never silently trace the same kernel at different windows
# (they used to: trace() defaulted to 256 instructions while
# iter_kernel_graphs defaulted to 96).
# ---------------------------------------------------------------------------

DEFAULT_CAP_WARPS = 2
DEFAULT_CAP_INSTR = 96


def resolve_trace_caps(cap_warps=None, cap_instr=None, program=None):
    """Resolve (cap_warps, cap_instr): explicit argument > the program's own
    ``trace_caps`` (model-zoo programs carry 10-100x larger windows) > the
    repo-wide defaults above."""
    prog_caps = getattr(program, "trace_caps", None) or (None, None)
    cw = cap_warps if cap_warps is not None else prog_caps[0]
    ci = cap_instr if cap_instr is not None else prog_caps[1]
    return (int(cw) if cw is not None else DEFAULT_CAP_WARPS,
            int(ci) if ci is not None else DEFAULT_CAP_INSTR)


# ---------------------------------------------------------------------------
# Layer-position specs: each layer has a token mixer and an FFN kind.
# ---------------------------------------------------------------------------

MIXER_ATTN = "attention"
MIXER_MAMBA2 = "mamba2"

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # MIXER_ATTN | MIXER_MAMBA2
    ffn: str    # FFN_DENSE | FFN_MOE | FFN_NONE


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int           # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0        # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid layout (period structure).  block_size layers form one scanned
    # block; attn_positions/moe_positions index *within* the block.
    block_size: int = 1
    attn_positions: Sequence[int] = ()   # positions with attention mixer
    moe_positions: Sequence[int] = ()    # positions whose FFN is MoE

    # misc
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    frontend: Optional[str] = None       # 'audio' | 'vision'
    frontend_tokens: int = 0             # prepended embedding tokens (vlm)

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"           # 'full' | 'dots' (save matmul outputs)
    scan_layers: bool = True
    attn_chunk: int = 1024               # query-chunk size for chunked attention
    attn_chunk_threshold: int = 8192     # use chunked attention for seq >= this
    loss_chunk: int = 256                # seq-chunk size for chunked cross-entropy
    moe_seq_chunk: int = 1024            # routing-group size (bounds dispatch buffers)
    decode_split: int = 0                # >0: flash-decoding split-softmax over
                                         # this many seq chunks (shard-local
                                         # partials + tiny LSE merge instead of
                                         # all-gathering the KV cache)
    attn_impl: str = "xla"               # 'xla' | 'pallas' | 'pallas_interpret'

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.attn_positions and self.num_heads > 0:
            # default: attention at every position of the block
            object.__setattr__(
                self, "attn_positions", tuple(range(self.block_size))
            )
        if not self.moe_positions and self.num_experts > 0:
            object.__setattr__(
                self, "moe_positions", tuple(range(self.block_size))
            )
        if self.num_layers % self.block_size != 0:
            raise ValueError(
                f"{self.arch_id}: num_layers {self.num_layers} not divisible "
                f"by block_size {self.block_size}"
            )

    # -- derived -----------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.num_layers // self.block_size

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def layer_specs(self) -> list[LayerSpec]:
        """The LayerSpec for each position within one block."""
        specs = []
        for p in range(self.block_size):
            if p in tuple(self.attn_positions):
                mixer = MIXER_ATTN
            else:
                mixer = MIXER_MAMBA2
            if self.d_ff == 0:
                ffn = FFN_NONE
            elif p in tuple(self.moe_positions):
                ffn = FFN_MOE
            else:
                ffn = FFN_DENSE
            specs.append(LayerSpec(mixer=mixer, ffn=ffn))
        return specs

    def is_subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM/hybrid)."""
        specs = self.layer_specs()
        n_attn = sum(1 for s in specs if s.mixer == MIXER_ATTN)
        return n_attn < len(specs)  # any mamba layer -> sub-quadratic prefill

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (assigned LM-family set)
# ---------------------------------------------------------------------------

KIND_TRAIN = "train"
KIND_PREFILL = "prefill"
KIND_DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, KIND_TRAIN),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, KIND_PREFILL),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, KIND_DECODE),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, KIND_DECODE),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; long_500k needs sub-quadratic attn."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Train / mesh configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 7e-4          # paper: AdamW lr 7e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1_000
    schedule: str = "cosine"             # paper: cosine annealing
    grad_clip: float = 1.0
    loss_scale: float = 1.0              # static scale on low-precision grads
                                         # (unscaled inside adamw_update)
    opt_dtype: str = "float32"           # bf16 moments for very large archs
    grad_compress: bool = False          # error-feedback int8 DP compression
    microbatch: int = 0                  # 0 = no gradient accumulation
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.multi_pod else ("data",)


# ---------------------------------------------------------------------------
# Analytical parameter / FLOP accounting (used by roofline + sim substrate)
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> dict[str, int]:
    """Total and active (per-token) parameter counts, matmul weights only."""
    D, F = cfg.d_model, cfg.d_ff
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = 0
    active = 0
    for spec in cfg.layer_specs():
        if spec.mixer == MIXER_ATTN:
            p = D * H * hd + 2 * D * K * hd + H * hd * D
            if cfg.qkv_bias:
                p += H * hd + 2 * K * hd
            total += p
            active += p
        else:  # mamba2
            din, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            p = D * (2 * din + 2 * ds + nh)  # in_proj -> [z, x, B, C, dt]
            p += (din + 2 * ds) * cfg.ssm_conv  # depthwise conv
            p += din * D  # out_proj
            p += 2 * nh  # A_log, D skip
            total += p
            active += p
        if spec.ffn == FFN_DENSE:
            p = 3 * D * F
            total += p
            active += p
        elif spec.ffn == FFN_MOE:
            total += cfg.num_experts * 3 * D * F + D * cfg.num_experts
            active += cfg.top_k * 3 * D * F + D * cfg.num_experts
    total *= cfg.num_blocks
    active *= cfg.num_blocks
    embed = cfg.vocab_size * D
    total += embed if cfg.tie_embeddings else 2 * embed
    active += embed if cfg.tie_embeddings else 2 * embed
    return {"total": total, "active": active}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step: 6*N_active*D_tokens (train), 2*N_active (fwd)."""
    counts = param_counts(cfg)
    n_active = counts["active"]
    if shape.kind == KIND_TRAIN:
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == KIND_PREFILL:
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
