"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), from the dry-run's compiled artifact:

  compute    = HLO_FLOPs   / (chips * peak_FLOP/s)       [197 TFLOP/s bf16]
  memory     = HLO_bytes   / (chips * HBM_bw)            [819 GB/s]
  collective = coll_bytes  / (chips * link_bw)           [~50 GB/s/link]

cost_analysis() reports per-device FLOPs/bytes on the SPMD-partitioned
module, so HLO_FLOPs = flops_per_device * chips and the chips cancel;
collective bytes are parsed from the partitioned HLO text (per-device) and
scaled the same way.  The dominant term is the bottleneck the perf loop
(EXPERIMENTS.md §Perf) iterates on.
"""

from __future__ import annotations

import json
import re

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9          # bytes/s per chip
LINK_BW = 50e9          # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(?:\([^)]*\)\s+)?((?:[a-z0-9]+)\[[0-9,]*\][^ ]*)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the partitioned
    HLO.  Returns per-category and total per-device bytes."""
    per_cat: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if shape_str is None:
            # tuple-result form: take shapes before the op name
            pre = line.split(kind)[0]
            shape_str = pre
        b = _shape_bytes(shape_str)
        per_cat[kind] = per_cat.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    total = sum(per_cat.values())
    return {
        "per_device_bytes": total,
        "by_kind_bytes": per_cat,
        "op_counts": count,
    }


def roofline_terms(rec: dict) -> dict:
    """Compute the three terms (seconds) + bottleneck for a dry-run record."""
    chips = rec["num_devices"]
    fpd = rec["cost"].get("flops_per_device") or 0.0
    bpd = rec["cost"].get("bytes_per_device") or 0.0
    cpd = rec["collectives"]["per_device_bytes"]
    t_compute = fpd / PEAK_FLOPS
    t_memory = bpd / HBM_BW
    t_coll = cpd / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    total_flops = fpd * chips
    useful = rec.get("model_flops", 0.0)
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "hlo_flops_total": total_flops,
        "model_flops": useful,
        "useful_flop_ratio": (useful / total_flops) if total_flops else None,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (
            min(1.0, t_compute / max(terms.values())) if max(terms.values()) else None
        ),
    }


def summarize(path: str = "dryrun_results.json"):
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r["multi_pod"],
                         r["status"], r.get("reason", r.get("error", ""))[:60]))
            continue
        rl = r["roofline"]
        rows.append((
            r["arch"], r["shape"], r["multi_pod"], "ok",
            f"comp {rl['compute_s']:.3e}s mem {rl['memory_s']:.3e}s "
            f"coll {rl['collective_s']:.3e}s -> {rl['dominant']}"
            f" (useful {100 * (rl['useful_flop_ratio'] or 0):.0f}%)",
        ))
    return rows


if __name__ == "__main__":
    import sys

    for row in summarize(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"):
        print(*row)
