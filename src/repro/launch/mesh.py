"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run driver must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, multi_pod: bool = False, **kw) -> MeshRules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshRules(mesh=mesh, batch_axes=batch_axes, **kw)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Single-host debug mesh (uses however many devices exist)."""
    return jax.make_mesh((data, model), ("data", "model"))
