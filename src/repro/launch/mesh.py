"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run driver must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(mesh, *, multi_pod: bool = False, **kw) -> MeshRules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return MeshRules(mesh=mesh, batch_axes=batch_axes, **kw)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Single-host debug mesh (uses however many devices exist)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_data_mesh(ndev: int = 0, *, axes: tuple = ("data", "model")) -> MeshRules:
    """Data-parallel MeshRules over the FIRST ``ndev`` visible devices
    (0 = all): an ``(ndev, 1)`` mesh with the model axis unsharded.  The
    scale-out drivers — ``fit_resilient``'s shrinking widths, the scale-out
    benchmark, the simulated-mesh tests — all build widths through here so
    they agree on device ORDER (a degraded 4-wide mesh is a prefix of the
    8-wide one, so arrays resharded on resume move, not reshuffle)."""
    import numpy as np
    from jax.sharding import Mesh

    n = int(ndev) or jax.device_count()
    devs = np.array(jax.devices()[:n]).reshape(n, 1)
    return MeshRules(mesh=Mesh(devs, axes))
