import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape) cell, lower + compile the step on the
production mesh — 16x16 (data, model) single-pod and 2x16x16
(pod, data, model) multi-pod — and record memory_analysis / cost_analysis /
per-device collective bytes into a JSON artifact consumed by the roofline
analysis (launch/roofline.py) and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system, not in the cell.
"""

import argparse
import json
import time
import traceback

import jax

from repro.config import (
    KIND_PREFILL, KIND_TRAIN, SHAPES, TrainConfig,
    param_counts, model_flops, shape_applicable,
)
from repro.configs import get_arch, list_archs
from repro.distributed.sharding import set_mesh_rules
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch import steps as steps_mod
from repro.launch.roofline import collective_bytes_from_hlo, roofline_terms
from repro.models.specs import batch_specs, decode_state_specs

LARGE_ARCH_PARAMS = 30e9  # bf16 optimizer moments above this (HBM fit)


def _train_config(cfg) -> TrainConfig:
    n = param_counts(cfg)["total"]
    return TrainConfig(opt_dtype="bfloat16" if n > LARGE_ARCH_PARAMS else "float32")


# every (arch, shape) cell is lowered exactly once per process by
# construction, so a compile cache would never hit — it would only pin
# dead executables in memory
def _lower_one(cfg, shape, mesh, rules, tcfg=None):  # lint: allow[R2] one-shot AOT lowering driver
    """Lower + compile a step for `cfg` on `mesh`; returns (compiled, timers)."""
    t0 = time.time()
    with mesh, set_mesh_rules(rules):
        if shape.kind == KIND_TRAIN:
            tcfg = tcfg or _train_config(cfg)
            astate = steps_mod.train_state_specs(cfg, tcfg)
            st_sh = steps_mod.train_state_shardings(cfg, tcfg, astate, rules)
            b_sh = steps_mod.batch_shardings(cfg, shape, rules)
            step = steps_mod.make_train_step(cfg, tcfg)
            lowered = jax.jit(
                step, in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None), donate_argnums=(0,),
            ).lower(astate, batch_specs(cfg, shape))
        elif shape.kind == KIND_PREFILL:
            aparams = steps_mod.abstract_params(cfg)
            p_sh = steps_mod.param_shardings(cfg, aparams, rules)
            b_sh = steps_mod.batch_shardings(cfg, shape, rules)
            step = steps_mod.make_prefill_step(cfg)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                aparams, batch_specs(cfg, shape)
            )
        else:  # decode
            aparams = steps_mod.abstract_params(cfg)
            p_sh = steps_mod.param_shardings(cfg, aparams, rules)
            d_sh = steps_mod.decode_state_shardings(cfg, shape, rules)
            b_sh = steps_mod.batch_shardings(cfg, shape, rules)
            step = steps_mod.make_decode_step(cfg)
            lowered = jax.jit(
                step, in_shardings=(p_sh, d_sh, b_sh),
                out_shardings=(None, d_sh), donate_argnums=(1,),
            ).lower(aparams, decode_state_specs(cfg, shape),
                    batch_specs(cfg, shape))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, (round(t_lower, 1), round(t_compile, 1))


def _costs(compiled):
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": cost.get("flops") or 0.0,
        "bytes": cost.get("bytes accessed") or 0.0,
        "coll": coll["per_device_bytes"],
        "coll_detail": coll,
    }


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               rules_kw=None, cfg_kw=None, correct_scan: bool = True,
               verbose=True):
    """Lower + compile one cell; returns the result record.

    XLA's cost_analysis counts a lax.scan body ONCE regardless of trip
    count, so per-step FLOP/byte/collective totals from the scanned-layers
    compile are underestimates.  With correct_scan=True we additionally
    lower UNROLLED 1-block and 2-block variants of the same arch and
    extrapolate: total = cost(1b) + (num_blocks - 1) * (cost(2b) - cost(1b)).
    memory_analysis comes from the full scanned compile (that's the real
    executable's footprint)."""
    cfg = get_arch(arch_id)
    if cfg_kw:
        cfg = cfg.replace(**cfg_kw)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, multi_pod=multi_pod, **(rules_kw or {}))
    tcfg = _train_config(cfg)
    compiled, (t_lower, t_compile) = _lower_one(cfg, shape, mesh, rules, tcfg)

    mem = compiled.memory_analysis()
    base = _costs(compiled)
    n_dev = mesh.devices.size

    corrected = dict(base)
    if correct_scan and cfg.num_blocks > 1:
        # the correction lowers must contain NO inner scans either (chunked
        # attention / chunked CE / seq-chunked MoE are all lax.scans that
        # cost_analysis counts once) — chunking exists only to bound runtime
        # memory, and lowering allocates nothing, so disable it here.
        unchunk = dict(
            scan_layers=False,
            attn_chunk_threshold=10**9,
            loss_chunk=10**9,
            moe_seq_chunk=10**9,
        )
        c1 = cfg.replace(num_layers=cfg.block_size, **unchunk)
        c2 = cfg.replace(num_layers=2 * cfg.block_size, **unchunk)
        k1, _ = _lower_one(c1, shape, mesh, rules, tcfg)
        k2, _ = _lower_one(c2, shape, mesh, rules, tcfg)
        s1, s2 = _costs(k1), _costs(k2)
        nb = cfg.num_blocks
        corrected = {
            k: s1[k] + (nb - 1) * (s2[k] - s1[k])
            for k in ("flops", "bytes", "coll")
        }

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "num_devices": int(n_dev),
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_scanned": {k: base[k] for k in ("flops", "bytes", "coll")},
        "cost": {
            "flops_per_device": corrected["flops"],
            "bytes_per_device": corrected["bytes"],
        },
        "collectives": {"per_device_bytes": corrected["coll"],
                        **{k: v for k, v in base["coll_detail"].items()
                           if k != "per_device_bytes"}},
        "model_flops": model_flops(cfg, shape),
        "params_total": param_counts(cfg)["total"],
        "params_active": param_counts(cfg)["active"],
    }
    rec["roofline"] = roofline_terms(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    # resume from existing artifact (incremental)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results
                if r.get("status") in ("ok", "skipped")}

    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mp)
                if key in done:
                    continue
                label = f"{arch} x {shape} ({'2x16x16' if mp else '16x16'})"
                print(f"[dryrun] {label} ...", flush=True)
                try:
                    # scan-correction (for the roofline table) on the
                    # single-pod mesh only; multi-pod proves the pod axis.
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     correct_scan=not mp)
                except Exception as e:  # a bug in the system — record it
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results.append(rec)
                status = rec["status"]
                if status == "ok":
                    m = rec["memory"]
                    print(
                        f"  ok: {rec['compile_s']}s compile, "
                        f"args {_gb(m['argument_bytes_per_device'])}, "
                        f"temp {_gb(m['temp_bytes_per_device'])}, "
                        f"flops/dev {rec['cost']['flops_per_device']:.3g}, "
                        f"coll/dev {_gb(rec['collectives']['per_device_bytes'])}",
                        flush=True,
                    )
                else:
                    print(f"  {status}: {rec.get('reason', rec.get('error'))}",
                          flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


def _gb(x):
    return f"{x / 1e9:.2f}GB" if x is not None else "?"


if __name__ == "__main__":
    raise SystemExit(main())
