"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production loop wiring: deterministic data pipeline, sharded train step
under an explicit mesh, async atomic checkpointing with ``--resume auto``
(elastic across mesh changes), straggler watchdog, heartbeat, optional
int8 error-feedback gradient compression and gradient accumulation.

On this CPU container use ``--smoke`` (reduced same-family config); full
configs are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.config import ShapeConfig, TrainConfig
from repro.configs import get_arch, smoke_arch
from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.distributed.fault import Heartbeat, Watchdog
from repro.distributed.sharding import MeshRules, set_mesh_rules
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_debug_mesh, make_production_mesh, make_rules
from repro.models import transformer as tf
from repro.optim import adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--mesh", choices=["debug", "pod", "multipod"],
                    default="debug")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", default=None, help="'auto' or a step number")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    tcfg = TrainConfig(
        learning_rate=args.lr, warmup_steps=args.warmup,
        total_steps=args.steps, microbatch=args.microbatch,
        grad_compress=args.grad_compress, seed=args.seed,
    )

    if args.mesh == "debug":
        mesh = make_debug_mesh()
        rules = MeshRules(mesh=mesh, batch_axes=("data",))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
        rules = make_rules(mesh, multi_pod=args.mesh == "multipod")

    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    data = TokenStream(
        cfg.vocab_size, args.seq_len, args.batch, seed=args.seed,
        frontend=cfg.frontend, d_model=cfg.d_model,
        frontend_tokens=cfg.frontend_tokens,
    )

    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    watchdog = Watchdog(
        on_straggler=lambda t: print(f"[watchdog] step exceeded {t:.1f}s SLO "
                                     f"(straggler suspected)", flush=True)
    )
    hb = Heartbeat(f"/tmp/repro_heartbeat_{args.seed}.json")
    hb.start()

    with mesh, set_mesh_rules(rules):
        astate = steps_mod.train_state_specs(cfg, tcfg)
        st_sh = steps_mod.train_state_shardings(cfg, tcfg, astate, rules)
        start_step = 0
        if ckpt and args.resume:
            step_arg = None if args.resume == "auto" else int(args.resume)
            try:
                state, start_step = ckpt.restore(astate, step=step_arg,
                                                 shardings=st_sh)
                data.skip(start_step)
                print(f"[train] resumed from step {start_step}", flush=True)
            except FileNotFoundError:
                state = None
        else:
            state = None
        if state is None:
            params, _ = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
            state = adamw_init(params, tcfg)
            state = jax.device_put(state, st_sh)

        # one-shot CLI: the single train jit is built once per process
        # lint: allow[R2] built once, before the step loop
        step_fn = jax.jit(
            steps_mod.make_train_step(cfg, tcfg),
            in_shardings=(st_sh, None), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )

        t_start = time.time()
        for step in range(start_step, args.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in data.next().items()}
            watchdog.step_start()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])  # lint: allow[R1] watchdog SLO timing needs the step's real completion
            dt = watchdog.step_end()
            hb.update(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}  # lint: allow[R1] log_every-gated metrics print; step already synced for the watchdog
                print(
                    f"[train] step {step:5d} loss={m['loss']:.4f} "
                    f"gnorm={m.get('grad_norm', 0):.2f} lr={m.get('lr', 0):.2e} "
                    f"{dt * 1e3:.0f}ms", flush=True,
                )
            if ckpt and (step + 1) % args.checkpoint_every == 0:
                ckpt.save(step + 1, state, specs=st_sh)
        if ckpt:
            ckpt.save(args.steps, state, specs=st_sh)
            ckpt.wait()
    hb.stop()
    total = time.time() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {total:.1f}s "
          f"({watchdog.fired} watchdog events)", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
