"""Step functions the launchers and the dry-run lower: train / prefill /
decode, plus the sharding trees that accompany them."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import MeshRules, sharding_for
from repro.models import transformer as tf
from repro.models.specs import batch_axes_tree, batch_specs, decode_state_specs
from repro.optim import TrainState, adamw_init, apply_gradients


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    def train_step(state: TrainState, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            grads, metrics = _accumulated_grads(cfg, state.params, batch, tcfg)
        else:
            # differentiate the SCALED loss (adamw_update divides the grads
            # by tcfg.loss_scale — the two sides of the loss-scale contract,
            # DESIGN.md §7); reported metrics stay unscaled
            (loss, aux), grads = jax.value_and_grad(
                lambda p: _scaled_lm_loss(cfg, p, batch, tcfg.loss_scale),
                has_aux=True,
            )(state.params)
            metrics = dict(aux, loss=loss / tcfg.loss_scale)
        new_state, opt_metrics = apply_gradients(state, grads, tcfg)
        return new_state, dict(metrics, **opt_metrics)

    return train_step


def _scaled_lm_loss(cfg, params, batch, scale):
    loss, aux = tf.lm_loss(cfg, params, batch)
    return loss * scale, aux


def _accumulated_grads(cfg, params, batch, tcfg):
    """Gradient accumulation over microbatches (scan over batch splits)."""
    n = tcfg.microbatch

    def split(x):
        B = x.shape[0]
        return x.reshape(n, B // n, *x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, _), grads = jax.value_and_grad(
            lambda p: _scaled_lm_loss(cfg, p, mb, tcfg.loss_scale),
            has_aux=True,
        )(params)
        acc = jax.tree_util.tree_map(jnp.add, acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
    grads = jax.tree_util.tree_map(lambda g: g / n, grads)
    return grads, {"loss": loss / (n * tcfg.loss_scale)}


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches, idx = tf.prefill(
            cfg, params, batch["tokens"], batch.get("frontend")
        )
        return logits, {"caches": caches, "index": idx}

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, state, batch):
        return tf.decode_step(cfg, params, state, batch["tokens"])

    return decode_step


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def _tree_shardings(axes_tree, spec_tree, rules: MeshRules, is_param: bool):
    def is_axes_leaf(t):
        return isinstance(t, tuple) and all(
            isinstance(x, (str, type(None))) for x in t
        )

    return jax.tree_util.tree_map(
        lambda axes, leaf: sharding_for(axes, leaf.shape, rules=rules,
                                        is_param=is_param),
        axes_tree, spec_tree, is_leaf=is_axes_leaf,
    )


def abstract_params(cfg: ModelConfig):
    specs = jax.eval_shape(
        lambda k: tf.init_params(cfg, k)[0],
        jax.random.PRNGKey(0))  # lint: allow[R3] abstract eval_shape key

    return specs


def param_shardings(cfg: ModelConfig, aparams, rules: MeshRules):
    return _tree_shardings(tf.params_axes(cfg), aparams, rules, True)


def train_state_specs(cfg: ModelConfig, tcfg: TrainConfig):
    aparams = abstract_params(cfg)
    return jax.eval_shape(lambda p: adamw_init(p, tcfg), aparams)


def train_state_shardings(cfg: ModelConfig, tcfg: TrainConfig,
                          astate: TrainState, rules: MeshRules):
    from jax.sharding import NamedSharding, PartitionSpec as P

    psh = param_shardings(cfg, astate.params, rules)
    rep = NamedSharding(rules.mesh, P())
    err = None if astate.compress_err is None else psh
    return TrainState(step=rep, params=psh, mu=psh, nu=psh, compress_err=err)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, rules: MeshRules):
    return _tree_shardings(
        batch_axes_tree(cfg, shape), batch_specs(cfg, shape), rules, False
    )


def decode_state_shardings(cfg: ModelConfig, shape: ShapeConfig,
                           rules: MeshRules):
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tf.decode_state_axes(cfg)
    specs = decode_state_specs(cfg, shape)
    caches = _tree_shardings(axes["caches"], specs["caches"], rules, False)
    return {"caches": caches, "index": NamedSharding(rules.mesh, P())}
