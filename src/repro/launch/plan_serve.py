"""Plan-serving launcher: continuous-batched sampling-as-a-service.

``python -m repro.launch.plan_serve --load 100,300 --requests 120``

Stands up a :class:`repro.serving.PlanService` (continuous batcher over the
compiled PlanEngine, DESIGN.md §9), optionally pre-warms the executable
pool, and drives it with open-loop Poisson traffic at each offered load,
reporting p50/p99 plan latency, plans/s, queue depth, and batch occupancy.

Knobs: ``--max-delay-ms`` (bucket flush deadline), ``--max-batch``
(programs per compiled dispatch), ``--warmup-buckets 64x16,128x16`` /
``--no-warmup`` (the warm pool), ``--load`` (offered req/s, comma list).

NOT the model-decode server: ``repro.launch.serve`` serves transformer
prefill/decode traffic.  This CLI serves *sampling plans*.  Tenant traffic
with ArtifactStore-backed encoder reuse goes through
``PlanService.submit_program`` (see repro.serving).
"""

from __future__ import annotations

import argparse
import json

from repro.sampling.engine import bucket_key
from repro.serving import (
    PlanService, parse_buckets, run_open_loop, synthetic_fleet,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.plan_serve")
    ap.add_argument("--requests", type=int, default=120,
                    help="requests per offered load")
    ap.add_argument("--load", default="100",
                    help="offered loads in req/s (comma list)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--warmup-buckets", default=None,
                    help="explicit warm pool, e.g. '64x16,128x16' "
                         "(default: every bucket of the synthetic fleet)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="serve cold: first requests pay the compiles")
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k-max", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, one load)")
    ap.add_argument("--json", default=None, help="write results JSON here")
    args = ap.parse_args(argv)

    n_requests = min(args.requests, 40) if args.smoke else args.requests
    loads = [float(x) for x in str(args.load).split(",") if x]
    if args.smoke:
        loads = loads[:1]

    fleet = synthetic_fleet(n_requests, d=args.d, seed=args.seed)
    buckets = sorted({bucket_key(r.embeddings) for r in fleet})
    rows = []
    with PlanService(max_batch=args.max_batch,
                     max_delay_ms=args.max_delay_ms,
                     k_max=args.k_max, iters=args.iters,
                     seed=args.seed) as svc:
        if not args.no_warmup:
            warm = (parse_buckets(args.warmup_buckets)
                    if args.warmup_buckets else buckets)
            built = svc.warmup(warm)
            print(f"[plan-serve] warm pool: {built} executables built for "
                  f"{len(warm)} buckets", flush=True)
        for rate in loads:
            res = run_open_loop(svc, fleet, rate, seed=args.seed)
            s = res.service
            print(
                f"[plan-serve] load {rate:.0f}/s: {res.plans_per_s:.1f} "
                f"plans/s, p50 {res.latency_ms['p50']:.1f}ms, p99 "
                f"{res.latency_ms['p99']:.1f}ms, occupancy "
                f"{s['batch_occupancy'] and round(s['batch_occupancy'], 2)}, "
                f"mean queue {s['mean_queue_depth']:.1f}, flushes "
                f"{s['flush_causes']}", flush=True)
            rows.append(res.to_json())
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"buckets": [list(b) for b in buckets],
                       "loads": rows}, f, indent=1, sort_keys=True)
        print(f"[plan-serve] wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
