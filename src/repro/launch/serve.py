"""MODEL-DECODE serving launcher: batched prefill + decode loop.

``python -m repro.launch.serve --arch mamba2-780m --smoke --tokens 32``

Runs continuous batching over a synthetic request queue: prefill each batch,
then decode N tokens per request with the KV/SSM cache, reporting per-phase
throughput.  Full configs are exercised by the dry-run decode cells.

This serves TRANSFORMER TOKENS, not sampling plans.  Plan serving — the
continuous-batched PlanEngine service with its warm executable pool
(DESIGN.md §9) — lives in :mod:`repro.serving` and is launched with
``python -m repro.launch.plan_serve``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_arch
from repro.distributed.sharding import MeshRules, set_mesh_rules
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as tf
from repro.models.frontends import text_len


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=2, help="number of batches")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_arch(args.arch) if args.smoke else get_arch(args.arch)
    mesh = make_debug_mesh()
    rules = MeshRules(mesh=mesh, batch_axes=("data",))

    params, _ = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.tokens + cfg.frontend_tokens

    # one-shot CLI: both jits are built exactly once per process, before
    # the request loop — there is nothing for a cache to save
    prefill_fn = jax.jit(lambda p, t, f: tf.prefill(cfg, p, t, f))  # lint: allow[R2] built once per process
    decode_fn = jax.jit(lambda p, s, t: tf.decode_step(cfg, p, s, t))  # lint: allow[R2] built once per process

    rng = np.random.default_rng(args.seed)
    tl = text_len(cfg, args.prompt_len + cfg.frontend_tokens)

    with mesh, set_mesh_rules(rules):
        for req in range(args.requests):
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (args.batch, tl)), jnp.int32
            )
            fe = None
            if cfg.frontend == "vision":
                fe = jnp.asarray(
                    rng.standard_normal((args.batch, cfg.frontend_tokens, cfg.d_model)),
                    jnp.float32,
                )
            elif cfg.frontend == "audio":
                fe = jnp.asarray(
                    rng.standard_normal((args.batch, tl, cfg.d_model)), jnp.float32
                )
            t0 = time.time()
            logits, caches, idx = prefill_fn(params, prompts, fe)
            jax.block_until_ready(logits)  # lint: allow[R1] prefill latency measurement needs a real sync
            t_prefill = time.time() - t0

            # build the decode state at max_seq and splice prefilled caches in
            # (host-side state construction needs the concrete prefill cursor
            # — a shape decision made once per batch, not a per-token sync)
            state = tf.init_decode_state(cfg, args.batch, max_seq,
                                         prefilled=int(idx))  # lint: allow[R1] concrete cursor, once per batch
            state = _splice_prefill(cfg, state, caches, int(idx))  # lint: allow[R1] same concrete cursor
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out_tokens = [tok]
            t0 = time.time()
            for _ in range(args.tokens - 1):
                logits, state = decode_fn(params, state, tok)
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
                out_tokens.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.time() - t0
            seq = jnp.concatenate(out_tokens, axis=1)
            print(
                f"[serve] batch {req}: prefill {tl} toks x{args.batch} in "
                f"{t_prefill * 1e3:.0f}ms; decode {args.tokens} toks in "
                f"{t_decode * 1e3:.0f}ms "
                f"({args.tokens * args.batch / max(t_decode, 1e-9):.1f} tok/s); "
                f"sample: {np.asarray(seq[0, :8]).tolist()}",
                flush=True,
            )
    return 0


def _splice_prefill(cfg, state, caches, prefilled: int):
    """Write prefill KV (length P) into the max_seq decode caches; SSM/conv
    states transfer directly."""
    import jax

    def splice(dst, src):
        if dst.shape == src.shape:  # ssm / conv states
            return src
        # KV: dst (nb,B,S_max,K,hd), src (nb,B,P,K,hd)
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), 0, axis=2
        )

    new_caches = jax.tree_util.tree_map(splice, state["caches"], caches)
    return {"caches": new_caches, "index": jnp.int32(prefilled)}


if __name__ == "__main__":
    raise SystemExit(main())
