"""One-command reproduction of the paper's sampling table.

Sweeps the full method x program x platform grid through the unified
``repro.sampling`` API and writes a machine-readable results JSON
(schema ``repro.sampling.results/v2``) plus reusable artifacts/plans:

  PYTHONPATH=src python -m repro.launch.sample \\
      --method gcl,pka,sieve,stem_root --programs nw,3mm \\
      --platforms P1,P2,P3 --out runs/table
  PYTHONPATH=src python -m repro.launch.sample --method gcl,pka --smoke
  PYTHONPATH=src python -m repro.launch.sample --suite scenarios \\
      --families iterative,pipeline,long_tail --scenario-seeds 0,1

``--suite scenarios`` sweeps a seeded generated scenario matrix
(repro.workloads) instead of the fixed paper table; rows carry the scenario
``family`` and the doc gains a method x family ``family_summary``.

Per the paper's cross-architecture protocol, clustering decisions are made
once (on the method's decision platform, P1 by default) and the same plan
is evaluated on every ``--platforms`` entry.  Artifacts are content-hash
cached under ``<out>/artifacts`` — a second sweep over an overlapping grid
replays trained encoders instead of refitting.

Each method's program axis runs in two stages: every program is prepared
(trained/profiled) first, then ALL plans are served through the method's
``plan_batch`` — engine-backed methods (gcl, pka) dispatch many programs
per compiled multi-K sweep (``repro.sampling.PlanEngine``; DESIGN.md §8)
and full simulations are evaluated vectorized per program.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

from repro.sampling import (
    ArtifactStore, available_methods, evaluate_metrics, get_method,
)
from repro.sim.hardware import PLATFORMS
from repro.sim.simulate import METRIC_NAMES, simulate_program
from repro.tracing.programs import PAPER_PROGRAMS, get_program
from repro.workloads import scenario_families, scenario_family_of, scenario_matrix

RESULTS_SCHEMA = "repro.sampling.results/v2"
SUITES = ("paper", "scenarios", "modelzoo")
SMOKE_PROGRAMS = ["3mm", "backprop"]
# modelzoo-suite smoke: one small arch, both phases (the full suite is
# repro.workloads.zoo_names(): every zoo arch x prefill/decode)
SMOKE_MODELZOO = ["model:llama3.2-3b:prefill", "model:llama3.2-3b:decode"]
SMOKE_GCL = dict(steps=10, batch_size=4, cap_instr=48)
# scenario-suite smoke: 3 families x 1 seed, small phase sizes
SMOKE_SCENARIOS = dict(families=("iterative", "pipeline", "long_tail"),
                       seeds=(0,), phases=2, phase_len=6)


def _method_kwargs(method_id: str, *, smoke: bool = False,
                   gcl_steps: int = 0, seed: int = 0,
                   suite: str = "paper", checkpoint_every: int = 0,
                   resume: bool = True, ingest_workers: int = 0,
                   graph_cache: bool = True) -> dict:
    if method_id == "pka":
        return {"seed": seed} if seed else {}
    if method_id != "gcl":
        return {}  # sieve / stem_root are deterministic, no seed
    kw: dict = dict(SMOKE_GCL) if smoke else {}
    if suite in ("scenarios", "modelzoo"):
        # generated populations / 10-100x model-zoo graphs flow through the
        # bounded-memory trace->graph path regardless of per-program size
        kw["streaming"] = True
    if ingest_workers:
        kw["ingest_workers"] = ingest_workers
    if not graph_cache:
        kw["graph_cache"] = False
    if gcl_steps:
        kw["steps"] = gcl_steps
    if seed:
        kw["seed"] = seed
    if checkpoint_every:
        # encoder-fit snapshots under <out>/artifacts/checkpoints: an
        # interrupted sweep rerun resumes mid-fit instead of refitting
        kw["checkpoint_every"] = checkpoint_every
    if not resume:
        kw["resume"] = False
    return kw


def split_programs(arg: str) -> list[str]:
    """Split a comma-separated --programs list, keeping multi-field
    scenario names intact: `scn:` spec fields are themselves
    comma-separated (`scn:long_tail:seed=3,phase_len=24`), so a fragment
    that is a bare `key=value` belongs to the preceding scenario name."""
    from repro.workloads.spec import ScenarioSpec
    from dataclasses import fields

    spec_keys = tuple(f"{f.name}=" for f in fields(ScenarioSpec)
                      if f.name != "family")
    out: list[str] = []
    for part in (p.strip() for p in arg.split(",") if p.strip()):
        if out and out[-1].startswith("scn:") and part.startswith(spec_keys):
            out[-1] += f",{part}"
        else:
            out.append(part)
    return out


def _family_summary(results: list[dict]) -> list[dict]:
    """Aggregate method x scenario-family: mean cycles error, geometric-mean
    speedup, cell count (the `--suite scenarios` headline table)."""
    groups: dict[tuple, list[dict]] = {}
    for row in results:
        groups.setdefault((row["method_id"], row["family"]), []).append(row)
    out = []
    for (method_id, family), rows in sorted(groups.items()):
        errs = [r["error_pct"]["cycles"] for r in rows]
        spd = [r["speedup"] for r in rows]
        out.append({
            "method_id": method_id,
            "family": family,
            "cells": len(rows),
            "mean_error_pct": float(sum(errs) / len(errs)),
            "geomean_speedup": float(
                math.exp(sum(math.log(max(s, 1e-12)) for s in spd) / len(spd))
            ),
        })
    return out


def run_grid(methods: list[str], programs: list[str], platforms: list[str],
             out_dir: str, *, smoke: bool = False, gcl_steps: int = 0,
             seed: int = 0, suite: str = "paper",
             checkpoint_every: int = 0, resume: bool = True,
             ingest_workers: int = 0, graph_cache: bool = True,
             verbose: bool = True) -> dict:
    """Run every (method, program) cell once, evaluate on every platform."""
    store = ArtifactStore(os.path.join(out_dir, "artifacts"))
    results: list[dict] = []
    failures: list[dict] = []
    batch_plan_errors: list[dict] = []  # plan_batch -> per-cell fallbacks
    metrics_cache: dict = {}  # (program, platform) -> full simulation

    def metrics_for(program_name, program, platform):
        key = (program_name, platform)
        if key not in metrics_cache:
            metrics_cache[key] = simulate_program(program, platform)
        return metrics_cache[key]

    t_start = time.time()
    for method_id in methods:
        method = get_method(
            method_id,
            **_method_kwargs(method_id, smoke=smoke, gcl_steps=gcl_steps,
                             seed=seed, suite=suite,
                             checkpoint_every=checkpoint_every,
                             resume=resume, ingest_workers=ingest_workers,
                             graph_cache=graph_cache))
        # stage 1: prepare (train/profile/featurize) the whole program axis
        prepared = []  # (program_name, program, artifacts, prepare_s)
        for program_name in programs:
            cell = f"{method_id} x {program_name}"
            try:
                program = get_program(program_name)
                t0 = time.time()
                artifacts = method.run_prepare(program, store=store)
                prepared.append((program_name, program, artifacts,
                                 time.time() - t0))
            except Exception as e:  # a broken cell must not kill the sweep
                failures.append({"cell": cell,
                                 "error": f"{type(e).__name__}: {e}"})
                if verbose:
                    print(f"  [{cell}] FAILED: {e}", flush=True)
        # stage 2: serve every prepared program's plan — engine-backed
        # methods dispatch MANY programs per compiled multi-K sweep
        t0 = time.time()
        try:
            plans = method.plan_batch(
                [(prog, art) for _, prog, art, _ in prepared])
            plans = list(zip(prepared, plans))
        except Exception as e:  # batched serving failed: re-plan per cell
            # the degradation must be loud — a batching-only bug would
            # otherwise hide behind the per-cell fallback forever
            batch_plan_errors.append({
                "method_id": method_id,
                "error": f"{type(e).__name__}: {e}"})
            if verbose:
                print(f"  [{method_id}] plan_batch FAILED "
                      f"({type(e).__name__}: {e}); falling back to "
                      f"per-cell planning", flush=True)
            plans = []
            for item in prepared:
                program_name, program, artifacts, _ = item
                try:
                    plans.append((item, method.plan(program, artifacts)))
                except Exception as e:
                    failures.append({
                        "cell": f"{method_id} x {program_name}",
                        "error": f"{type(e).__name__}: {e}"})
                    if verbose:
                        print(f"  [{method_id} x {program_name}] FAILED: {e}",
                              flush=True)
        plan_s = (time.time() - t0) / max(len(plans), 1)
        # plans are served; artifact payloads (encoder params, embeddings)
        # are persisted in the store and no longer needed — don't pin
        # O(programs x encoder) memory through the evaluation stage
        for _, _, artifacts, _ in prepared:
            artifacts.payload.clear()
        # stage 3: persist + evaluate every (plan, platform)
        for (program_name, program, artifacts, prep_s), plan in plans:
            cell = f"{method_id} x {program_name}"
            try:
                store.save_plan(plan, method_id, artifacts.key)
                fit_s = prep_s + plan_s
                if verbose:
                    print(f"  [{cell}] K={plan.num_clusters} "
                          f"reps={len(plan.rep_indices())} ({fit_s:.1f}s)",
                          flush=True)
                for platform in platforms:
                    res = evaluate_metrics(
                        plan, metrics_for(program_name, program, platform),
                        program=program.name, platform=platform)
                    row = res.to_dict()
                    row.update(method_id=method_id, fit_s=fit_s,
                               artifact_key=artifacts.key,
                               family=scenario_family_of(program_name))
                    results.append(row)
            except Exception as e:
                failures.append({"cell": cell,
                                 "error": f"{type(e).__name__}: {e}"})
                if verbose:
                    print(f"  [{cell}] FAILED: {e}", flush=True)
    return {
        "schema": RESULTS_SCHEMA,
        "created_unix": time.time(),
        "grid": {"methods": methods, "programs": programs,
                 "platforms": platforms, "smoke": smoke, "suite": suite},
        "wall_time_s": time.time() - t_start,
        "results": results,
        "family_summary": _family_summary(results),
        "failures": failures,
        "batch_plan_errors": batch_plan_errors,
    }


def validate_results(doc: dict) -> None:
    """Schema check for the results JSON; raises ValueError on violation."""
    def fail(msg):
        raise ValueError(f"results JSON invalid: {msg}")

    if doc.get("schema") != RESULTS_SCHEMA:
        fail(f"schema is {doc.get('schema')!r}, want {RESULTS_SCHEMA!r}")
    grid = doc.get("grid")
    if not isinstance(grid, dict):
        fail("missing grid")
    for key in ("methods", "programs", "platforms"):
        if not isinstance(grid.get(key), list) or not grid[key]:
            fail(f"grid.{key} must be a non-empty list")
    if grid.get("suite") not in SUITES:
        fail(f"grid.suite must be one of {SUITES}")
    if not isinstance(doc.get("results"), list):
        fail("results must be a list")
    if not isinstance(doc.get("failures"), list):
        fail("failures must be a list")
    if not isinstance(doc.get("batch_plan_errors", []), list):
        fail("batch_plan_errors must be a list")
    if not isinstance(doc.get("family_summary"), list):
        fail("family_summary must be a list")
    for i, row in enumerate(doc["family_summary"]):
        where = f"family_summary[{i}]"
        for key in ("method_id", "family"):
            if not isinstance(row.get(key), str) or not row[key]:
                fail(f"{where}.{key} must be a non-empty string")
        if not isinstance(row.get("cells"), int) or row["cells"] <= 0:
            fail(f"{where}.cells must be a positive int")
        for key in ("mean_error_pct", "geomean_speedup"):
            if not isinstance(row.get(key), (int, float)) or row[key] < 0:
                fail(f"{where}.{key} must be a number >= 0")
    for i, row in enumerate(doc["results"]):
        where = f"results[{i}]"
        for key in ("method", "method_id", "program", "platform", "family"):
            if not isinstance(row.get(key), str) or not row[key]:
                fail(f"{where}.{key} must be a non-empty string")
        if row["method_id"] not in grid["methods"]:
            fail(f"{where}.method_id {row['method_id']!r} not in grid")
        if row["platform"] not in grid["platforms"]:
            fail(f"{where}.platform {row['platform']!r} not in grid")
        for key in ("num_kernels", "num_clusters", "num_reps"):
            if not isinstance(row.get(key), int) or row[key] <= 0:
                fail(f"{where}.{key} must be a positive int")
        err = row.get("error_pct")
        if not isinstance(err, dict):
            fail(f"{where}.error_pct must be a dict")
        for name in METRIC_NAMES:
            v = err.get(name)
            if not isinstance(v, (int, float)) or v < 0:
                fail(f"{where}.error_pct[{name!r}] must be a float >= 0")
        for key in ("speedup", "sim_speedup"):
            if not isinstance(row.get(key), (int, float)) or row[key] <= 0:
                fail(f"{where}.{key} must be a positive number")
        for key in ("sim_time_full_s", "sim_time_sampled_s", "fit_s"):
            if not isinstance(row.get(key), (int, float)) or row[key] < 0:
                fail(f"{where}.{key} must be a number >= 0")


def _print_table(doc: dict) -> None:
    wide = max([len(r["program"]) for r in doc["results"]] + [8]) + 2
    print(f"\n{'method':14s}{'program':{wide}s}{'plat':>5s}{'K':>5s}"
          f"{'reps':>6s}{'err %':>8s}{'speedup':>9s}")
    for row in doc["results"]:
        print(f"{row['method']:14s}{row['program']:{wide}s}"
              f"{row['platform']:>5s}"
              f"{row['num_clusters']:5d}{row['num_reps']:6d}"
              f"{row['error_pct']['cycles']:8.2f}{row['speedup']:8.1f}x")
    if doc["grid"].get("suite") == "scenarios" and doc["family_summary"]:
        print(f"\n{'method':14s}{'family':14s}{'cells':>6s}"
              f"{'mean err %':>12s}{'gm speedup':>12s}")
        for s in doc["family_summary"]:
            print(f"{s['method_id']:14s}{s['family']:14s}{s['cells']:6d}"
                  f"{s['mean_error_pct']:12.2f}{s['geomean_speedup']:11.1f}x")
    if doc["failures"]:
        print(f"\n{len(doc['failures'])} cell(s) FAILED:")
        for f in doc["failures"]:
            print(f"  {f['cell']}: {f['error']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.sample",
        description="Sweep sampling methods over programs and platforms.")
    ap.add_argument("--method", default="all",
                    help="comma-separated method ids, or 'all' "
                         f"(known: {','.join(available_methods())})")
    ap.add_argument("--suite", default="paper", choices=SUITES,
                    help="program axis: the paper's fixed 11-program table, "
                         "or a seeded generated scenario matrix "
                         "(repro.workloads)")
    ap.add_argument("--programs", default="",
                    help="comma-separated program names — overrides --suite "
                         "(default: smoke set with --smoke, else all paper "
                         f"programs: {','.join(PAPER_PROGRAMS)}; scenario "
                         "specs like scn:pipeline:seed=1 also work)")
    ap.add_argument("--families", default="",
                    help="scenario families for --suite scenarios "
                         f"(known: {','.join(scenario_families())}; "
                         "default: smoke subset with --smoke, else all)")
    ap.add_argument("--scenario-seeds", default="0",
                    help="comma-separated spec seeds for --suite scenarios")
    ap.add_argument("--platforms", default="P1",
                    help=f"comma-separated platforms (known: "
                         f"{','.join(PLATFORMS)})")
    ap.add_argument("--out", default="runs/sample",
                    help="run directory (artifacts, plans, results.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny GCL config + small default programs")
    ap.add_argument("--gcl-steps", type=int, default=0,
                    help="override GCL contrastive training steps")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot GCL encoder fits every N steps under "
                         "<out>/artifacts/checkpoints; a rerun of an "
                         "interrupted sweep resumes mid-fit (0 = off)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing fit checkpoints (refit from "
                         "scratch; snapshots are still written)")
    ap.add_argument("--seed", type=int, default=0,
                    help="reseed the stochastic methods (gcl, pka); "
                         "sieve/stem_root are deterministic")
    ap.add_argument("--ingest-workers", type=int, default=0,
                    help="concurrent trace->graph ingest workers for gcl "
                         "(0 = sequential; output is bit-identical at any "
                         "worker count)")
    ap.add_argument("--no-graph-cache", action="store_true",
                    help="skip the on-disk packed-graph cache (always "
                         "re-trace; warm runs normally re-trace nothing)")
    args = ap.parse_args(argv)

    methods = (available_methods() if args.method == "all"
               else [m.strip() for m in args.method.split(",") if m.strip()])
    for m in methods:
        if m not in available_methods():
            ap.error(f"unknown method {m!r}; known: {available_methods()}")
    if args.programs:
        programs = split_programs(args.programs)
    elif args.suite == "scenarios":
        families = [f.strip() for f in args.families.split(",") if f.strip()]
        for f in families:
            if f not in scenario_families():
                ap.error(f"unknown family {f!r}; known: "
                         f"{scenario_families()}")
        seeds = tuple(int(s) for s in args.scenario_seeds.split(",") if s)
        if args.smoke:
            sm = dict(SMOKE_SCENARIOS)
            programs = scenario_matrix(
                families or sm["families"], seeds or sm["seeds"],
                phases=sm["phases"], phase_len=sm["phase_len"])
        else:
            programs = scenario_matrix(families or None, seeds or (0,))
    elif args.suite == "modelzoo":
        from repro.workloads import zoo_names

        programs = SMOKE_MODELZOO if args.smoke else zoo_names()
    else:
        programs = SMOKE_PROGRAMS if args.smoke else list(PAPER_PROGRAMS)
    platforms = [p.strip() for p in args.platforms.split(",") if p.strip()]
    for p in platforms:
        if p not in PLATFORMS:
            ap.error(f"unknown platform {p!r}; known: {list(PLATFORMS)}")

    print(f"== sampling grid [{args.suite}]: {len(methods)} method(s) x "
          f"{len(programs)} program(s) x {len(platforms)} platform(s) "
          f"-> {args.out} ==")
    doc = run_grid(methods, programs, platforms, args.out, smoke=args.smoke,
                   gcl_steps=args.gcl_steps, seed=args.seed,
                   suite=args.suite, checkpoint_every=args.checkpoint_every,
                   resume=not args.no_resume,
                   ingest_workers=args.ingest_workers,
                   graph_cache=not args.no_graph_cache)
    validate_results(doc)
    os.makedirs(args.out, exist_ok=True)
    results_path = os.path.join(args.out, "results.json")
    with open(results_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    _print_table(doc)
    print(f"\nresults JSON: {results_path} "
          f"({len(doc['results'])} rows, {doc['wall_time_s']:.0f}s)")
    return 1 if doc["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
