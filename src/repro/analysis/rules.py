"""Repo-specific invariant rules R1–R5 (DESIGN.md §10).

R1  host-sync hazard      float()/int()/.item()/np.asarray()/device_get/
                          block_until_ready inside a jit/scan/vmap-traced
                          region, or applied to compiled-engine outputs
                          inside a dispatch hot loop
R2  recompile hazard      jax.jit built outside the process-wide caches
                          (per-call jits, jit-in-loop, unhashable statics)
R3  RNG discipline        hard-coded PRNGKey literals in library code;
                          key reuse across samplers without split/fold_in
R4  donation safety       a buffer read after being passed through a
                          donate_argnums position
R5  Pallas conformance    hard-coded interpret= outside repro.kernels,
                          true-division grids, bf16 casts that bypass
                          core/precision.py, kernel matmuls without an
                          explicit f32 accumulator

Waiver syntax: a ``# lint: allow[R1] reason`` comment on the finding line,
the line above it, or the enclosing ``def`` line (function-wide) suppresses
the named rule(s).  Waivers are for *genuine* host paths (the
``engine="python"`` parity shim, the sequential per-K reference, one-shot
CLI jits) — fix true positives instead of waiving them.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass
from typing import Optional

from repro.analysis.callgraph import FunctionInfo, ModuleIndex, dotted

RULES = {
    "R1": "host-sync hazard",
    "R2": "recompile hazard",
    "R3": "RNG discipline",
    "R4": "donation safety",
    "R5": "Pallas conformance",
}

#: numpy functions that force a device->host materialization when handed a
#: traced/device array (trace-time shape math like np.sqrt(3) stays legal)
_NP_SYNC = {"asarray", "array", "ascontiguousarray", "copy", "save",
            "savez", "savez_compressed", "frombuffer"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_JAX_SYNC = {"jax.device_get", "jax.block_until_ready"}
_SAMPLERS = {
    "uniform", "normal", "bernoulli", "randint", "categorical", "choice",
    "permutation", "shuffle", "gumbel", "truncated_normal", "exponential",
    "laplace", "bits", "beta", "dirichlet", "gamma", "poisson",
}
_KEY_MAKERS = {"jax.random.PRNGKey", "jax.random.split",
               "jax.random.fold_in", "jax.random.key"}

_WAIVE_RE = re.compile(r"lint:\s*allow\[([A-Za-z0-9_,\s]+)\]")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str      # repo-relative, forward slashes
    line: int
    col: int
    symbol: str    # enclosing function qualname or "<module>"
    message: str

    @property
    def key(self) -> str:
        """Baseline identity — line-insensitive so unrelated edits that
        shift code never churn the baseline."""
        return f"{self.rule} :: {self.path} :: {self.symbol} :: {self.message}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


def parse_waivers(source: str) -> dict[int, set]:
    """line -> set of waived rule ids, from ``# lint: allow[...]`` comments."""
    out: dict[int, set] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVE_RE.search(tok.string)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return out


def _snippet(node: ast.AST, limit: int = 48) -> str:
    try:
        text = ast.unparse(node)
    except Exception:
        text = "<expr>"
    text = " ".join(text.split())
    return text if len(text) <= limit else text[: limit - 3] + "..."


def iter_own(node: ast.AST):
    """Walk a function body WITHOUT descending into nested functions or
    lambdas (those are indexed — and judged — separately)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
            stack.extend(ast.iter_child_nodes(child))


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _assigned_names(target: ast.AST) -> set:
    out = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
    return out


class RuleContext:
    """Everything one module's rule passes need: the index, the global
    function map (after fixed points), and resolution helpers."""

    def __init__(self, idx: ModuleIndex, funcs: dict[str, FunctionInfo],
                 jit_attrs: dict[str, tuple]):
        self.idx = idx
        self.funcs = funcs
        self.jit_attrs = jit_attrs   # repo-wide attr name -> donate positions
        self.in_kernels = "/kernels/" in idx.path.replace("\\", "/")
        #: node -> owning FunctionInfo (module-level nodes are absent)
        self.owner: dict[int, FunctionInfo] = {}
        for info in idx.functions.values():
            for child in iter_own(info.node):
                self.owner.setdefault(id(child), info)

    def symbol(self, node: ast.AST) -> str:
        info = self.owner.get(id(node))
        return info.qual if info is not None else "<module>"

    def owner_info(self, node: ast.AST) -> Optional[FunctionInfo]:
        return self.owner.get(id(node))

    def call_name(self, node: ast.Call) -> Optional[str]:
        return self.idx.call_names.get(node)

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=self.idx.path, line=node.lineno,
                       col=node.col_offset, symbol=self.symbol(node),
                       message=message)

    # -- dispatch-source classification -------------------------------------
    def lookup(self, name: Optional[str]) -> Optional[FunctionInfo]:
        """Map a resolved callee (fid or cross-module dotted path) to a
        scanned function."""
        if name is None:
            return None
        return self.funcs.get(name)

    def local_executables(self, fn: FunctionInfo) -> dict:
        """Names in ``fn`` bound to compiled executables -> donate
        positions: direct ``x = jax.jit(...)`` plus factory results like
        ``step_fn = self._make_step()`` where the factory returns a jit."""
        out: dict[str, tuple] = dict(self.idx.jit_locals.get(fn.fid, {}))
        for node in iter_own(fn.node):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                target = self.lookup(self.call_name(node.value))
                if target is not None and target.returns_jit:
                    for tgt in node.targets:
                        for n in _assigned_names(tgt):
                            out[n] = target.donate_positions
        return out

    def is_dispatch_call(self, node: ast.Call, fn: FunctionInfo,
                         local_exec: dict) -> bool:
        """Does this call launch compiled device work?"""
        name = self.call_name(node)
        if name is None and node in self.idx.submit_targets:
            # pool.submit(fn, ...): the future IS compiled work in flight
            # when the worker fn dispatches — taint it like a direct call
            worker = self.lookup(self.idx.submit_targets.get(node))
            if worker is not None:
                return (worker.traced_entry or worker.returns_jit
                        or worker.dispatching)
            return False
        target = self.lookup(name)
        if target is not None:
            return (target.traced_entry or target.returns_jit
                    or target.dispatching)
        if name is not None:
            return False  # resolved external (jax.lax.scan, np.*) — not ours
        func = node.func
        if isinstance(func, ast.Name):
            return func.id in local_exec
        if isinstance(func, ast.Attribute):
            # unresolvable attr (eng.scan on a local object): fall back to
            # the repo-wide jit-attr tail match
            return func.attr in self.jit_attrs
        return False

    def donate_positions_of(self, node: ast.Call, fn: FunctionInfo,
                            local_exec: dict) -> tuple:
        name = self.call_name(node)
        if name is not None:
            # resolved names never donate at the call site: externals
            # (jax.lax.scan) don't, and calling a returns-jit *factory*
            # doesn't either — donation applies when the bound result runs
            return ()
        func = node.func
        if isinstance(func, ast.Name):
            return local_exec.get(func.id, ())
        if isinstance(func, ast.Attribute):
            return self.jit_attrs.get(func.attr, ())
        return ()


def _is_builtin_cast(ctx: RuleContext, node: ast.Call) -> Optional[str]:
    """float()/int()/bool() on a non-constant argument (a device scalar at
    runtime forces a sync)."""
    func = node.func
    if (isinstance(func, ast.Name) and func.id in ("float", "int", "bool")
            and ctx.call_name(node) is None and len(node.args) == 1
            and not isinstance(node.args[0], ast.Constant)):
        return func.id
    return None


def _sync_kind(ctx: RuleContext, node: ast.Call) -> Optional[str]:
    """Classify a call as a host-sync primitive (None if not one)."""
    cast = _is_builtin_cast(ctx, node)
    if cast is not None:
        return f"{cast}()"
    name = ctx.call_name(node)
    if name in _JAX_SYNC:
        return name
    if name is not None and name.startswith("numpy."):
        tail = name.split(".")[-1]
        if tail in _NP_SYNC:
            return f"np.{tail}"
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr in _SYNC_METHODS:
        return f".{func.attr}()"
    return None


# ---------------------------------------------------------------------------
# R1 — host-sync hazard
# ---------------------------------------------------------------------------


def check_r1(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    # R1c: registered host-only ingestion roots (callgraph.INGEST_ENTRIES)
    # reached by the traced fixed point — the numpy-RNG tracer's bit-exact
    # stream contract cannot survive running under jit/scan/vmap
    for info in ctx.idx.functions.values():
        if info.host_entry and info.traced:
            out.append(ctx.finding(
                "R1", info.node,
                f"registered host-only ingestion entry `{info.qual}` is "
                f"reachable from a jit/scan/vmap trace — trace->graph "
                f"ingestion must stay on host threads"))
    # R1a: sync primitives inside traced regions
    for info in ctx.idx.functions.values():
        if not info.traced:
            continue
        for node in iter_own(info.node):
            if not isinstance(node, ast.Call):
                continue
            kind = _sync_kind(ctx, node)
            if kind is not None:
                out.append(ctx.finding(
                    "R1", node,
                    f"host sync `{_snippet(node)}` inside jit/scan/vmap-"
                    f"traced `{info.qual}` ({kind} forces a device round "
                    f"trip per trace)"))
    # R1b: sync on compiled-engine outputs inside a dispatch hot loop
    for info in ctx.idx.functions.values():
        if info.traced:
            continue
        for loop in iter_own(info.node):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            out.extend(_check_dispatch_loop(ctx, info, loop))
    return out


def _check_dispatch_loop(ctx: RuleContext, info: FunctionInfo,
                         loop: ast.AST) -> list[Finding]:
    body_nodes = [n for stmt in loop.body for n in [stmt, *iter_own(stmt)]]
    local_exec = ctx.local_executables(info)
    tainted: set = set()
    dispatch_names: set = set()
    for node in body_nodes:
        if isinstance(node, ast.Assign):
            calls = [c for c in ast.walk(node.value)
                     if isinstance(c, ast.Call)
                     and ctx.is_dispatch_call(c, info, local_exec)]
            if calls:
                for tgt in node.targets:
                    tainted |= _assigned_names(tgt)
                dispatch_names |= {_snippet(c.func, 32) for c in calls}
    if not tainted:
        return []
    # comprehension variables iterating over tainted values inherit taint
    for node in body_nodes:
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                             ast.GeneratorExp)):
            for gen in node.generators:
                if _names_in(gen.iter) & tainted:
                    tainted |= _assigned_names(gen.target)
    out = []
    for node in body_nodes:
        if not isinstance(node, ast.Call):
            continue
        kind = _sync_kind_loop(ctx, node)
        if kind is None:
            continue
        refs = set()
        for arg in [*node.args, *[k.value for k in node.keywords]]:
            refs |= _names_in(arg)
        if isinstance(node.func, ast.Attribute):
            refs |= _names_in(node.func.value)
        if refs & tainted:
            out.append(ctx.finding(
                "R1", node,
                f"host sync `{_snippet(node)}` on compiled-engine output "
                f"(from {'/'.join(sorted(dispatch_names))}) inside the "
                f"dispatch loop of `{info.qual}` — one device round trip "
                f"per iteration"))
    return out


def _sync_kind_loop(ctx: RuleContext, node: ast.Call) -> Optional[str]:
    """In a dispatch loop ANY numpy call on an engine output syncs, not
    just the conversion set."""
    kind = _sync_kind(ctx, node)
    if kind is not None:
        return kind
    name = ctx.call_name(node)
    if name is not None and name.startswith("numpy."):
        return f"np.{name.split('.')[-1]}"
    return None


# ---------------------------------------------------------------------------
# R2 — recompile hazard
# ---------------------------------------------------------------------------


def check_r2(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    for node, name in ctx.idx.call_names.items():
        if name != "jax.jit":
            continue
        info = ctx.owner_info(node)
        if info is None:
            continue  # module-level jit compiles once per process
        if info.lru_cached:
            continue
        if _jit_result_cached(ctx, info, node):
            continue
        in_loop = _enclosing_loop(info, node)
        if in_loop:
            out.append(ctx.finding(
                "R2", node,
                f"jax.jit built inside a loop in `{info.qual}` — every "
                f"iteration traces a fresh executable; hoist it or route "
                f"through a process-wide cache"))
        else:
            out.append(ctx.finding(
                "R2", node,
                f"jax.jit built per call in `{info.qual}` without a "
                f"process-wide cache (lru_cache / cache-dict store) — "
                f"repeated calls recompile"))
    out.extend(_check_static_args(ctx))
    return out


def _jit_result_cached(ctx: RuleContext, info: FunctionInfo,
                       jit_call: ast.Call) -> bool:
    """The jit result escapes into a cache: assigned to a subscript
    (``cache[key] = jax.jit(...)``), to an attribute (``self._fn = ...``,
    bounded per instance), stored under a name that is later written into a
    subscript, or passed as a keyword into a registry-style constructor."""
    names: set = set()
    for node in iter_own(info.node):
        if isinstance(node, ast.Assign) and _contains(node.value, jit_call):
            for tgt in node.targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    return True
                names |= _assigned_names(tgt)
        if isinstance(node, ast.keyword) and _contains(node.value, jit_call):
            return True  # EngineFns(scan=jax.jit(...)) — cached via lru
    if not names:
        return False
    for node in iter_own(info.node):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Subscript)
                        and isinstance(node.value, ast.Name)
                        and node.value.id in names):
                    return True
    return False


def _contains(tree: ast.AST, target: ast.AST) -> bool:
    return any(n is target for n in ast.walk(tree))


def _enclosing_loop(info: FunctionInfo, target: ast.AST) -> bool:
    for node in iter_own(info.node):
        if isinstance(node, (ast.For, ast.While)):
            if any(n is target for n in ast.walk(node)):
                return True
    return False


def _check_static_args(ctx: RuleContext) -> list[Finding]:
    """Unhashable literals at static positions of jitted callables."""
    out: list[Finding] = []
    static_fns: dict[str, set] = {}   # local fn qual -> static arg names
    for info in ctx.idx.functions.values():
        node = info.node
        if isinstance(node, ast.Lambda):
            continue
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            base = ctx.idx.resolve(dec.func)
            is_jit = base == "jax.jit" or (
                base == "functools.partial" and dec.args
                and ctx.idx.resolve(dec.args[0]) == "jax.jit")
            if not is_jit:
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    vals = (kw.value.elts if isinstance(
                        kw.value, (ast.Tuple, ast.List)) else [kw.value])
                    static_fns[info.fid] = {
                        v.value for v in vals
                        if isinstance(v, ast.Constant)}
    if not static_fns:
        return out
    for node, name in ctx.idx.call_names.items():
        target = static_fns.get(name or "")
        if not target:
            continue
        for kw in node.keywords:
            if kw.arg in target and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set)):
                out.append(ctx.finding(
                    "R2", node,
                    f"unhashable {type(kw.value).__name__.lower()} literal "
                    f"passed as static arg `{kw.arg}` — every call "
                    f"re-traces (and newer jax versions reject it)"))
    return out


# ---------------------------------------------------------------------------
# R3 — RNG discipline
# ---------------------------------------------------------------------------


def check_r3(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    for node, name in ctx.idx.call_names.items():
        if name in ("jax.random.PRNGKey", "jax.random.key") and node.args \
                and isinstance(node.args[0], ast.Constant):
            out.append(ctx.finding(
                "R3", node,
                f"hard-coded `{_snippet(node)}` in library code — derive "
                f"the key from the config seed via fold_in so callers "
                f"control determinism"))
    for info in ctx.idx.functions.values():
        out.extend(_check_key_reuse(ctx, info))
    return out


def _check_key_reuse(ctx: RuleContext, info: FunctionInfo) -> list[Finding]:
    key_vars: set = set()
    reassigned: set = set()
    for node in iter_own(info.node):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if ctx.call_name(node.value) in _KEY_MAKERS:
                for tgt in node.targets:
                    new = _assigned_names(tgt)
                    reassigned |= new & key_vars
                    key_vars |= new
    uses: dict[str, list] = {}
    for node in iter_own(info.node):
        if not isinstance(node, ast.Call):
            continue
        name = ctx.call_name(node)
        if (name is None or not name.startswith("jax.random.")
                or name.split(".")[-1] not in _SAMPLERS):
            continue
        if node.args and isinstance(node.args[0], ast.Name) \
                and node.args[0].id in key_vars:
            uses.setdefault(node.args[0].id, []).append(node)
    out = []
    for var, nodes in uses.items():
        if len(nodes) < 2 or var in reassigned:
            continue
        for node in sorted(nodes, key=lambda n: n.lineno)[1:]:
            out.append(ctx.finding(
                "R3", node,
                f"PRNGKey `{var}` reused by `{_snippet(node)}` after an "
                f"earlier sampler draw in `{info.qual}` — split or fold_in "
                f"between draws (reuse correlates the streams)"))
    return out


# ---------------------------------------------------------------------------
# R4 — donation safety
# ---------------------------------------------------------------------------


def check_r4(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    for info in ctx.idx.functions.values():
        out.extend(_check_donation(ctx, info))
    return out


def _check_donation(ctx: RuleContext, info: FunctionInfo) -> list[Finding]:
    body = info.node.body
    if not isinstance(body, list):
        return []
    # local names bound to donating executables (x = self._make_step() where
    # _make_step returns jax.jit(..., donate_argnums=...))
    local_jit = ctx.local_executables(info)
    dead: dict[str, tuple] = {}   # name -> (line, callee snippet)
    out: list[Finding] = []
    statements = sorted(
        (n for n in iter_own(info.node) if isinstance(n, ast.stmt)),
        key=lambda n: (n.lineno, n.col_offset))
    for stmt in statements:
        # reads of dead names in this statement (before any rebinds apply)
        reads = _names_in(stmt)
        writes = _assigned_names(stmt) if isinstance(
            stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.For)) else set()
        for name in list(dead):
            line, callee = dead[name]
            if stmt.lineno <= line:
                continue
            if name in reads and name not in writes:
                out.append(ctx.finding(
                    "R4", stmt,
                    f"`{name}` read after being donated to `{callee}` "
                    f"(donate_argnums) in `{info.qual}` — the buffer is "
                    f"invalid once the executable runs"))
                dead.pop(name)
            elif name in writes:
                dead.pop(name)
        for call in ast.walk(stmt):
            if not isinstance(call, ast.Call):
                continue
            donate = ctx.donate_positions_of(call, info, local_jit)
            if not donate:
                continue
            rebound = _assigned_names(stmt)
            for pos in donate:
                if pos < len(call.args) and isinstance(
                        call.args[pos], ast.Name):
                    name = call.args[pos].id
                    if name not in rebound:
                        dead[name] = (stmt.lineno, _snippet(call.func, 32))
    return out


# ---------------------------------------------------------------------------
# R5 — Pallas conformance
# ---------------------------------------------------------------------------


def check_r5(ctx: RuleContext) -> list[Finding]:
    out: list[Finding] = []
    path = ctx.idx.path.replace("\\", "/")
    in_precision = path.endswith("core/precision.py")
    is_kernel_impl = ctx.in_kernels and path.endswith("kernel.py")
    for node, name in ctx.idx.call_names.items():
        # R5a: hard-coded interpret outside repro.kernels
        if not ctx.in_kernels:
            for kw in node.keywords:
                if kw.arg == "interpret" and isinstance(
                        kw.value, ast.Constant) and isinstance(
                            kw.value.value, bool):
                    out.append(ctx.finding(
                        "R5", node,
                        f"hard-coded interpret={kw.value.value} at "
                        f"`{_snippet(node)}` — pass interpret=None so "
                        f"repro.kernels.default_interpret resolves the "
                        f"backend"))
        # R5b: true-division grid in pallas_call
        if name == "jax.experimental.pallas.pallas_call":
            for kw in node.keywords:
                if kw.arg == "grid" and any(
                        isinstance(n, ast.BinOp)
                        and isinstance(n.op, ast.Div)
                        for n in ast.walk(kw.value)):
                    out.append(ctx.finding(
                        "R5", node,
                        "pallas_call grid uses true division `/` — a "
                        "non-divisible shape silently yields a float "
                        "grid; use `//` (with a divisibility guard) or "
                        "pl.cdiv"))
        # R5c: bf16 casts outside the precision policy
        if not in_precision:
            bf16 = _bf16_cast(ctx, node)
            if bf16 is not None:
                out.append(ctx.finding(
                    "R5", node,
                    f"direct bfloat16 cast `{_snippet(node)}` bypasses "
                    f"the precision policy — use "
                    f"core.precision.Policy.cast_compute so LN/readout/"
                    f"loss stay f32"))
        # R5d: kernel matmuls must pin an f32 accumulator
        if is_kernel_impl and name in (
                "jax.lax.dot_general", "jax.numpy.dot", "jax.numpy.einsum",
                "jax.numpy.matmul"):
            if not any(kw.arg == "preferred_element_type"
                       for kw in node.keywords):
                out.append(ctx.finding(
                    "R5", node,
                    f"kernel matmul `{_snippet(node)}` without "
                    f"preferred_element_type — bf16 inputs would "
                    f"accumulate in bf16 on the MXU"))
    return out


def _bf16_cast(ctx: RuleContext, node: ast.Call) -> Optional[str]:
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr == "astype" \
            and node.args:
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and arg.value == "bfloat16":
            return "bfloat16"
        parts = dotted(arg)
        if parts and parts[-1] == "bfloat16":
            return "bfloat16"
    for kw in node.keywords:
        if kw.arg == "dtype":
            if isinstance(kw.value, ast.Constant) \
                    and kw.value.value == "bfloat16":
                return "bfloat16"
            parts = dotted(kw.value)
            if parts and parts[-1] == "bfloat16":
                return "bfloat16"
    return None


ALL_CHECKS = (check_r1, check_r2, check_r3, check_r4, check_r5)


def run_rules(ctx: RuleContext) -> list[Finding]:
    findings: list[Finding] = []
    for check in ALL_CHECKS:
        findings.extend(check(ctx))
    return findings


def apply_waivers(findings: list[Finding], waivers: dict[int, set],
                  ctx: RuleContext) -> list[Finding]:
    """Drop findings waived on their line, the line above, or the
    enclosing def line."""
    def_lines: dict[str, int] = {
        info.qual: info.node.lineno for info in ctx.idx.functions.values()}
    kept = []
    for f in findings:
        lines = [f.line, f.line - 1]
        if f.symbol in def_lines:
            # on the def line or its own line just above -> function-wide
            lines.extend((def_lines[f.symbol], def_lines[f.symbol] - 1))
        waived = any(f.rule in waivers.get(ln, ()) or
                     "ALL" in waivers.get(ln, ()) for ln in lines)
        if not waived:
            kept.append(f)
    return kept
