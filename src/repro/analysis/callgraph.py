"""Module indexing + traced-region call graph for the invariant linter.

The linter's rules need to know, for every function in the repo, whether it
can execute under a JAX trace (R1 host-sync, R5 precision) and whether it
*launches* compiled work (the dispatch-loop taint analysis).  This module
builds that knowledge from the AST alone:

- :class:`ModuleIndex` parses one file and records every function
  (including nested defs and lambdas), resolves call targets through the
  import aliases and local scopes (``rgcn_mod.encode_packed`` ->
  ``repro.core.rgcn.encode_packed``, ``self._make_step`` ->
  ``Class._make_step``), and marks *trace entries*: functions decorated
  with / passed to ``jax.jit`` / ``vmap`` / ``lax.scan`` / ``pallas_call``
  and friends;
- :func:`build_graph` links the per-module indexes into one call graph and
  runs two fixed points: **traced** (a callee of a traced function is
  traced) and **dispatching** (a function that directly or transitively
  invokes a compiled executable).

Both properties deliberately over-approximate — a function reachable from
a traced region is treated as traced even if some call sites are host-only.
That is the point of the waiver syntax (``# lint: allow[R1] reason``): the
analysis stays sound and the human records why an exception is genuine.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Optional

#: callables whose function-valued arguments (or decorated functions) run
#: under a JAX trace
TRACERS = {
    "jax.jit",
    "jax.vmap",
    "jax.pmap",
    "jax.grad",
    "jax.value_and_grad",
    "jax.checkpoint",
    "jax.remat",
    "jax.custom_vjp",
    "jax.custom_jvp",
    "jax.lax.scan",
    "jax.lax.map",
    "jax.lax.fori_loop",
    "jax.lax.while_loop",
    "jax.lax.cond",
    "jax.lax.switch",
    "jax.lax.associative_scan",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
    "jax.shard_map",  # the experimental alias graduated to the jax namespace
}

#: fully-qualified fids of kernel-package entry points that must ALWAYS be
#: trace entries.  Decorator detection (functools.partial(jax.jit, ...) /
#: jax.custom_vjp) already finds these today; the explicit registry pins
#: them so a refactor of the decorator spelling can't silently drop a
#: Pallas launch out of the traced fixed point (R1/R5 would then stop
#: looking inside it).
KERNEL_ENTRIES = {
    "repro.kernels.rgcn_fused.kernel:rgcn_fused_flat_fwd",
    "repro.kernels.rgcn_fused.ops:rgcn_fused_agg_flat",
    "repro.kernels.rgcn_fused.ops:fused_two_level_readout",
}

#: fully-qualified fids of the trace->graph ingestion roots (the dual of
#: KERNEL_ENTRIES): these run the numpy RNG tracer on HOST threads — on
#: pool workers via ``pool.submit`` — and must NEVER become reachable from
#: a jit/scan/vmap trace (the tracer's bit-exact RNG stream contract dies
#: the moment it runs under a trace).  ``build_graph`` pins them as
#: ``host_entry`` and R1 flags any of them that the traced fixed point
#: reaches.  The ``.submit`` hop itself is a call edge (see visit_Call),
#: so the worker-side bodies stay inside the R1-R5 fixed points.
INGEST_ENTRIES = {
    "repro.ingest.engine:IngestEngine.iter_graphs",
    "repro.ingest.engine:IngestEngine._build_one",
    "repro.tracing.tracer:trace_kernel",
    "repro.tracing.tracer:trace_kernel_loop",
}

#: tracers whose FIRST positional argument is not the traced function
#: (the traced callable sits at these positions instead)
_TRACER_FN_POS = {
    "jax.lax.fori_loop": (2,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1,),
}


def dotted(node: ast.AST) -> Optional[list[str]]:
    """Flatten ``a.b.c`` into ``["a", "b", "c"]`` (None if not a pure
    name/attribute chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return parts[::-1]


@dataclass
class FunctionInfo:
    """One function/lambda, with everything the rules need to know."""

    fid: str                       # "repro.core.train:ContrastiveTrainer.fit"
    module: str
    path: str
    qual: str
    node: ast.AST                  # FunctionDef / AsyncFunctionDef / Lambda
    cls: Optional[str] = None      # enclosing class name, if a method
    calls: set = field(default_factory=set)          # resolved callee ids
    traced_entry: bool = False     # decorated with / passed to a tracer
    host_entry: bool = False       # registered host-only ingestion root
    lru_cached: bool = False       # functools.lru_cache/cache decorated
    returns_jit: bool = False      # returns a jax.jit(...) result
    donate_positions: tuple = ()   # donate_argnums of the returned jit
    traced: bool = False           # fixed-point result
    dispatching: bool = False      # fixed-point result


class ModuleIndex(ast.NodeVisitor):
    """Per-file AST index; see module docstring."""

    def __init__(self, path: str, module: str, tree: ast.Module):
        self.path = path
        self.module = module
        self.tree = tree
        self.functions: dict[str, FunctionInfo] = {}
        self.imports: dict[str, str] = {}
        #: attribute names ever assigned a jax.jit(...) result anywhere in
        #: the repo-wide scan (self._embed_fn, EngineFns(scan=...)); used as
        #: a tail-match fallback when full resolution fails
        self.jit_attrs: dict[str, tuple] = {}   # attr name -> donate positions
        #: resolution of every Call node's callee to a dotted string
        self.call_names: dict[ast.Call, Optional[str]] = {}
        #: pool.submit(fn, ...) call -> resolved worker fn (rules use this
        #: to treat a future of compiled work as a dispatch source)
        self.submit_targets: dict[ast.Call, Optional[str]] = {}
        #: per-function local names bound to jitted callables -> donate pos
        self.jit_locals: dict[str, dict[str, tuple]] = {}
        self._scopes: list[dict] = [{}]
        self._quals: list[str] = []
        self._cls: list[str] = []
        self._fn: list[FunctionInfo] = []
        self._prescan(tree)
        self.visit(tree)

    # -- symbol tables -------------------------------------------------------
    def _prescan(self, tree: ast.Module) -> None:
        """Module-level names must resolve regardless of definition order."""
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scopes[0][node.name] = ("func", node.name)
            elif isinstance(node, ast.ClassDef):
                self._scopes[0][node.name] = ("class", node.name)

    def _bind(self, name: str, ref: tuple) -> None:
        self._scopes[-1][name] = ref

    def _lookup(self, name: str) -> Optional[tuple]:
        for scope in reversed(self._scopes):
            if name in scope:
                return scope[name]
        if name in self.imports:
            return ("ext", self.imports[name])
        return None

    def _qual(self, name: str) -> str:
        return ".".join(self._quals + [name]) if self._quals else name

    def _fid(self, qual: str) -> str:
        return f"{self.module}:{qual}"

    # -- name resolution -----------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Resolve a callee expression to a dotted string: either an
        external path ("jax.lax.scan", "numpy.asarray") or a local id
        ("<module>:<qual>").  ``self.x`` resolves within the enclosing
        class; ``functools.partial(f, ...)`` unwraps to ``f``."""
        if isinstance(node, ast.Call):  # partial(f, ...) / jit(f) chains
            inner = self.resolve(node.func)
            if inner in ("functools.partial", "jax.jit", "jax.vmap",
                         "jax.pmap", "jax.checkpoint", "jax.remat"):
                for arg in node.args:
                    r = self.resolve(arg)
                    if r is not None:
                        return r
            return None
        parts = dotted(node)
        if parts is None:
            return None
        base, rest = parts[0], parts[1:]
        if base == "self" and self._cls and rest:
            return self._fid(f"{self._cls[-1]}.{rest[0]}")
        ref = self._lookup(base)
        if ref is None:
            return None
        kind, target = ref
        if kind == "ext":
            return ".".join([target] + rest)
        if kind == "func":
            return self._fid(target) if not rest else None
        if kind == "class":
            return self._fid(".".join([target] + rest)) if rest else None
        return None

    def _resolve_local_function(self, node: ast.AST) -> Optional[str]:
        """Like resolve(), but only returns ids of functions defined in
        this module (the targets tracer arguments may mark as traced)."""
        r = self.resolve(node)
        if r is not None and r.startswith(f"{self.module}:"):
            return r
        return None

    # -- visitors ------------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.imports[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None or node.level:
            return
        for a in node.names:
            self.imports[a.asname or a.name] = f"{node.module}.{a.name}"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._cls.append(node.name)
        self._quals.append(node.name)
        self._scopes.append({})
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scopes[-1][item.name] = (
                    "func", f"{node.name}.{item.name}")
        self.generic_visit(node)
        self._scopes.pop()
        self._quals.pop()
        self._cls.pop()

    def _enter_function(self, node, name: str) -> FunctionInfo:
        qual = self._qual(name)
        info = FunctionInfo(
            fid=self._fid(qual), module=self.module, path=self.path,
            qual=qual, node=node, cls=self._cls[-1] if self._cls else None)
        self.functions[qual] = info
        self.jit_locals[info.fid] = {}
        return info

    def _handle_decorators(self, node, info: FunctionInfo) -> None:
        for dec in node.decorator_list:
            name = self.resolve(dec.func if isinstance(dec, ast.Call)
                                else dec)
            if isinstance(dec, ast.Call) and name == "functools.partial" \
                    and dec.args:
                # functools.partial(jax.jit, static_argnames=...) decorator
                name = self.resolve(dec.args[0])
            if name in TRACERS:
                info.traced_entry = True
            if name in ("functools.lru_cache", "functools.cache"):
                info.lru_cached = True

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_function(node, node.name)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_function(node, node.name)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_function(node, f"<lambda:{node.lineno}>")

    def _visit_function(self, node, name: str) -> None:
        info = self._enter_function(node, name)
        if not isinstance(node, ast.Lambda):
            self._handle_decorators(node, info)
        if self._quals:  # nested defs resolve by name in the parent scope
            self._scopes[-1].setdefault(name, ("func", info.qual))
        self._quals.append(name)
        self._scopes.append({})
        self._fn.append(info)
        # prescan sibling-order-independent nested defs
        body = node.body if isinstance(node.body, list) else [node.body]
        for item in body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scopes[-1][item.name] = (
                    "func", f"{info.qual}.{item.name}")
        for item in body:
            self.visit(item)
        if not isinstance(node, ast.Lambda):
            self._finish_function(node, info)
        self._fn.pop()
        self._scopes.pop()
        self._quals.pop()

    def _finish_function(self, node, info: FunctionInfo) -> None:
        """Mark returns-jitted functions (their call results are compiled
        executables — dispatch/donation sources at the call site)."""
        locals_jit = self.jit_locals[info.fid]
        for ret in ast.walk(node):
            if not isinstance(ret, ast.Return) or ret.value is None:
                continue
            val = ret.value
            if isinstance(val, ast.Call) and self._is_jit_call(val):
                info.returns_jit = True
                info.donate_positions = self._donate_positions(val)
            elif isinstance(val, ast.Name) and val.id in locals_jit:
                info.returns_jit = True
                info.donate_positions = locals_jit[val.id]
            elif isinstance(val, ast.Attribute) and val.attr in self.jit_attrs:
                info.returns_jit = True
                info.donate_positions = self.jit_attrs[val.attr]

    # -- call / assignment analysis -----------------------------------------
    def _is_jit_call(self, node: ast.Call) -> bool:
        return self.resolve(node.func) == "jax.jit"

    @staticmethod
    def _donate_positions(node: ast.Call) -> tuple:
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                if isinstance(kw.value, ast.Tuple):
                    return tuple(e.value for e in kw.value.elts
                                 if isinstance(e, ast.Constant))
                if isinstance(kw.value, ast.Constant):
                    return (kw.value.value,)
        return ()

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        value = node.value
        if isinstance(value, ast.Call) and self._is_jit_call(value):
            donate = self._donate_positions(value)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and self._fn:
                    self.jit_locals[self._fn[-1].fid][tgt.id] = donate
                elif isinstance(tgt, ast.Attribute):
                    self.jit_attrs[tgt.attr] = donate
        # alias: name = other_local_function / partial(fn, ...)
        target_ref = None
        if isinstance(value, (ast.Name, ast.Attribute)):
            r = self._resolve_local_function(value)
            if r is not None:
                target_ref = ("func", r.split(":", 1)[1])
        elif isinstance(value, ast.Call):
            base = self.resolve(value.func)
            if base == "functools.partial" and value.args:
                r = self._resolve_local_function(value.args[0])
                if r is not None:
                    target_ref = ("func", r.split(":", 1)[1])
        if target_ref is not None:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self._bind(tgt.id, target_ref)

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        name = self.resolve(node.func)
        self.call_names[node] = name
        if self._fn:
            fn = self._fn[-1]
            if name is not None:
                fn.calls.add(name)
            # jit-attr construction through keywords:
            #   EngineFns(scan=jax.jit(chunk, donate_argnums=(0,)))
            for kw in node.keywords:
                if (kw.arg and isinstance(kw.value, ast.Call)
                        and self._is_jit_call(kw.value)):
                    self.jit_attrs[kw.arg] = self._donate_positions(kw.value)
        # worker-pool hop: pool.submit(fn, ...) runs fn on an executor
        # thread.  The pool object is an unresolvable local (name is None
        # here — our OWN .submit methods resolve above and keep their
        # normal edge), so record the worker fn as a callee: the traced /
        # dispatching fixed points then see through the executor instead
        # of losing the body at the thread boundary.
        if (name is None and node.args
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "submit"):
            target = self.resolve(node.args[0])
            self.submit_targets[node] = target
            if target is not None and self._fn:
                self._fn[-1].calls.add(target)
        if name in TRACERS:
            positions = _TRACER_FN_POS.get(name, (0,))
            for pos in positions:
                if pos < len(node.args):
                    self._mark_traced_target(node.args[pos])
            # jax.jit(f)(...) nests: inner vmap/partial calls get their own
            # visit, so only direct args need handling here

    def _mark_traced_target(self, arg: ast.AST) -> None:
        fid = None
        if isinstance(arg, ast.Lambda):
            fid = self._fid(self._qual(f"<lambda:{arg.lineno}>"))
        elif isinstance(arg, ast.Call):
            inner = self.resolve(arg.func)
            if inner == "functools.partial" and arg.args:
                fid = self._resolve_local_function(arg.args[0])
            elif inner in ("jax.vmap", "jax.jit", "jax.checkpoint",
                           "jax.remat") and arg.args:
                fid = self._resolve_local_function(arg.args[0])
        else:
            fid = self._resolve_local_function(arg)
        if fid is not None:
            qual = fid.split(":", 1)[1]
            if qual in self.functions:
                self.functions[qual].traced_entry = True


def index_module(path: str, module: str, source: str) -> ModuleIndex:
    return ModuleIndex(path, module, ast.parse(source, filename=path))


def build_graph(indexes: list[ModuleIndex]) -> dict[str, FunctionInfo]:
    """Link per-module indexes and run the traced/dispatching fixed points.
    Returns the global fid -> FunctionInfo map (mutated in place)."""
    funcs: dict[str, FunctionInfo] = {}
    modnames = set()
    for idx in indexes:
        modnames.add(idx.module)
        for info in idx.functions.values():
            funcs[info.fid] = info
    for fid in KERNEL_ENTRIES:      # registered kernel launches (see above)
        if fid in funcs:
            funcs[fid].traced_entry = True
    for fid in INGEST_ENTRIES:      # registered host-only ingestion roots
        if fid in funcs:
            funcs[fid].host_entry = True

    def to_fid(callee: str) -> Optional[str]:
        """Map a resolved dotted path to a known function id."""
        if callee in funcs:
            return callee
        if ":" in callee:
            return None
        # external-style path into a scanned module: repro.core.rgcn.encode
        parts = callee.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            mod = ".".join(parts[:cut])
            if mod in modnames:
                fid = f"{mod}:{'.'.join(parts[cut:])}"
                return fid if fid in funcs else None
        return None

    edges: dict[str, set] = {}
    for info in funcs.values():
        edges[info.fid] = set()
        for callee in info.calls:
            fid = to_fid(callee)
            if fid is not None:
                edges[info.fid].add(fid)

    # traced: trace entries + everything they (transitively) call
    work = [f.fid for f in funcs.values() if f.traced_entry]
    for fid in work:
        funcs[fid].traced = True
    while work:
        fid = work.pop()
        for callee in edges[fid]:
            if not funcs[callee].traced:
                funcs[callee].traced = True
                work.append(callee)

    # dispatching: launches compiled work (directly or transitively)
    jit_attr_names = set()
    for idx in indexes:
        jit_attr_names.update(idx.jit_attrs)
    for idx in indexes:
        for info in idx.functions.values():
            if info.dispatching:
                continue
            for callee in info.calls:
                fid = to_fid(callee)
                if fid is not None and (funcs[fid].traced_entry
                                        or funcs[fid].returns_jit):
                    info.dispatching = True
                    break
    changed = True
    while changed:
        changed = False
        for info in funcs.values():
            if info.dispatching:
                continue
            for callee in edges[info.fid]:
                if funcs[callee].dispatching:
                    info.dispatching = True
                    changed = True
                    break
    return funcs
