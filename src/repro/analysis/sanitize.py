"""Runtime sanitizers for the compiled engines (DESIGN.md §10).

Two checks that the static linter cannot prove but the process can assert:

- :func:`recompile_guard` — a context manager that snapshots the engine
  compile counters (``repro.core.clustering.ENGINE_STATS["builds"]`` and
  the train engine's ``_engine_fns`` lru_cache misses) and raises
  :class:`RecompileError` if the guarded region built more executables
  than its budget (0 on warm serving/training paths).

- :func:`check_finite` / :func:`nan_tripwire` — a NaN/inf tripwire that
  walks arbitrary result trees (dicts, dataclasses like ``Plan`` /
  ``Artifacts``, numpy or jax arrays) and raises :class:`NonFiniteError`
  naming the offending path.  ``nan_tripwire(fn)`` wraps ``fit`` /
  ``plan_many`` style callables; ``PlanService(..., sanitize=True)`` wires
  it into the dispatcher.

Both are cheap enough for tests and smoke CI; the tripwire syncs results
to host, so keep it off hot production paths.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Iterator, Optional

import numpy as np


class RecompileError(RuntimeError):
    """A guarded region built more executables than its budget."""


class NonFiniteError(ValueError):
    """A guarded result contained NaN or inf."""


def _train_misses() -> int:
    """Current build count of the train engine's executable cache."""
    from repro.core import train as train_mod

    return train_mod._engine_fns.cache_info().misses


@dataclasses.dataclass
class GuardStats:
    """Filled in when the :func:`recompile_guard` block exits."""

    cluster_builds: int = 0
    train_builds: int = 0

    @property
    def builds(self) -> int:
        return self.cluster_builds + self.train_builds


@contextlib.contextmanager
def recompile_guard(max_builds: int = 0, *, include_train: bool = True,
                    label: str = "warm path") -> Iterator[GuardStats]:
    """Assert the region compiles at most ``max_builds`` new executables.

    Counts builds of the clustering/plan sweep engine (``ENGINE_STATS``)
    plus, when ``include_train``, the train engine cache.  Use around warm
    serving or resumed-training regions where every executable should
    already exist::

        service.warmup(specs)
        with recompile_guard():          # 0 new builds allowed
            service.plan(xs)
    """
    from repro.core import clustering

    cluster_start = clustering.ENGINE_STATS["builds"]
    train_start = _train_misses() if include_train else 0
    stats = GuardStats()
    try:
        yield stats
    finally:
        stats.cluster_builds = (
            clustering.ENGINE_STATS["builds"] - cluster_start)
        stats.train_builds = (
            (_train_misses() - train_start) if include_train else 0)
    if stats.builds > max_builds:
        raise RecompileError(
            f"recompile guard tripped on {label}: {stats.builds} new "
            f"executable build(s) (cluster={stats.cluster_builds}, "
            f"train={stats.train_builds}) exceed the budget of "
            f"{max_builds} — warm the pool first (PlanEngine.warmup / "
            f"clustering.warm_sweep) or raise max_builds")


def _is_float_array(x: Any) -> bool:
    dtype = getattr(x, "dtype", None)
    if dtype is None:
        return isinstance(x, float)
    try:
        return np.issubdtype(np.dtype(dtype), np.inexact)
    except TypeError:
        return False


def _walk(obj: Any, path: str, seen: set) -> Iterator[tuple]:
    if id(obj) in seen:
        return
    if isinstance(obj, dict):
        seen.add(id(obj))
        for k, v in obj.items():
            yield from _walk(v, f"{path}[{k!r}]", seen)
    elif isinstance(obj, (list, tuple)):
        seen.add(id(obj))
        for i, v in enumerate(obj):
            yield from _walk(v, f"{path}[{i}]", seen)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        seen.add(id(obj))
        for f in dataclasses.fields(obj):
            yield from _walk(getattr(obj, f.name), f"{path}.{f.name}", seen)
    elif _is_float_array(obj) or isinstance(obj, float):
        yield path, obj


def check_finite(obj: Any, name: str = "result") -> None:
    """Raise :class:`NonFiniteError` if any float leaf of ``obj`` holds
    NaN/inf.  Walks dicts, sequences, dataclasses, numpy and jax arrays
    (device arrays are synced to host — sanitizer cost, not hot-path)."""
    for path, leaf in _walk(obj, name, set()):
        arr = np.asarray(leaf)
        if arr.size and not np.isfinite(arr).all():
            bad = int(arr.size - np.isfinite(arr).sum())
            raise NonFiniteError(
                f"non-finite values in {path}: {bad}/{arr.size} element(s) "
                f"are NaN/inf (dtype={arr.dtype}, shape={arr.shape})")


def nan_tripwire(fn: Optional[Callable] = None, *,
                 name: Optional[str] = None) -> Callable:
    """Wrap a callable so its return value is checked by
    :func:`check_finite`.  Usable bare or as a decorator::

        plan = nan_tripwire(engine.plan_many)
        @nan_tripwire
        def fit(...): ...
    """
    if fn is None:
        return functools.partial(nan_tripwire, name=name)
    label = name or getattr(fn, "__qualname__", repr(fn))

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        out = fn(*args, **kwargs)
        check_finite(out, name=f"{label}(...)")
        return out

    return wrapped
