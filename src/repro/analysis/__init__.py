"""repro.analysis — invariant linter + runtime sanitizers (DESIGN.md §10).

The compiled engines (train scan epochs, the multi-K plan sweep, warm
serving) advertise invariants that plain pytest cannot see: zero recompiles
on warm paths, no host syncs inside traced regions or dispatch hot loops,
fold-in RNG discipline, donation safety, and Pallas/precision conformance.
This package machine-checks them two ways:

- **statically** — ``python -m repro.analysis.lint`` runs an AST pass over
  ``src/repro`` with repo-specific rules R1–R5 (:mod:`repro.analysis.rules`),
  a call-graph that knows which functions are jit/scan/vmap-traced
  (:mod:`repro.analysis.callgraph`), inline waivers
  (``# lint: allow[R1] reason``) and a checked-in baseline
  (``baseline.json``) so accepted findings never fail CI while any NEW
  finding does;
- **at runtime** — :mod:`repro.analysis.sanitize` provides a
  :func:`~repro.analysis.sanitize.recompile_guard` context manager
  (asserts a build budget against the engine compile counters) and a
  NaN/inf :func:`~repro.analysis.sanitize.check_finite` /
  :func:`~repro.analysis.sanitize.nan_tripwire` wrappable around
  ``fit`` / ``plan_many`` (and optionally ``PlanService``).
"""

from __future__ import annotations

from repro.analysis.rules import Finding, RULES
from repro.analysis.sanitize import (
    NonFiniteError, RecompileError, check_finite, nan_tripwire,
    recompile_guard,
)

__all__ = [
    "Finding",
    "RULES",
    "NonFiniteError",
    "RecompileError",
    "check_finite",
    "nan_tripwire",
    "recompile_guard",
]
