"""CLI driver for the invariant linter: ``python -m repro.analysis.lint``.

Scans ``src/repro`` (or the given paths), runs rules R1–R5 over a repo-wide
call graph, drops ``# lint: allow[...]`` waivers, and diffs the remaining
findings against the checked-in baseline (``src/repro/analysis/
baseline.json``).  Exit status is 0 iff the run matches the baseline
exactly — any NEW finding fails, and so does a STALE baseline entry (a
finding that was fixed but not removed from the baseline, which keeps the
baseline honest).

Usage:
    python -m repro.analysis.lint                 # diff vs baseline
    python -m repro.analysis.lint --json          # machine-readable output
    python -m repro.analysis.lint --check-baseline  # explicit CI mode
    python -m repro.analysis.lint --write-baseline  # accept current findings
    python -m repro.analysis.lint --no-baseline src/repro/core  # raw report

Baseline identity is line-insensitive (rule, path, symbol, message), so
unrelated edits never churn it; the file stores a count per key so adding a
*second* instance of an accepted finding still fails.
"""

from __future__ import annotations

import argparse
import ast
import json
import sys
from collections import Counter
from pathlib import Path
from typing import Optional

from repro.analysis.callgraph import ModuleIndex, build_graph
from repro.analysis.rules import (
    Finding, RuleContext, apply_waivers, parse_waivers, run_rules,
)

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"
BASELINE_VERSION = 1


def module_name_for(path: Path, root: Optional[Path] = None) -> str:
    """Derive the import path: src/repro/core/train.py -> repro.core.train.
    Files outside a src/ tree fall back to their stem."""
    parts = path.with_suffix("").parts
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    elif root is not None:
        try:
            parts = path.with_suffix("").relative_to(root).parts
        except ValueError:
            parts = (path.stem,)
    else:
        parts = (path.stem,)
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def _rel(path: Path) -> str:
    try:
        return path.resolve().relative_to(Path.cwd()).as_posix()
    except ValueError:
        return path.as_posix()


def collect_files(paths: list) -> list:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: list, *, module_names: Optional[dict] = None
               ) -> list[Finding]:
    """Index every file, link the call graph, run all rules, apply
    waivers.  ``module_names`` optionally overrides path -> module."""
    indexes: list[ModuleIndex] = []
    sources: dict[str, str] = {}
    for path in collect_files(paths):
        source = path.read_text()
        rel = _rel(path)
        module = (module_names or {}).get(rel) or module_name_for(path)
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as exc:
            raise SystemExit(f"lint: cannot parse {rel}: {exc}") from exc
        indexes.append(ModuleIndex(rel, module, tree))
        sources[rel] = source
    funcs = build_graph(indexes)
    # register cross-module dotted aliases (repro.core.rgcn.encode_packed)
    # alongside the canonical fids (repro.core.rgcn:encode_packed)
    by_name = dict(funcs)
    for fid, info in funcs.items():
        mod, qual = fid.split(":", 1)
        by_name.setdefault(f"{mod}.{qual}", info)
    jit_attrs: dict[str, tuple] = {}
    for idx in indexes:
        jit_attrs.update(idx.jit_attrs)
    findings: list[Finding] = []
    for idx in indexes:
        ctx = RuleContext(idx, by_name, jit_attrs)
        raw = run_rules(ctx)
        waivers = parse_waivers(sources[idx.path])
        findings.extend(apply_waivers(raw, waivers, ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def load_baseline(path: Path) -> Counter:
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise SystemExit(
            f"lint: unsupported baseline version in {path}: "
            f"{data.get('version')!r}")
    return Counter(data.get("findings", {}))


def write_baseline(path: Path, findings: list) -> None:
    counts = Counter(f.key for f in findings)
    payload = {
        "version": BASELINE_VERSION,
        "findings": {k: counts[k] for k in sorted(counts)},
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def diff_baseline(findings: list, baseline: Counter):
    """Split findings into (new, accepted) and report stale baseline keys."""
    counts = Counter(f.key for f in findings)
    budget = Counter(baseline)
    new: list[Finding] = []
    accepted: list[Finding] = []
    for f in findings:
        if budget[f.key] > 0:
            budget[f.key] -= 1
            accepted.append(f)
        else:
            new.append(f)
    stale = sorted(k for k in baseline if baseline[k] > counts.get(k, 0))
    return new, accepted, stale


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX/Pallas invariant linter (rules R1-R5)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to scan (default: src/repro)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit machine-readable JSON")
    ap.add_argument("--baseline", type=Path, default=BASELINE_PATH,
                    help=f"baseline file (default: {BASELINE_PATH.name} "
                         f"next to this module)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="CI mode: fail on any new or stale finding "
                         "(also the default when a baseline exists)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings as the baseline")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline; report everything")
    args = ap.parse_args(argv)

    paths = args.paths or ["src/repro"]
    findings = lint_paths(paths)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"lint: wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = Counter() if args.no_baseline else load_baseline(args.baseline)
    new, accepted, stale = diff_baseline(findings, baseline)

    if args.as_json:
        accepted_ids = {id(f) for f in accepted}
        payload = {
            "findings": [{
                "rule": f.rule, "path": f.path, "line": f.line,
                "col": f.col, "symbol": f.symbol, "message": f.message,
                "key": f.key, "baselined": id(f) in accepted_ids,
            } for f in findings],
            "stale_baseline": stale,
            "summary": {"total": len(findings), "new": len(new),
                        "baselined": len(accepted), "stale": len(stale)},
        }
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f.render())
        for key in stale:
            print(f"stale baseline entry (fixed? remove it): {key}")
        print(f"lint: {len(findings)} finding(s) — {len(accepted)} "
              f"baselined, {len(new)} new, {len(stale)} stale")

    strict = args.check_baseline or not args.no_baseline
    if strict and (new or stale):
        return 1
    if args.no_baseline and findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
