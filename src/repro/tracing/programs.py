"""The paper's 11 workloads (PolyBench / Rodinia / Tango / LLM) as synthetic
kernel-invocation streams, plus ``lm_program`` which derives a workload from
ANY assigned architecture config (the framework-integration path: the LM zoo
is the simulation subject, exactly like the paper's qwen1.5/phi-2/pythia).

Program structure encodes the behaviors the paper's evaluation hinges on:
- nw:   255 invocations with DISTINCT names, 2 behavior groups
        (name-based methods find no reduction; GCL-Sampler finds 2 clusters)
- lu:   2225 near-identical invocations with distinct names (massive speedup)
- 3mm:  9 invocations, distinct names, 3 shape groups
- AlexNet: two conv layers with ~equal instruction counts but different
        cache behavior (Sieve's instruction-count signature fails)
- backprop: 2 singleton kernels (no reduction opportunity; speedup 1x)
- phi-2: attention kernels whose library algorithm differs per platform
        (cuDNN heuristic quirk -> Table 3 cross-arch anomaly)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


from repro.tracing.templates import make_kernel
from repro.utils.registry import Registry


@dataclass
class Program:
    name: str
    kernels: list
    # extra content folded into `program_fingerprint` — generated programs
    # (repro.workloads) put their ScenarioSpec hash there so two same-named
    # programs built from different specs/seeds never share artifact keys,
    # and model-zoo programs record their trace window there (a caps change
    # must never replay another window's artifacts)
    fingerprint_extra: str = ""
    # default (cap_warps, cap_instr) trace window for this program; None =
    # the repo-wide defaults (repro.config).  Model-zoo programs carry
    # 10-100x larger windows here (resolve_trace_caps consults it).
    trace_caps: Optional[tuple] = None

    def __len__(self):
        return len(self.kernels)


# name -> zero-arg builder; the paper suite registers below.  Generated
# scenario programs need no registration: their `scn:` names resolve
# lazily in get_program (the name IS the spec)
PROGRAMS: Registry = Registry("program")


def _build_nw():
    # Two behavior groups with IDENTICAL instruction mix / count / grid
    # (PKA's feature space cannot separate them) but different spatial
    # locality: group 0 reuses cache lines (stride 32), group 1 streams
    # (stride 512, no reuse) -> different cycles.  The HRG sees the reuse as
    # shared memory-variable nodes.  All 255 invocation names are distinct
    # (name-keyed methods find no reduction).
    ks = []
    for i in range(255):
        which = i % 2
        params = (
            {"nx": 2048, "ny": 16, "pts": 5, "iters": 8,
             "stride": 32, "reuse": 4.0, "ilp": 3.0}
            if which == 0
            else {"nx": 2048, "ny": 16, "pts": 5, "iters": 8,
                  "stride": 512, "reuse": 1.0, "ilp": 3.0}
        )
        ks.append(
            make_kernel(
                f"needle_cuda_shared_{which + 1}_diag{i}", "stencil", params,
                i, seed=7,
            )
        )
    return Program("nw", ks)


def _build_lu():
    ks = []
    for i in range(2225):
        ks.append(
            make_kernel(
                f"lu_kernel_step{i}", "gemv",
                {"n": 2048, "m": 2048}, i, seed=11,
            )
        )
    return Program("lu", ks)


def _build_3mm():
    ks = []
    shapes = [
        ("mm3_kernel_E", {"M": 512, "N": 512, "K": 512}),
        ("mm3_kernel_F", {"M": 512, "N": 512, "K": 1024}),
        ("mm3_kernel_G", {"M": 512, "N": 1024, "K": 512}),
    ]
    seq = 0
    for run in range(3):
        for nm, p in shapes:
            ks.append(make_kernel(f"{nm}_run{run}", "gemm", p, seq, seed=13))
            seq += 1
    return Program("3mm", ks)


def _build_bfs():
    ks = []
    frontier = 256
    seq = 0
    for it in range(13):
        for which in range(2):
            ks.append(
                make_kernel(
                    "Kernel" if which == 0 else "Kernel2", "traversal",
                    {"nodes": 1_000_000, "degree": 8,
                     "frontier": int(frontier), "divergence": 0.4},
                    seq, seed=17,
                )
            )
            seq += 1
        frontier = frontier * 4 if it < 5 else max(frontier // 3, 64)
    return Program("bfs", ks)


def _build_cfd():
    ks = []
    seq = 0
    kinds = [
        ("cuda_compute_step_factor", "elementwise", {"n": 97_000 * 4, "nops": 6, "iters": 4}),
        ("cuda_compute_flux", "stencil", {"nx": 97_000, "ny": 4, "pts": 9, "iters": 16}),
        ("cuda_time_step", "elementwise", {"n": 97_000 * 4, "nops": 3, "iters": 4}),
        ("cuda_initialize_variables", "elementwise", {"n": 97_000 * 4, "nops": 1, "iters": 2}),
    ]
    for it in range(606):
        for nm, tmpl, p in kinds:
            ks.append(make_kernel(nm, tmpl, p, seq, seed=19))
            seq += 1
    ks.append(
        make_kernel("memset_like", "elementwise",
                    {"n": 97_000, "nops": 1, "iters": 1}, seq, seed=19)
    )
    return Program("cfd", ks)


def _build_lud():
    """40 decomposition steps whose launch geometry shrinks in quantized
    plateaus (the scheduler reuses tile configurations), so each name has a
    few repeated size groups.  PKA's z-scored feature space collapses here:
    the instruction MIX is identical across all gemm kernels, leaving a
    near-1-D instruction-count axis whose silhouette prefers 2-3 coarse
    clusters -> large reconstruction error (the paper's 60.8% lud failure);
    the HRG sees per-group footprints/strides and separates exactly."""
    ks = []
    seq = 0
    sizes = [2048, 1536, 1024, 512]
    for step in range(40):
        rem = sizes[step // 10]
        ks.append(make_kernel("lud_diagonal", "gemm",
                              {"M": 64, "N": 64, "K": 64}, seq, seed=23))
        seq += 1
        ks.append(make_kernel("lud_perimeter", "gemm",
                              {"M": rem, "N": 128, "K": 64}, seq, seed=23))
        seq += 1
        ks.append(make_kernel("lud_internal", "gemm",
                              {"M": rem, "N": rem, "K": 64}, seq, seed=23))
        seq += 1
    return Program("lud", ks)


def _build_backprop():
    # Same template, same instruction mix AND total count — but one kernel is
    # a 1-CTA latency-bound reduction and the other a 576-CTA streaming pass.
    # PKA's microarch-independent features are identical -> it merges them
    # (the paper's 55.2% backprop error); the traces differ structurally
    # (S2R ctaid values, loop trip counts), so GCL-Sampler separates them.
    ks = [
        make_kernel("bpnn_layerforward_CUDA", "gemv",
                    {"n": 16, "m": 147_456, "acc_regs": 1}, 0, seed=29),
        make_kernel("bpnn_adjust_weights_cuda", "gemv",
                    {"n": 36_864, "m": 256, "acc_regs": 2}, 1, seed=29),
    ]
    return Program("backprop", ks)


def _build_alexnet():
    """All convolutions run under the SAME library kernel name (the cuDNN
    reality).  conv2 (implicit-gemm) and conv3 (winograd) are tuned to ~equal
    dynamic instruction counts with very different ILP behavior — Sieve's
    instruction-count signature merges them (the paper's 29.2% AlexNet
    error); GCL-Sampler sees the different loop bodies."""
    ks = []
    seq = 0
    layers = [
        ("implicit_convolve_sgemm", "conv", {"c": 3, "hw": 55, "k": 96, "r": 11}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 96 * 55 * 55, "nops": 1, "iters": 2}),
        ("pooling_fw_4d_kernel", "stencil", {"nx": 96 * 27, "ny": 27, "pts": 9, "iters": 4}),
        # conv2: implicit gemm, 15-instr body x 75 iters x 680 CTAs
        # (convs dominate AlexNet runtime, as on real hardware)
        ("implicit_convolve_sgemm", "conv",
         {"c": 96, "hw": 27, "k": 256, "r": 5, "ctas": 2000}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 256 * 27 * 27, "nops": 1, "iters": 2}),
        ("pooling_fw_4d_kernel", "stencil", {"nx": 256 * 13, "ny": 13, "pts": 9, "iters": 4}),
        # conv3: winograd, 12-instr body x 93 iters x 680 CTAs (~equal count,
        # very different ILP -> Sieve's instruction-count signature merges
        # two kernels whose cycles differ ~2x)
        ("implicit_convolve_sgemm", "conv",
         {"c": 186, "hw": 13, "k": 256, "r": 4, "ctas": 2000, "algo": "winograd"}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 384 * 13 * 13, "nops": 1, "iters": 2}),
        ("implicit_convolve_sgemm", "conv", {"c": 384, "hw": 13, "k": 384, "r": 3}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 384 * 13 * 13, "nops": 1, "iters": 2}),
        ("implicit_convolve_sgemm", "conv", {"c": 384, "hw": 13, "k": 256, "r": 3}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 256 * 13 * 13, "nops": 1, "iters": 2}),
        ("pooling_fw_4d_kernel", "stencil", {"nx": 256 * 6, "ny": 6, "pts": 9, "iters": 4}),
        ("ampere_sgemm_fc", "gemm", {"M": 128, "N": 4096, "K": 9216}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 4096 * 128, "nops": 1, "iters": 2}),
        ("ampere_sgemm_fc", "gemm", {"M": 128, "N": 4096, "K": 4096}),
        ("activation_fw_4d_kernel", "elementwise", {"n": 4096 * 128, "nops": 1, "iters": 2}),
        ("ampere_sgemm_fc", "gemm", {"M": 128, "N": 1000, "K": 4096}),
        ("softmax_fw_kernel", "softmax", {"rows": 128, "cols": 1000}),
    ]
    for nm, tmpl, p in layers:
        ks.append(make_kernel(nm, tmpl, p, seq, seed=31))
        seq += 1
    # training-style backward pass (wgrad/dgrad kernels reuse the shapes)
    for nm, tmpl, p in layers:
        ks.append(make_kernel(f"{nm}_wgrad", tmpl, p, seq, seed=31))
        seq += 1
    return Program("AlexNet", ks)


# ---------------------------------------------------------------------------
# LLM programs
# ---------------------------------------------------------------------------


def _lm_layer_kernels(prefix, d_model, d_ff, n_heads, seq_len, decode,
                      seq_start, seed, attn_algo="implicit_gemm",
                      moe=None, mamba=None):
    """Kernel stream for one transformer layer step."""
    ks = []
    s = seq_start
    T = 1 if decode else seq_len
    gem = "gemv" if decode else "gemm"

    def gemm_p(m, n, k):
        return {"n": n, "m": k} if decode else {"M": max(m, 64), "N": n, "K": k}

    ks.append(make_kernel(f"{prefix}_rmsnorm", "softmax",
                          {"rows": T, "cols": d_model}, s, seed)); s += 1
    if mamba is not None:
        din = mamba["d_inner"]
        ks.append(make_kernel(f"vectorized_elementwise_conv", "elementwise",
                              {"n": T * din, "nops": 4, "iters": 4}, s, seed)); s += 1
        ks.append(make_kernel(f"cutlass_80_ssd_{din}x{d_model}", gem,
                              gemm_p(T, 2 * din, d_model), s, seed)); s += 1
        ks.append(make_kernel(f"ssd_chunk_scan", "reduction",
                              {"n": T * din}, s, seed)); s += 1
        ks.append(make_kernel(f"cutlass_80_out_{d_model}x{din}", gem,
                              gemm_p(T, d_model, din), s, seed)); s += 1
    else:
        ks.append(make_kernel(f"cutlass_80_tensorop_qkv_{d_model}", gem,
                              gemm_p(T, 3 * d_model, d_model), s, seed)); s += 1
        ks.append(make_kernel(f"{attn_algo}_attention_fwd", "conv",
                              {"c": n_heads, "hw": min(seq_len, 128), "k": 64,
                               "r": 3, "algo": attn_algo}, s, seed)); s += 1
        ks.append(make_kernel(f"softmax_warp_fwd", "softmax",
                              {"rows": T * n_heads, "cols": seq_len}, s, seed)); s += 1
        ks.append(make_kernel(f"cutlass_80_tensorop_o_{d_model}", gem,
                              gemm_p(T, d_model, d_model), s, seed)); s += 1
    if moe is not None:
        E, topk = moe["experts"], moe["top_k"]
        ks.append(make_kernel("moe_router_topk", "softmax",
                              {"rows": T, "cols": E}, s, seed)); s += 1
        ks.append(make_kernel(f"grouped_gemm_moe_{d_ff}", gem,
                              gemm_p(T * topk // max(E // 4, 1), d_ff, d_model), s, seed)); s += 1
        ks.append(make_kernel(f"grouped_gemm_moe_down_{d_ff}", gem,
                              gemm_p(T * topk // max(E // 4, 1), d_model, d_ff), s, seed)); s += 1
    elif d_ff > 0:
        ks.append(make_kernel(f"cutlass_80_tensorop_ffn_up_{d_ff}", gem,
                              gemm_p(T, d_ff, d_model), s, seed)); s += 1
        ks.append(make_kernel(f"cutlass_80_tensorop_ffn_down_{d_ff}", gem,
                              gemm_p(T, d_model, d_ff), s, seed)); s += 1
    return ks, s


def _build_llm(name, layers, d_model, d_ff, n_heads, steps, seq_len, seed,
               platform_sensitive=False):
    ks = []
    s = 0
    for step in range(steps):
        decode = step > 0  # step 0 = prefill, rest = decode
        algo = "cudnn_heuristic" if platform_sensitive else "implicit_gemm"
        for layer in range(layers):
            lk, s = _lm_layer_kernels(
                f"L{layer}", d_model, d_ff, n_heads, seq_len, decode, s, seed,
                attn_algo=algo,
            )
            ks.extend(lk)
        ks.append(make_kernel("lm_head_logits", "gemv" if decode else "gemm",
                              {"n": 50_000, "m": d_model} if decode
                              else {"M": max(seq_len, 64), "N": 50_000, "K": d_model},
                              s, seed)); s += 1
    for k in ks:
        k.seq = ks.index(k) if False else k.seq  # seq already assigned
    # re-sequence deterministically
    for i, k in enumerate(ks):
        k.seq = i
    return Program(name, ks)


def _build_qwen15():
    return _build_llm("qwen1.5", layers=24, d_model=2048, d_ff=5504,
                      n_heads=16, steps=4, seq_len=512, seed=37)


def _build_phi2():
    return _build_llm("phi-2", layers=32, d_model=2560, d_ff=10240,
                      n_heads=32, steps=5, seq_len=512, seed=41,
                      platform_sensitive=True)


def _build_pythia():
    return _build_llm("pythia", layers=24, d_model=2048, d_ff=8192,
                      n_heads=16, steps=5, seq_len=512, seed=43)


_BUILDERS = {
    "nw": _build_nw, "lu": _build_lu, "3mm": _build_3mm, "bfs": _build_bfs,
    "cfd": _build_cfd, "lud": _build_lud, "backprop": _build_backprop,
    "AlexNet": _build_alexnet, "qwen1.5": _build_qwen15,
    "phi-2": _build_phi2, "pythia": _build_pythia,
}
for _name, _builder in _BUILDERS.items():
    PROGRAMS.add(_name, _builder)

PAPER_PROGRAMS = list(_BUILDERS)


def _model_builder(name):
    def build():
        from repro.workloads.modelzoo import model_program

        return model_program(name)
    return build


# the model-zoo trace-pack grid (repro.workloads.modelzoo) — registered with
# lazy builders so the names list in PROGRAMS without importing configs; the
# grid mirrors modelzoo.MODEL_ZOO x modelzoo.PHASES (asserted by its tests)
MODEL_ZOO_PROGRAMS = [
    f"model:{_a}:{_p}"
    for _a in ("llama3.2-3b", "mamba2-780m", "dbrx-132b")
    for _p in ("prefill", "decode")
]
for _name in MODEL_ZOO_PROGRAMS:
    PROGRAMS.add(_name, _model_builder(_name))

_cache: dict = {}


def get_program(name: str) -> Program:
    if name.startswith("scn:"):
        # generated scenario programs (repro.workloads) resolve lazily: the
        # name IS the spec, no pre-registration needed.  Deliberately NOT
        # memoized — the scn: name space is open-ended (a large scenario
        # matrix would pin every generated Program for the process
        # lifetime) and build_scenario is cheap and deterministic.
        from repro.workloads import scenario_program

        return scenario_program(name)
    if name not in _cache:
        if name in PROGRAMS:
            _cache[name] = PROGRAMS.get(name)()
        elif name.startswith("lm:"):
            _cache[name] = lm_program(name[3:])
        elif name.startswith("model:"):
            from repro.workloads.modelzoo import model_program

            _cache[name] = model_program(name)
        else:
            raise KeyError(f"unknown program {name!r}")
    return _cache[name]


def lm_program(arch_id: str, steps: int = 3, seq_len: int = 512) -> Program:
    """Derive a sampled-simulation workload from an assigned architecture
    config — the paper's LLM-workload path applied to the model zoo."""
    from repro.config import FFN_MOE, MIXER_MAMBA2
    from repro.configs import get_arch

    cfg = get_arch(arch_id)
    ks = []
    s = 0
    for step in range(steps):
        decode = step > 0
        for layer in range(cfg.num_layers):
            spec = cfg.layer_specs()[layer % cfg.block_size]
            moe = (
                {"experts": cfg.num_experts, "top_k": cfg.top_k}
                if spec.ffn == FFN_MOE else None
            )
            mamba = (
                {"d_inner": cfg.d_inner} if spec.mixer == MIXER_MAMBA2 else None
            )
            lk, s = _lm_layer_kernels(
                f"L{layer}", cfg.d_model, cfg.d_ff, max(cfg.num_heads, 1),
                seq_len, decode, s, seed=101, moe=moe, mamba=mamba,
            )
            ks.extend(lk)
    for i, k in enumerate(ks):
        k.seq = i
    return Program(f"lm:{arch_id}", ks)
