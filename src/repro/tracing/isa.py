"""SASS-like ISA for the synthetic trace substrate.

The trace format follows the paper's Table 1 per-instruction record:
CTA coords, warp id, PC, active mask, dest regs, opcode, src regs, memory
width, dynamic values.  Opcodes are grouped into classes consumed by the
timing model (instruction mix) and used as token IDs by the HRG features.
"""

from __future__ import annotations

# opcode -> (class, typical latency cycles, flops per lane)
OPCODES: dict[str, tuple[str, int, int]] = {
    # memory
    "LDG": ("mem_load", 400, 0),     # global load
    "STG": ("mem_store", 40, 0),     # global store
    "LDS": ("smem", 30, 0),          # shared load
    "STS": ("smem", 30, 0),          # shared store
    "LDC": ("mem_load", 100, 0),     # constant load
    "RED": ("mem_store", 400, 0),    # global reduction (atomic)
    # fp32
    "FADD": ("fp", 4, 1),
    "FMUL": ("fp", 4, 1),
    "FFMA": ("fp", 4, 2),
    "FSETP": ("fp", 4, 0),
    "MUFU": ("sfu", 16, 1),          # special function (exp/rsqrt/sin)
    # fp16 / tensor
    "HMMA": ("tensor", 16, 128),     # tensor-core MMA (per-lane amortized)
    "HFMA2": ("fp", 4, 4),
    # int / logic
    "IADD3": ("alu", 4, 0),
    "IMAD": ("alu", 5, 0),
    "ISETP": ("alu", 4, 0),
    "LOP3": ("alu", 4, 0),
    "SHF": ("alu", 4, 0),
    "MOV": ("alu", 2, 0),
    "S2R": ("alu", 8, 0),
    "I2F": ("alu", 8, 0),
    # control / sync
    "BRA": ("control", 8, 0),
    "EXIT": ("control", 4, 0),
    "BAR": ("barrier", 30, 0),
    "SHFL": ("shuffle", 10, 0),      # warp shuffle
}

OPCODE_LIST = sorted(OPCODES)
OPCODE_IDS = {op: i for i, op in enumerate(OPCODE_LIST)}
NUM_OPCODES = len(OPCODE_LIST)

INSTR_CLASSES = sorted({cls for cls, _, _ in OPCODES.values()})
CLASS_IDS = {c: i for i, c in enumerate(INSTR_CLASSES)}

OPCODE_CLASS = {OPCODE_IDS[op]: CLASS_IDS[cls] for op, (cls, _, _) in OPCODES.items()}
OPCODE_LATENCY = {OPCODE_IDS[op]: lat for op, (_, lat, _) in OPCODES.items()}
OPCODE_FLOPS = {OPCODE_IDS[op]: fl for op, (_, _, fl) in OPCODES.items()}

# pseudo-node kinds (paper §3.2: operations inside an instruction needing
# explicit modeling, e.g. memory reference computation)
PSEUDO_KINDS = ["MemRef", "PredGuard", "AddrCalc"]
PSEUDO_IDS = {k: i for i, k in enumerate(PSEUDO_KINDS)}

# variable-node kinds
VAR_KINDS = ["reg", "mem", "init"]
VAR_IDS = {k: i for i, k in enumerate(VAR_KINDS)}
