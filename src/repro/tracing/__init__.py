from repro.tracing.isa import OPCODES, OPCODE_IDS, INSTR_CLASSES
from repro.tracing.tracer import KernelInvocation, WarpTrace, trace_kernel
from repro.tracing.programs import PROGRAMS, get_program, lm_program
