"""NVBit-like tracer over synthetic kernel templates.

Faithful to the paper's scoping strategy (§3.1): one representative SM per
kernel invocation, all CTAs on that SM, instructions grouped per warp in
temporal order.  Each trace entry carries the Table-1 record fields.

The trace is generated lazily and deterministically from
(template, params, seed): the *graph* subject uses a bounded per-warp window
(cap_instr) of a bounded number of warps (cap_warps), while the *timing*
subject (KernelStats) is computed analytically over the full grid — the same
split real samplers make between per-SM traces and whole-kernel metrics.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.config import resolve_trace_caps
from repro.tracing.isa import (
    CLASS_IDS, INSTR_CLASSES, OPCODE_IDS,
)


@dataclass
class BodyInstr:
    op: str
    dests: tuple = ()
    srcs: tuple = ()
    mem: Optional[dict] = None  # {'kind','width','stride_iter','base','pattern'}


@dataclass
class KernelStats:
    """Whole-kernel analytic statistics (full grid) for the timing model."""
    warp_instructions: float           # total dynamic warp-instructions
    class_counts: np.ndarray           # (num_classes,) warp-instruction counts
    flops: float
    bytes_accessed: float              # total global bytes requested
    working_set: float                 # unique global bytes
    reuse_factor: float                # accesses per unique byte
    pattern: str                       # coalesced | strided | random
    ctas: int
    threads_per_cta: int
    regs_per_thread: int
    smem_per_cta: int
    ilp: float                         # independent-instruction factor
    divergence: float                  # 0..1 branch divergence

    @property
    def instr_mix(self) -> np.ndarray:
        tot = max(self.class_counts.sum(), 1.0)
        return self.class_counts / tot


@dataclass
class WarpTrace:
    """Per-warp instruction stream (Table-1 record, vectorized)."""
    opcode: np.ndarray      # (N,) int16 token ids
    pc: np.ndarray          # (N,) int32
    mask: np.ndarray        # (N,) uint32 active-lane mask
    dest: np.ndarray        # (N,2) int16, -1 = none
    src: np.ndarray         # (N,3) int16, -1 = none
    mem_width: np.ndarray   # (N,) int16, 0 = not memory
    mem_addr: np.ndarray    # (N,) int64, 0 = not memory
    vstats: np.ndarray      # (N,8) float32 dynamic-value stats of the write


@dataclass
class KernelInvocation:
    name: str
    template: str
    params: dict
    seq: int                 # invocation index within the program
    seed: int
    body_fn: Callable = None  # params -> (body, n_iter, meta)
    stats_fn: Callable = None  # (params, platform) -> KernelStats

    def stats(self, platform: str = "P1") -> KernelStats:
        return self.stats_fn(self.params, platform)

    def trace(self, cap_warps: Optional[int] = None,
              cap_instr: Optional[int] = None, *,
              loop: bool = False) -> list[WarpTrace]:
        cap_warps, cap_instr = resolve_trace_caps(cap_warps, cap_instr)
        body, n_iter, meta = self.body_fn(self.params)
        st = self.stats("P1")  # launch geometry for the S2R prologue values
        meta = dict(meta, ctas=st.ctas, threads=st.threads_per_cta,
                    working_set=st.working_set)
        fn = trace_kernel_loop if loop else trace_kernel
        return fn(self, body, n_iter, meta, cap_warps, cap_instr)


def _rng_for(inv: KernelInvocation, warp: int) -> np.random.Generator:
    h = hashlib.blake2b(
        f"{inv.template}|{sorted(inv.params.items())}|{inv.seed}|{warp}".encode(),
        digest_size=8,
    ).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


def _value_stats(rng, scale, n=8):
    """8-dim dynamic-value summary: mean, std, median, min, max, p25, p75,
    skew — synthesized from a lane-value distribution (32 lanes)."""
    lanes = rng.normal(loc=scale, scale=abs(scale) * 0.1 + 1e-3, size=32)
    q25, med, q75 = np.percentile(lanes, [25, 50, 75])
    std = lanes.std()
    skew = float(np.mean(((lanes - lanes.mean()) / (std + 1e-9)) ** 3))
    return np.array(
        [lanes.mean(), std, med, lanes.min(), lanes.max(), q25, q75, skew],
        np.float32,
    )


def trace_kernel_loop(inv, body, n_iter, meta, cap_warps, cap_instr) -> list[WarpTrace]:
    """Reference tracer: unroll the loop body instruction-by-instruction.

    Kept as the bit-exact oracle for the vectorized ``trace_kernel`` below —
    both consume the identical `_rng_for` stream, so their outputs must match
    to the last bit (the parity suite enforces it).

    Every warp starts with the SASS prologue real kernels carry:
    S2R ctaid / S2R tid — their recorded dynamic values expose the launch
    geometry to the graph features (microarchitecture-independent, exactly
    what NVBit captures)."""
    prologue = [
        BodyInstr("S2R", (0,), ()),   # ctaid
        BodyInstr("S2R", (1,), ()),   # tid
        BodyInstr("IMAD", (2,), (0, 1)),
    ]
    body_len = len(body)
    iters = max(1, min(n_iter, max(1, (cap_instr - len(prologue)) // body_len)))
    warps = min(cap_warps, meta.get("warps_per_cta", 8))
    ctas = meta.get("ctas", 1)
    threads = meta.get("threads", 256)
    out = []
    for w in range(warps):
        rng = _rng_for(inv, w)
        N = len(prologue) + body_len * iters
        opcode = np.empty(N, np.int16)
        pc = np.empty(N, np.int32)
        mask = np.full(N, 0xFFFFFFFF, np.uint32)
        dest = np.full((N, 2), -1, np.int16)
        src = np.full((N, 3), -1, np.int16)
        mem_width = np.zeros(N, np.int16)
        mem_addr = np.zeros(N, np.int64)
        vstats = np.zeros((N, 8), np.float32)
        div = meta.get("divergence", 0.0)
        # each traced warp's addresses live in its CTA's slice of the kernel
        # footprint (warps on the representative SM cover evenly-spaced
        # CTAs) — address MAGNITUDE faithfully encodes the working set,
        # which is how real traces expose problem size to the HRG.
        ws = float(meta.get("working_set", 1 << 20))
        warp_base = (int((w + 1) / (warps + 1) * ws) // 128) * 128

        cta_sample = float(rng.integers(0, max(ctas, 1)))
        for j, ins in enumerate(prologue):
            opcode[j] = OPCODE_IDS[ins.op]
            pc[j] = 16 * j
            for d_i, d in enumerate(ins.dests[:2]):
                dest[j, d_i] = d
            for s_i, s_ in enumerate(ins.srcs[:3]):
                src[j, s_i] = s_
        # launch-geometry values: scale encodes grid/block size
        vstats[0] = _value_stats(rng, np.log1p(ctas) + cta_sample * 1e-6)
        vstats[1] = _value_stats(rng, np.log1p(threads))
        vstats[2] = _value_stats(rng, np.log1p(ctas * threads))

        p0 = len(prologue)
        for it in range(iters):
            for j, ins in enumerate(body):
                idx = p0 + it * body_len + j
                opcode[idx] = OPCODE_IDS[ins.op]
                pc[idx] = 16 * (p0 + j)  # static PC: iterations share PCs
                if div > 0 and ins.op in ("BRA", "ISETP"):
                    lanes = rng.random(32) > div
                    mask[idx] = np.uint32(
                        int("".join("1" if b else "0" for b in lanes[::-1]), 2)
                    )
                for d_i, d in enumerate(ins.dests[:2]):
                    dest[idx, d_i] = d
                for s_i, s_ in enumerate(ins.srcs[:3]):
                    src[idx, s_i] = s_
                if ins.mem is not None:
                    m = ins.mem
                    mem_width[idx] = m.get("width", 4)
                    stride = m.get("stride_iter", 128)
                    # buffers are ws-sized allocations: the template's base
                    # constant selects WHICH buffer; its address scale is the
                    # kernel's footprint (as in real allocator behavior).
                    buf = (int(m.get("base", 0)) >> 28) & 0xF
                    mem_addr[idx] = (
                        buf * (int(ws) // 128) * 128 + warp_base + it * stride
                    )
                    vstats[idx] = _value_stats(rng, float(mem_addr[idx]) * 1e-6)
                elif ins.dests and ins.dests[0] == 2 and ins.op == "IADD3":
                    # loop counter: NVBit records its values over the FULL
                    # execution (0..n_iter) even though the graph window is
                    # bounded — the trip count is real trace information.
                    vstats[idx] = np.array(
                        [n_iter / 2, n_iter / 3.46, n_iter / 2, 0.0,
                         n_iter, n_iter / 4, 3 * n_iter / 4, 0.0],
                        np.float32,
                    )
                elif ins.dests:
                    vstats[idx] = _value_stats(rng, float(rng.normal(0, 2.0)))
        out.append(
            WarpTrace(opcode, pc, mask, dest, src, mem_width, mem_addr, vstats)
        )
    return out


def trace_kernel(inv, body, n_iter, meta, cap_warps, cap_instr) -> list[WarpTrace]:
    """Vectorized tracer: numpy tiling instead of per-instruction loops.

    Bit-exact with ``trace_kernel_loop``: the per-warp RNG stream is replayed
    draw-for-draw, but consecutive normal draws are merged into single
    ``standard_normal`` calls (a Generator's normal stream is
    position-deterministic, so ``normal(loc, s, 32)`` equals
    ``loc + s * standard_normal(32)`` and back-to-back draws concatenate) and
    the 8-dim value statistics are computed for all write events at once over
    an (M, 32) lane matrix.  Uniform divergence draws interleave with the
    normal stream, so runs are split at each branch event when
    ``divergence > 0``."""
    prologue = [
        BodyInstr("S2R", (0,), ()),   # ctaid
        BodyInstr("S2R", (1,), ()),   # tid
        BodyInstr("IMAD", (2,), (0, 1)),
    ]
    body_len = len(body)
    iters = max(1, min(n_iter, max(1, (cap_instr - len(prologue)) // body_len)))
    warps = min(cap_warps, meta.get("warps_per_cta", 8))
    ctas = meta.get("ctas", 1)
    threads = meta.get("threads", 256)
    div = meta.get("divergence", 0.0)
    ws = float(meta.get("working_set", 1 << 20))
    p0 = len(prologue)
    N = p0 + body_len * iters

    # -- static instruction template (identical across warps/iterations) ----
    allins = prologue + list(body)
    tmpl_op = np.array([OPCODE_IDS[i.op] for i in allins], np.int16)
    tmpl_dest = np.full((len(allins), 2), -1, np.int16)
    tmpl_src = np.full((len(allins), 3), -1, np.int16)
    for j, ins in enumerate(allins):
        for d_i, d in enumerate(ins.dests[:2]):
            tmpl_dest[j, d_i] = d
        for s_i, s_ in enumerate(ins.srcs[:3]):
            tmpl_src[j, s_i] = s_
    tmpl_mw = np.array(
        [(i.mem.get("width", 4) if i.mem is not None else 0) for i in allins],
        np.int16,
    )
    opcode = np.concatenate([tmpl_op[:p0], np.tile(tmpl_op[p0:], iters)])
    pc = np.concatenate(
        [16 * np.arange(p0), np.tile(16 * (p0 + np.arange(body_len)), iters)]
    ).astype(np.int32)
    dest = np.concatenate([tmpl_dest[:p0], np.tile(tmpl_dest[p0:], (iters, 1))])
    src = np.concatenate([tmpl_src[:p0], np.tile(tmpl_src[p0:], (iters, 1))])
    mem_width = np.concatenate([tmpl_mw[:p0], np.tile(tmpl_mw[p0:], iters)])

    # -- per-iteration RNG event sequence (body order, same as the oracle) --
    # 'u' = 32 uniform lanes (branch divergence), 'm' = 32 normals keyed on
    # the address, 'd' = 1 scalar normal + 32 lane normals.
    ev: list[tuple[str, int]] = []
    loop_js: list[int] = []
    for j, ins in enumerate(body):
        if div > 0 and ins.op in ("BRA", "ISETP"):
            ev.append(("u", j))
        if ins.mem is not None:
            ev.append(("m", j))
        elif ins.dests and ins.dests[0] == 2 and ins.op == "IADD3":
            loop_js.append(j)
        elif ins.dests:
            ev.append(("d", j))
    val_events = [(k, j) for k, j in ev if k != "u"]
    unif_js = [j for k, j in ev if k == "u"]
    n_val, n_u = len(val_events), len(unif_js)
    per_iter = [(-1 if k == "u" else (33 if k == "d" else 32)) for k, _ in ev]
    per_iter_normals = sum(c for c in per_iter if c > 0)

    M = 3 + iters * n_val  # value-stat event rows (3 prologue rows first)
    ev_counts = np.array([33 if k == "d" else 32 for k, _ in val_events],
                         np.int64)
    counts = np.concatenate([np.full(3, 32, np.int64), np.tile(ev_counts, iters)])
    offs = np.zeros(M, np.int64)
    np.cumsum(counts[:-1], out=offs[1:])
    has_scalar = counts == 33
    it_arr = np.arange(iters, dtype=np.int64)
    if loop_js:
        lc_row = np.array(
            [n_iter / 2, n_iter / 3.46, n_iter / 2, 0.0,
             n_iter, n_iter / 4, 3 * n_iter / 4, 0.0],
            np.float32,
        )

    out = []
    for w in range(warps):
        rng = _rng_for(inv, w)
        warp_base = (int((w + 1) / (warps + 1) * ws) // 128) * 128
        cta_sample = float(rng.integers(0, max(ctas, 1)))

        # replay the draw stream: merged normal runs split by uniform draws
        chunks: list[np.ndarray] = []
        unifs: list[np.ndarray] = []
        if n_u == 0:
            chunks.append(rng.standard_normal(96 + iters * per_iter_normals))
        else:
            run = 96
            for _ in range(iters):
                for c in per_iter:
                    if c < 0:
                        if run:
                            chunks.append(rng.standard_normal(run))
                            run = 0
                        unifs.append(rng.random(32))
                    else:
                        run += c
            if run:
                chunks.append(rng.standard_normal(run))
        z = np.concatenate(chunks)

        # locs per value-event row; mem addresses land in mem_addr as we go
        mem_addr = np.zeros(N, np.int64)
        locs = np.empty(M, np.float64)
        locs[0] = np.log1p(ctas) + cta_sample * 1e-6
        locs[1] = np.log1p(threads)
        locs[2] = np.log1p(ctas * threads)
        if n_val:
            body_rows = locs[3:].reshape(iters, n_val)
            for e, (kind, j) in enumerate(val_events):
                if kind == "m":
                    m = body[j].mem
                    stride = m.get("stride_iter", 128)
                    buf = (int(m.get("base", 0)) >> 28) & 0xF
                    addr = (buf * (int(ws) // 128) * 128 + warp_base
                            + it_arr * stride)
                    mem_addr[p0 + it_arr * body_len + j] = addr
                    body_rows[:, e] = addr.astype(np.float64) * 1e-6
        locs[has_scalar] = 2.0 * z[offs[has_scalar]]

        lane_idx = (offs + has_scalar)[:, None] + np.arange(32)[None, :]
        lanes = locs[:, None] + (np.abs(locs) * 0.1 + 1e-3)[:, None] * z[lane_idx]
        q25, med, q75 = np.percentile(lanes, [25, 50, 75], axis=1)
        mean = lanes.mean(axis=1)
        std = lanes.std(axis=1)
        skew = np.mean(((lanes - mean[:, None]) / (std[:, None] + 1e-9)) ** 3,
                       axis=1)
        stats8 = np.stack(
            [mean, std, med, lanes.min(axis=1), lanes.max(axis=1),
             q25, q75, skew], axis=1,
        ).astype(np.float32)

        vstats = np.zeros((N, 8), np.float32)
        vstats[:3] = stats8[:3]
        if n_val:
            val_j = np.array([j for _, j in val_events], np.int64)
            tgt = p0 + (it_arr[:, None] * body_len + val_j[None, :]).ravel()
            vstats[tgt] = stats8[3:]
        if loop_js:
            lj = np.array(loop_js, np.int64)
            tgt = p0 + (it_arr[:, None] * body_len + lj[None, :]).ravel()
            vstats[tgt] = lc_row

        mask = np.full(N, 0xFFFFFFFF, np.uint32)
        if n_u:
            ub = np.asarray(unifs) > div  # (iters*n_u, 32), iteration-major
            bits = (ub.astype(np.uint64)
                    << np.arange(32, dtype=np.uint64)[None, :]).sum(axis=1)
            uj = np.array(unif_js, np.int64)
            tgt = p0 + (it_arr[:, None] * body_len + uj[None, :]).ravel()
            mask[tgt] = bits.astype(np.uint32)

        out.append(
            WarpTrace(opcode.copy(), pc.copy(), mask, dest.copy(), src.copy(),
                      mem_width.copy(), mem_addr, vstats)
        )
    return out


def make_stats(
    *, body_class_counts, n_iter, ctas, threads_per_cta, flops_total,
    bytes_accessed, working_set, pattern, regs=32, smem=0, ilp=2.0,
    divergence=0.0,
) -> KernelStats:
    warps_per_cta = (threads_per_cta + 31) // 32
    total_warp_instr = float(
        sum(body_class_counts.values()) * n_iter * warps_per_cta * ctas
    )
    counts = np.zeros(len(INSTR_CLASSES), np.float64)
    for cls, c in body_class_counts.items():
        counts[CLASS_IDS[cls]] = c * n_iter * warps_per_cta * ctas
    return KernelStats(
        warp_instructions=total_warp_instr,
        class_counts=counts,
        flops=float(flops_total),
        bytes_accessed=float(bytes_accessed),
        working_set=float(max(working_set, 1.0)),
        reuse_factor=float(max(bytes_accessed / max(working_set, 1.0), 1.0)),
        pattern=pattern,
        ctas=int(ctas),
        threads_per_cta=int(threads_per_cta),
        regs_per_thread=int(regs),
        smem_per_cta=int(smem),
        ilp=float(ilp),
        divergence=float(divergence),
    )
