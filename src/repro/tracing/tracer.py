"""NVBit-like tracer over synthetic kernel templates.

Faithful to the paper's scoping strategy (§3.1): one representative SM per
kernel invocation, all CTAs on that SM, instructions grouped per warp in
temporal order.  Each trace entry carries the Table-1 record fields.

The trace is generated lazily and deterministically from
(template, params, seed): the *graph* subject uses a bounded per-warp window
(cap_instr) of a bounded number of warps (cap_warps), while the *timing*
subject (KernelStats) is computed analytically over the full grid — the same
split real samplers make between per-SM traces and whole-kernel metrics.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.tracing.isa import (
    CLASS_IDS, INSTR_CLASSES, OPCODE_IDS,
)


@dataclass
class BodyInstr:
    op: str
    dests: tuple = ()
    srcs: tuple = ()
    mem: Optional[dict] = None  # {'kind','width','stride_iter','base','pattern'}


@dataclass
class KernelStats:
    """Whole-kernel analytic statistics (full grid) for the timing model."""
    warp_instructions: float           # total dynamic warp-instructions
    class_counts: np.ndarray           # (num_classes,) warp-instruction counts
    flops: float
    bytes_accessed: float              # total global bytes requested
    working_set: float                 # unique global bytes
    reuse_factor: float                # accesses per unique byte
    pattern: str                       # coalesced | strided | random
    ctas: int
    threads_per_cta: int
    regs_per_thread: int
    smem_per_cta: int
    ilp: float                         # independent-instruction factor
    divergence: float                  # 0..1 branch divergence

    @property
    def instr_mix(self) -> np.ndarray:
        tot = max(self.class_counts.sum(), 1.0)
        return self.class_counts / tot


@dataclass
class WarpTrace:
    """Per-warp instruction stream (Table-1 record, vectorized)."""
    opcode: np.ndarray      # (N,) int16 token ids
    pc: np.ndarray          # (N,) int32
    mask: np.ndarray        # (N,) uint32 active-lane mask
    dest: np.ndarray        # (N,2) int16, -1 = none
    src: np.ndarray         # (N,3) int16, -1 = none
    mem_width: np.ndarray   # (N,) int16, 0 = not memory
    mem_addr: np.ndarray    # (N,) int64, 0 = not memory
    vstats: np.ndarray      # (N,8) float32 dynamic-value stats of the write


@dataclass
class KernelInvocation:
    name: str
    template: str
    params: dict
    seq: int                 # invocation index within the program
    seed: int
    body_fn: Callable = None  # params -> (body, n_iter, meta)
    stats_fn: Callable = None  # (params, platform) -> KernelStats

    def stats(self, platform: str = "P1") -> KernelStats:
        return self.stats_fn(self.params, platform)

    def trace(self, cap_warps: int = 2, cap_instr: int = 256) -> list[WarpTrace]:
        body, n_iter, meta = self.body_fn(self.params)
        st = self.stats("P1")  # launch geometry for the S2R prologue values
        meta = dict(meta, ctas=st.ctas, threads=st.threads_per_cta,
                    working_set=st.working_set)
        return trace_kernel(self, body, n_iter, meta, cap_warps, cap_instr)


def _rng_for(inv: KernelInvocation, warp: int) -> np.random.Generator:
    h = hashlib.blake2b(
        f"{inv.template}|{sorted(inv.params.items())}|{inv.seed}|{warp}".encode(),
        digest_size=8,
    ).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


def _value_stats(rng, scale, n=8):
    """8-dim dynamic-value summary: mean, std, median, min, max, p25, p75,
    skew — synthesized from a lane-value distribution (32 lanes)."""
    lanes = rng.normal(loc=scale, scale=abs(scale) * 0.1 + 1e-3, size=32)
    q25, med, q75 = np.percentile(lanes, [25, 50, 75])
    std = lanes.std()
    skew = float(np.mean(((lanes - lanes.mean()) / (std + 1e-9)) ** 3))
    return np.array(
        [lanes.mean(), std, med, lanes.min(), lanes.max(), q25, q75, skew],
        np.float32,
    )


def trace_kernel(inv, body, n_iter, meta, cap_warps, cap_instr) -> list[WarpTrace]:
    """Unroll the loop body into per-warp streams (bounded window).

    Every warp starts with the SASS prologue real kernels carry:
    S2R ctaid / S2R tid — their recorded dynamic values expose the launch
    geometry to the graph features (microarchitecture-independent, exactly
    what NVBit captures)."""
    prologue = [
        BodyInstr("S2R", (0,), ()),   # ctaid
        BodyInstr("S2R", (1,), ()),   # tid
        BodyInstr("IMAD", (2,), (0, 1)),
    ]
    body_len = len(body)
    iters = max(1, min(n_iter, max(1, (cap_instr - len(prologue)) // body_len)))
    warps = min(cap_warps, meta.get("warps_per_cta", 8))
    ctas = meta.get("ctas", 1)
    threads = meta.get("threads", 256)
    out = []
    for w in range(warps):
        rng = _rng_for(inv, w)
        N = len(prologue) + body_len * iters
        opcode = np.empty(N, np.int16)
        pc = np.empty(N, np.int32)
        mask = np.full(N, 0xFFFFFFFF, np.uint32)
        dest = np.full((N, 2), -1, np.int16)
        src = np.full((N, 3), -1, np.int16)
        mem_width = np.zeros(N, np.int16)
        mem_addr = np.zeros(N, np.int64)
        vstats = np.zeros((N, 8), np.float32)
        div = meta.get("divergence", 0.0)
        # each traced warp's addresses live in its CTA's slice of the kernel
        # footprint (warps on the representative SM cover evenly-spaced
        # CTAs) — address MAGNITUDE faithfully encodes the working set,
        # which is how real traces expose problem size to the HRG.
        ws = float(meta.get("working_set", 1 << 20))
        warp_base = (int((w + 1) / (warps + 1) * ws) // 128) * 128

        cta_sample = float(rng.integers(0, max(ctas, 1)))
        for j, ins in enumerate(prologue):
            opcode[j] = OPCODE_IDS[ins.op]
            pc[j] = 16 * j
            for d_i, d in enumerate(ins.dests[:2]):
                dest[j, d_i] = d
            for s_i, s_ in enumerate(ins.srcs[:3]):
                src[j, s_i] = s_
        # launch-geometry values: scale encodes grid/block size
        vstats[0] = _value_stats(rng, np.log1p(ctas) + cta_sample * 1e-6)
        vstats[1] = _value_stats(rng, np.log1p(threads))
        vstats[2] = _value_stats(rng, np.log1p(ctas * threads))

        p0 = len(prologue)
        for it in range(iters):
            for j, ins in enumerate(body):
                idx = p0 + it * body_len + j
                opcode[idx] = OPCODE_IDS[ins.op]
                pc[idx] = 16 * (p0 + j)  # static PC: iterations share PCs
                if div > 0 and ins.op in ("BRA", "ISETP"):
                    lanes = rng.random(32) > div
                    mask[idx] = np.uint32(
                        int("".join("1" if b else "0" for b in lanes[::-1]), 2)
                    )
                for d_i, d in enumerate(ins.dests[:2]):
                    dest[idx, d_i] = d
                for s_i, s_ in enumerate(ins.srcs[:3]):
                    src[idx, s_i] = s_
                if ins.mem is not None:
                    m = ins.mem
                    mem_width[idx] = m.get("width", 4)
                    stride = m.get("stride_iter", 128)
                    # buffers are ws-sized allocations: the template's base
                    # constant selects WHICH buffer; its address scale is the
                    # kernel's footprint (as in real allocator behavior).
                    buf = (int(m.get("base", 0)) >> 28) & 0xF
                    mem_addr[idx] = (
                        buf * (int(ws) // 128) * 128 + warp_base + it * stride
                    )
                    vstats[idx] = _value_stats(rng, float(mem_addr[idx]) * 1e-6)
                elif ins.dests and ins.dests[0] == 2 and ins.op == "IADD3":
                    # loop counter: NVBit records its values over the FULL
                    # execution (0..n_iter) even though the graph window is
                    # bounded — the trip count is real trace information.
                    vstats[idx] = np.array(
                        [n_iter / 2, n_iter / 3.46, n_iter / 2, 0.0,
                         n_iter, n_iter / 4, 3 * n_iter / 4, 0.0],
                        np.float32,
                    )
                elif ins.dests:
                    vstats[idx] = _value_stats(rng, float(rng.normal(0, 2.0)))
        out.append(
            WarpTrace(opcode, pc, mask, dest, src, mem_width, mem_addr, vstats)
        )
    return out


def make_stats(
    *, body_class_counts, n_iter, ctas, threads_per_cta, flops_total,
    bytes_accessed, working_set, pattern, regs=32, smem=0, ilp=2.0,
    divergence=0.0,
) -> KernelStats:
    warps_per_cta = (threads_per_cta + 31) // 32
    total_warp_instr = float(
        sum(body_class_counts.values()) * n_iter * warps_per_cta * ctas
    )
    counts = np.zeros(len(INSTR_CLASSES), np.float64)
    for cls, c in body_class_counts.items():
        counts[CLASS_IDS[cls]] = c * n_iter * warps_per_cta * ctas
    return KernelStats(
        warp_instructions=total_warp_instr,
        class_counts=counts,
        flops=float(flops_total),
        bytes_accessed=float(bytes_accessed),
        working_set=float(max(working_set, 1.0)),
        reuse_factor=float(max(bytes_accessed / max(working_set, 1.0), 1.0)),
        pattern=pattern,
        ctas=int(ctas),
        threads_per_cta=int(threads_per_cta),
        regs_per_thread=int(regs),
        smem_per_cta=int(smem),
        ilp=float(ilp),
        divergence=float(divergence),
    )
