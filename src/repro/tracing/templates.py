"""Kernel template library: parameterized SASS-like loop bodies + analytic
whole-grid statistics.  Templates cover the behavioral space of the paper's
benchmark suites (PolyBench / Rodinia / Tango / LLM inference kernels)."""

from __future__ import annotations

from collections import Counter


from repro.tracing.tracer import BodyInstr as I
from repro.tracing.tracer import KernelInvocation, make_stats
from repro.utils.registry import Registry

TEMPLATES: Registry = Registry("kernel template")


def _count_classes(body):
    from repro.tracing.isa import OPCODES

    return Counter(OPCODES[i.op][0] for i in body)


# ---------------------------------------------------------------------------
# GEMM (tiled, smem double-buffered flavor)
# ---------------------------------------------------------------------------


def gemm_body(params):
    fp16 = params.get("fp16", False)
    mma = "HMMA" if fp16 else "FFMA"
    # row-major leading dimensions are visible in the address stream of real
    # SASS traces: A advances by lda=K*4 per k-tile row crossing, B by ldb=N*4
    # per k-step — so matrix shape is trace-discoverable (not just grid size).
    lda = max(128, params["K"] * 4)
    ldb = max(128, params["N"] * 4)
    body = [
        I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": lda, "base": 0x10000000, "pattern": "coalesced"}),
        I("LDG", (11,), (3,), mem={"kind": "load", "width": 16, "stride_iter": ldb, "base": 0x20000000, "pattern": "coalesced"}),
        I("STS", (), (10,)),
        I("STS", (), (11,)),
        I("BAR", (), ()),
        I("LDS", (12,), ()),
        I("LDS", (13,), ()),
    ]
    for r in range(8):
        body.append(I(mma, (20 + r,), (12, 13, 20 + r)))
    body += [I("BAR", (), ()), I("IADD3", (2,), (2,)), I("ISETP", (), (2,)), I("BRA", (), ())]
    M, N, K = params["M"], params["N"], params["K"]
    n_iter = max(1, K // 32)
    return body, n_iter, {"warps_per_cta": 8}


def gemm_stats(params, platform):
    M, N, K = params["M"], params["N"], params["K"]
    fp16 = params.get("fp16", False)
    body, n_iter, _ = gemm_body(params)
    ctas = max(1, (M // 64) * (N // 64))
    elt = 2 if fp16 else 4
    # tiled-GEMM traffic: A rereads once per 128-wide N tile, B per M tile
    tile = 128
    bytes_acc = elt * (
        M * K * max(1, N // tile) + K * N * max(1, M // tile) + M * N
    )
    ws = (M * K + K * N + M * N) * elt
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=2.0 * M * N * K,
        bytes_accessed=max(bytes_acc, ws), working_set=ws,
        pattern="coalesced", regs=96 if fp16 else 64, smem=32768, ilp=4.0,
    )


TEMPLATES.add("gemm", (gemm_body, gemm_stats))


# ---------------------------------------------------------------------------
# Elementwise / memcpy-like streams
# ---------------------------------------------------------------------------


def elementwise_body(params):
    nops = params.get("nops", 2)
    ops = params.get("ops", ["FMUL", "FADD"])
    body = [I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": 4096, "base": 0x30000000, "pattern": "coalesced"})]
    prev = 10
    for i in range(nops):
        op = ops[i % len(ops)]
        body.append(I(op, (11 + i,), (prev,)))
        prev = 11 + i
    body += [
        I("STG", (), (prev,), mem={"kind": "store", "width": 16, "stride_iter": 4096, "base": 0x40000000, "pattern": "coalesced"}),
        I("IADD3", (2,), (2,)),
        I("BRA", (), ()),
    ]
    n = params["n"]
    n_iter = max(1, n // (256 * 4 * max(1, params.get("grid_cap", 4096))))
    return body, max(n_iter, params.get("iters", 4)), {"warps_per_cta": 8}


def elementwise_stats(params, platform):
    n = params["n"]
    nops = params.get("nops", 2)
    body, n_iter, _ = elementwise_body(params)
    ctas = min(max(1, n // (256 * 4)), params.get("grid_cap", 4096))
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=float(n) * nops,
        bytes_accessed=8.0 * n, working_set=8.0 * n,
        pattern="coalesced", regs=24, ilp=6.0,
    )


TEMPLATES.add("elementwise", (elementwise_body, elementwise_stats))


# ---------------------------------------------------------------------------
# Reduction (shuffle tree)
# ---------------------------------------------------------------------------


def reduction_body(params):
    body = [
        I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": 4096, "base": 0x50000000, "pattern": "coalesced"}),
        I("FADD", (11,), (10, 11)),
        I("IADD3", (2,), (2,)),
        I("BRA", (), ()),
    ]
    tail = []
    for s in range(5):
        tail += [I("SHFL", (12,), (11,)), I("FADD", (11,), (11, 12))]
    tail += [I("BAR", (), ()), I("STG", (), (11,), mem={"kind": "store", "width": 4, "stride_iter": 4, "base": 0x60000000, "pattern": "coalesced"})]
    n = params["n"]
    n_iter = max(2, min(64, n // (256 * 1024)))
    return body + tail, n_iter, {"warps_per_cta": 8}


def reduction_stats(params, platform):
    n = params["n"]
    body, n_iter, _ = reduction_body(params)
    ctas = max(1, min(n // (256 * 16), 2048))
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=float(n),
        bytes_accessed=4.0 * n, working_set=4.0 * n,
        pattern="coalesced", regs=16, ilp=2.0,
    )


TEMPLATES.add("reduction", (reduction_body, reduction_stats))


# ---------------------------------------------------------------------------
# Stencil (structured neighbors, L1-friendly)
# ---------------------------------------------------------------------------


def stencil_body(params):
    pts = params.get("pts", 5)
    stride = params.get("stride", 512)  # small stride -> line reuse in trace
    body = []
    for p in range(pts):
        body.append(I("LDG", (10 + p,), (2,), mem={"kind": "load", "width": 4, "stride_iter": stride, "base": 0x70000000 + 4096 * p, "pattern": params.get("pattern", "strided")}))
    acc = 30
    body.append(I("FMUL", (acc,), (10,)))
    for p in range(1, pts):
        body.append(I("FFMA", (acc,), (10 + p, acc)))
    body += [
        I("STG", (), (acc,), mem={"kind": "store", "width": 4, "stride_iter": stride, "base": 0x80000000, "pattern": "coalesced"}),
        I("IADD3", (2,), (2,)),
        I("ISETP", (), (2,)),
        I("BRA", (), ()),
    ]
    return body, max(2, params.get("iters", 8)), {"warps_per_cta": 8}


def stencil_stats(params, platform):
    nx, ny = params["nx"], params["ny"]
    pts = params.get("pts", 5)
    body, n_iter, _ = stencil_body(params)
    ctas = max(1, (nx * ny) // (256 * n_iter))
    reuse = params.get("reuse", 1.0)  # spatial-locality factor
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=2.0 * nx * ny * pts,
        bytes_accessed=4.0 * nx * ny * pts,
        working_set=4.0 * nx * ny * pts / max(reuse, 1.0),
        pattern=params.get("pattern", "strided"), regs=40,
        ilp=params.get("ilp", 3.0),
    )


TEMPLATES.add("stencil", (stencil_body, stencil_stats))


# ---------------------------------------------------------------------------
# Softmax / normalization rows (SFU-heavy)
# ---------------------------------------------------------------------------


def softmax_body(params):
    body = [
        I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": 2048, "base": 0x90000000, "pattern": "coalesced"}),
        I("FADD", (11,), (10, 11)),
        I("SHFL", (12,), (11,)),
        I("FADD", (11,), (11, 12)),
        I("MUFU", (13,), (10,)),
        I("FADD", (14,), (13, 14)),
        I("SHFL", (15,), (14,)),
        I("FADD", (14,), (14, 15)),
        I("MUFU", (16,), (14,)),
        I("FMUL", (17,), (13, 16)),
        I("STG", (), (17,), mem={"kind": "store", "width": 16, "stride_iter": 2048, "base": 0xA0000000, "pattern": "coalesced"}),
        I("IADD3", (2,), (2,)),
        I("BRA", (), ()),
    ]
    cols = params["cols"]
    n_iter = max(1, cols // (32 * 4))
    return body, n_iter, {"warps_per_cta": 4}


def softmax_stats(params, platform):
    rows, cols = params["rows"], params["cols"]
    body, n_iter, _ = softmax_body(params)
    ctas = max(1, rows // 4)
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=128, flops_total=6.0 * rows * cols,
        bytes_accessed=8.0 * rows * cols, working_set=8.0 * rows * cols,
        pattern="coalesced", regs=32, ilp=2.5,
    )


TEMPLATES.add("softmax", (softmax_body, softmax_stats))


# ---------------------------------------------------------------------------
# Convolution (implicit GEMM; platform-sensitive algorithm selection!)
# ---------------------------------------------------------------------------


def conv_body(params):
    algo = params.get("algo", "implicit_gemm")
    if algo == "cudnn_heuristic":
        algo = "implicit_gemm"  # traces are collected on P1 (paper setup)
    if algo == "winograd":
        # transform-heavy: more ALU, fewer loads
        body = [
            I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": 256, "base": 0xB0000000, "pattern": "strided"}),
            I("FADD", (11,), (10,)), I("FMUL", (12,), (11,)),
            I("FADD", (13,), (12,)), I("FMUL", (14,), (13,)),
        ]
        for r in range(4):
            body.append(I("FFMA", (20 + r,), (14, 20 + r)))
        body += [I("STG", (), (20,), mem={"kind": "store", "width": 16, "stride_iter": 256, "base": 0xC0000000, "pattern": "coalesced"}),
                 I("IADD3", (2,), (2,)), I("BRA", (), ())]
    else:
        body = [
            I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": 512, "base": 0xB0000000, "pattern": "strided"}),
            I("LDG", (11,), (3,), mem={"kind": "load", "width": 16, "stride_iter": 0, "base": 0xB8000000, "pattern": "coalesced"}),
            I("STS", (), (10,)), I("BAR", (), ()), I("LDS", (12,), ()),
        ]
        for r in range(6):
            body.append(I("FFMA", (20 + r,), (11, 12, 20 + r)))
        body += [I("BAR", (), ()),
                 I("STG", (), (20,), mem={"kind": "store", "width": 16, "stride_iter": 512, "base": 0xC0000000, "pattern": "coalesced"}),
                 I("IADD3", (2,), (2,)), I("BRA", (), ())]
    c, k, r = params["c"], params["k"], params.get("r", 3)
    n_iter = max(1, (c * r * r) // 32)
    return body, n_iter, {"warps_per_cta": 8}


def conv_stats(params, platform):
    c, hw, k, r = params["c"], params["hw"], params["k"], params.get("r", 3)
    algo = params.get("algo", "implicit_gemm")
    if algo == "cudnn_heuristic":
        # the library picks the algorithm per GPU generation at runtime
        # (the paper's phi-2 / PKA profiling quirk, §5.2): clustering done on
        # P1 sees implicit-gemm behavior; P2/P3 ground truth runs winograd.
        algo = "implicit_gemm" if platform == "P1" else "winograd"
    p = dict(params)
    p["algo"] = algo
    body, n_iter, _ = conv_body(p)
    ctas = params.get("ctas", max(1, (hw * hw * k) // (64 * 64)))
    flops = 2.0 * hw * hw * k * c * r * r
    if algo == "winograd":
        flops *= 0.45  # winograd reduces multiplies
    bytes_acc = 4.0 * (hw * hw * c * 3 + k * c * r * r)
    # winograd: long transform dependency chains -> low ILP (the perf
    # difference instruction-count signatures cannot see)
    ilp = 1.0 if algo == "winograd" else 4.0
    # the output buffer scales with the launched grid (64x64 tile per CTA)
    ws = 4.0 * (hw * hw * c + k * c * r * r) + 4.0 * ctas * 64 * 64
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=flops,
        bytes_accessed=max(bytes_acc, ws), working_set=ws,
        pattern="strided", regs=80, smem=24576, ilp=ilp,
    )


TEMPLATES.add("conv", (conv_body, conv_stats))


# ---------------------------------------------------------------------------
# Graph traversal (irregular, divergent, atomic)
# ---------------------------------------------------------------------------


def traversal_body(params):
    body = [
        I("LDG", (10,), (2,), mem={"kind": "load", "width": 4, "stride_iter": 4, "base": 0xD0000000, "pattern": "coalesced"}),
        I("ISETP", (), (10,)),
        I("BRA", (), ()),
        I("LDG", (11,), (10,), mem={"kind": "load", "width": 4, "stride_iter": 8192, "base": 0xD8000000, "pattern": "random"}),
        I("LDG", (12,), (11,), mem={"kind": "load", "width": 4, "stride_iter": 16384, "base": 0xE0000000, "pattern": "random"}),
        I("IADD3", (13,), (11, 12)),
        I("ISETP", (), (13,)),
        I("RED", (), (13,), mem={"kind": "store", "width": 4, "stride_iter": 8192, "base": 0xE8000000, "pattern": "random"}),
        I("IADD3", (2,), (2,)),
        I("BRA", (), ()),
    ]
    deg = params.get("degree", 8)
    return body, max(1, deg), {"warps_per_cta": 8, "divergence": params.get("divergence", 0.4)}


def traversal_stats(params, platform):
    nodes, deg = params["nodes"], params.get("degree", 8)
    frontier = params.get("frontier", nodes)
    body, n_iter, _ = traversal_body(params)
    ctas = max(1, frontier // 256)
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=0.0,
        bytes_accessed=4.0 * frontier * deg * 3,
        working_set=4.0 * nodes,
        pattern="random", regs=24, ilp=1.2,
        divergence=params.get("divergence", 0.4),
    )


TEMPLATES.add("traversal", (traversal_body, traversal_stats))


# ---------------------------------------------------------------------------
# GEMV (memory-bound matvec — LLM decode kernels)
# ---------------------------------------------------------------------------


def gemv_body(params):
    # acc_regs=1 -> serial FFMA dependency chain (latency-bound);
    # acc_regs=2 -> independent accumulators (ILP).  Identical opcode MIX and
    # COUNT either way — the difference lives in the register SSA structure,
    # which HRGs capture and hand-crafted mixes cannot.
    serial = params.get("acc_regs", 2) == 1
    lda = max(128, params["m"] * 4)  # matvec row stride = m*4
    body = [
        I("LDG", (10,), (2,), mem={"kind": "load", "width": 16, "stride_iter": lda, "base": 0xF0000000, "pattern": "coalesced"}),
        I("LDG", (11,), (3,), mem={"kind": "load", "width": 16, "stride_iter": 64, "base": 0xF8000000, "pattern": "coalesced"}),
        I("FFMA", (20,), (10, 11, 20)),
        I("FFMA", (20,) if serial else (21,), (10, 11, 20) if serial else (10, 11, 21)),
        I("IADD3", (2,), (2,)),
        I("BRA", (), ()),
    ]
    tail = [I("SHFL", (22,), (20,)), I("FADD", (20,), (20, 22)),
            I("STG", (), (20,), mem={"kind": "store", "width": 4, "stride_iter": 4, "base": 0xFC000000, "pattern": "coalesced"})]
    n, m = params["n"], params["m"]
    n_iter = max(1, m // (32 * 8))
    return body + tail, n_iter, {"warps_per_cta": 8}


def gemv_stats(params, platform):
    n, m = params["n"], params["m"]
    body, n_iter, _ = gemv_body(params)
    ctas = max(1, n // 64)
    ilp = 1.0 if params.get("acc_regs", 2) == 1 else 6.0
    return make_stats(
        body_class_counts=_count_classes(body), n_iter=n_iter, ctas=ctas,
        threads_per_cta=256, flops_total=2.0 * n * m,
        bytes_accessed=4.0 * n * m + 8.0 * m, working_set=4.0 * n * m,
        pattern="coalesced", regs=32, ilp=ilp,
    )


TEMPLATES.add("gemv", (gemv_body, gemv_stats))


def make_kernel(name, template, params, seq, seed) -> KernelInvocation:
    body_fn, stats_fn = TEMPLATES.get(template)
    return KernelInvocation(
        name=name, template=template, params=params, seq=seq, seed=seed,
        body_fn=body_fn, stats_fn=stats_fn,
    )
