"""Top-k MoE with group-local (per-batch-row) routing and capacity gather.

TPU-native adaptation (DESIGN.md §3): no token-permute scatter across devices.
Each batch row is a routing group — routing, position-in-expert cumsum,
gather into (E, C) buffers and the combine scatter are all *local to the
batch dim*, which is sharded over the data axes; GSPMD never sees a
cross-shard cumsum.  Expert FFN weights are sharded over the model axis on
d_ff (TP-MoE, Megatron-style: one all-reduce after w2) — expert-parallel
(experts over 'model') is a recorded perf-iteration alternative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import _dense_init, cast


def moe_axes(cfg: ModelConfig):
    return {
        "router": ("embed", "experts"),
        "w1": ("experts", "embed", "ffn"),
        "w3": ("experts", "embed", "ffn"),
        "w2": ("experts", "ffn", "embed"),
    }


def init_moe(key, cfg: ModelConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "router": _dense_init(k1, (D, E), scale=0.02),
        "w1": _dense_init(k2, (E, D, F)),
        "w3": _dense_init(k3, (E, D, F)),
        "w2": _dense_init(k4, (E, F, D), scale=1.0 / np.sqrt(F) / np.sqrt(2 * cfg.num_layers)),
    }
    return params, moe_axes(cfg)


# lint: allow[R1] config shape math — trace-time constants, not device syncs
def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = int(np.ceil(cfg.top_k * group_tokens * cfg.capacity_factor / cfg.num_experts))
    c = max(c, cfg.top_k)
    return int(np.ceil(c / 4) * 4) if c > 4 else c


def moe_forward(cfg: ModelConfig, p, h):
    """h (B,S,D) -> (out (B,S,D), aux_loss scalar).

    Long sequences are routed in seq chunks of cfg.moe_seq_chunk via
    lax.scan: the expanded (B,E,C,D) dispatch buffers scale with the CHUNK,
    not the sequence — the peak-memory fix that keeps 32k-token MoE training
    inside HBM (EXPERIMENTS.md §Dry-run)."""
    B, S, D = h.shape
    G = min(cfg.moe_seq_chunk, S)
    if S > G and S % G == 0:
        nch = S // G
        hs = h.reshape(B, nch, G, D).swapaxes(0, 1)  # (nch,B,G,D)

        def body(aux, h_c):
            out_c, a = _moe_group(cfg, p, h_c)
            return aux + a, out_c

        aux, outs = jax.lax.scan(body, jnp.float32(0.0), hs)
        out = outs.swapaxes(0, 1).reshape(B, S, D)
        return constrain(out, "batch", "seq", "embed"), aux / nch
    return _moe_group(cfg, p, h)


def _moe_group(cfg: ModelConfig, p, h):
    B, S, D = h.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)
    dt = jnp.dtype(cfg.compute_dtype)

    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32), p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)  # (B,S,E) fp32
    top_g, top_e = jax.lax.top_k(gates, k)   # (B,S,k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (per group, averaged).
    me = jnp.mean(gates, axis=1)  # (B,E)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=1) / S,
        axis=0,
    )
    aux = E * jnp.mean(jnp.sum(me * ce[None], axis=-1))

    # --- group-local dispatch --------------------------------------------
    flat_e = top_e.reshape(B, S * k)                       # expert id per slot
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (B,S*k,E)
    pos = jnp.cumsum(onehot, axis=1) * onehot              # 1-based position
    pos_in_e = jnp.sum(pos, axis=-1) - 1                   # (B,S*k)
    keep = (pos_in_e >= 0) & (pos_in_e < C)
    tok_idx = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(S * k)
    tok_idx = jnp.broadcast_to(tok_idx[None], (B, S * k))

    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    safe_pos = jnp.clip(pos_in_e, 0, C - 1)
    # (B,E,C) buffer of token indices; sentinel S points at a zero row.
    idxbuf = jnp.full((B, E, C), S, jnp.int32)
    idxbuf = idxbuf.at[b_idx, flat_e, safe_pos].set(
        jnp.where(keep, tok_idx, S), mode="drop"
    )

    h_pad = jnp.concatenate([h, jnp.zeros((B, 1, D), h.dtype)], axis=1)
    xe = jnp.take_along_axis(
        h_pad[:, :, None, :], idxbuf.reshape(B, E * C)[:, :, None, None], axis=1
    ).reshape(B, E, C, D)
    # 'experts' is shardable when the rules put the model axis on it (EP);
    # under the default TP-MoE policy these dims stay unsharded.
    xe = constrain(xe, "batch", "experts", None, "embed")

    w1, w3, w2 = cast(p["w1"], dt), cast(p["w3"], dt), cast(p["w2"], dt)
    a = jnp.einsum("becd,edf->becf", xe, w1)
    g = jnp.einsum("becd,edf->becf", xe, w3)
    a = constrain(a, "batch", "experts", None, "ffn")
    z = jax.nn.silu(a) * g
    ye = jnp.einsum("becf,efd->becd", z, w2)
    ye = constrain(ye, "batch", "experts", None, "embed")

    # --- combine: gather each slot's expert output, weight, scatter-add ---
    contrib = jnp.take_along_axis(
        ye.reshape(B, E * C, D),
        (flat_e * C + safe_pos)[:, :, None],
        axis=1,
    )  # (B, S*k, D)
    w = jnp.where(keep, top_g.reshape(B, S * k), 0.0).astype(contrib.dtype)
    contrib = contrib * w[..., None]
    out = jnp.zeros((B, S, D), contrib.dtype)
    out = out.at[b_idx, tok_idx].add(contrib, mode="drop")
    return constrain(out.astype(h.dtype), "batch", "seq", "embed"), aux
