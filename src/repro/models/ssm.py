"""Mamba-2 / SSD (state-space duality) mixer — TPU-native chunked form.

Training/prefill uses the SSD chunked algorithm: intra-chunk quadratic
attention-like matmuls (MXU-friendly (Q x Q) per head) + an O(S/chunk)
inter-chunk state recurrence (lax.scan).  Decode is the O(1) recurrent
update.  The Pallas kernel (repro.kernels.ssd_scan) accelerates the
intra-chunk part; this module is the XLA path and oracle.

Layout convention: d_inner is heads-major, i.e. x.reshape(B,S,nh,hp) shards
consistently when d_inner is sharded over 'model' (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import _dense_init, cast, rmsnorm_gated


def ssm_axes(cfg: ModelConfig):
    return {
        "in_z": ("embed", "d_inner"),
        "in_x": ("embed", "d_inner"),
        "in_B": ("embed", "ssm_state"),
        "in_C": ("embed", "ssm_state"),
        "in_dt": ("embed", "ssm_heads"),
        "conv_x": ("conv", "d_inner"),
        "conv_B": ("conv", "ssm_state"),
        "conv_C": ("conv", "ssm_state"),
        "A_log": ("ssm_heads",),
        "D_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("d_inner",),
        "out": ("d_inner", "embed"),
    }


def init_ssm(key, cfg: ModelConfig):
    D, din, ds, nh, cw = (
        cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv,
    )
    ks = jax.random.split(key, 8)
    params = {
        "in_z": _dense_init(ks[0], (D, din)),
        "in_x": _dense_init(ks[1], (D, din)),
        "in_B": _dense_init(ks[2], (D, ds)),
        "in_C": _dense_init(ks[3], (D, ds)),
        "in_dt": _dense_init(ks[4], (D, nh), scale=0.02),
        "conv_x": _dense_init(ks[5], (cw, din), scale=1.0 / np.sqrt(cw)),
        "conv_B": _dense_init(ks[6], (cw, ds), scale=1.0 / np.sqrt(cw)),
        "conv_C": _dense_init(ks[7], (cw, ds), scale=1.0 / np.sqrt(cw)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((din,), jnp.float32),
        "out": _dense_init(ks[0], (din, D), scale=1.0 / np.sqrt(din) / np.sqrt(2 * cfg.num_layers)),
    }
    return params, ssm_axes(cfg)


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (cw,C)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i : i + S, :] * w[i][None, None, :]
    return jax.nn.silu(out)


def _projections(cfg: ModelConfig, p, h):
    dt_ = jnp.dtype(cfg.compute_dtype)
    z = jnp.einsum("bsd,di->bsi", h, cast(p["in_z"], dt_))
    x = jnp.einsum("bsd,di->bsi", h, cast(p["in_x"], dt_))
    Bc = jnp.einsum("bsd,dn->bsn", h, cast(p["in_B"], dt_))
    Cc = jnp.einsum("bsd,dn->bsn", h, cast(p["in_C"], dt_))
    dt_raw = jnp.einsum("bsd,dn->bsn", h, cast(p["in_dt"], dt_))
    z = constrain(z, "batch", "seq", "d_inner")
    x = constrain(x, "batch", "seq", "d_inner")
    return z, x, Bc, Cc, dt_raw


def ssd_chunked(x, dt, A, Bc, Cc, chunk, initial_state=None):
    """The SSD chunked scan (pure jnp oracle; mirrored by the Pallas kernel).

    x (B,S,nh,hp); dt (B,S,nh) (already softplus'ed); A (nh,) negative;
    Bc/Cc (B,S,ds) shared over heads.  Returns (y (B,S,nh,hp),
    final_state (B,nh,hp,ds)).
    """
    B, S, nh, hp = x.shape
    ds = Bc.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    a = (dt * A[None, None, :]).astype(jnp.float32)       # (B,S,nh) log-decay
    ar = a.reshape(B, nc, Q, nh)
    cum = jnp.cumsum(ar, axis=2)                          # (B,nc,Q,nh)
    cum_h = cum.transpose(0, 1, 3, 2)                     # (B,nc,nh,Q)
    xr = x.reshape(B, nc, Q, nh, hp)
    dtr = dt.reshape(B, nc, Q, nh).astype(jnp.float32)
    Br = Bc.reshape(B, nc, Q, ds).astype(jnp.float32)
    Cr = Cc.reshape(B, nc, Q, ds).astype(jnp.float32)

    # intra-chunk (quadratic within chunk).  Mask BEFORE exp: the masked
    # upper triangle has positive diffs whose exp overflows, and grad through
    # where(c, inf, 0) is NaN (0 * inf).
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cr, Br)            # (B,nc,Q,Q)
    diff = cum_h[..., :, None] - cum_h[..., None, :]      # (B,nc,nh,Q,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.exp(jnp.where(causal[None, None, None], diff, -1e30))
    w = CB[:, :, None] * decay * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", w, xr.astype(jnp.float32))

    # chunk states: sum_s exp(cum_last - cum_s) * dt_s * B_s (x) x_s
    dec_last = jnp.exp(cum_h[..., -1:] - cum_h)           # (B,nc,nh,Q)
    sd = dec_last * dtr.transpose(0, 1, 3, 2)             # (B,nc,nh,Q)
    states = jnp.einsum("bchs,bcsn,bcshp->bchpn", sd, Br, xr.astype(jnp.float32))

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum_h[..., -1])                 # (B,nc,nh)
    if initial_state is None:
        init = jnp.zeros((B, nh, hp, ds), jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    def body(carry, xs):
        dec_c, st_c = xs  # (B,nh), (B,nh,hp,ds)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev

    (final, prevs) = jax.lax.scan(
        body, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    prevs = prevs.transpose(1, 0, 2, 3, 4)                # (B,nc,nh,hp,ds)

    # inter-chunk output: C_t . (decay-to-t * state_entering_chunk)
    dec_in = jnp.exp(cum_h)                               # (B,nc,nh,Q)
    y_inter = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cr, prevs, dec_in)

    y = (y_intra + y_inter).reshape(B, S, nh, hp).astype(x.dtype)
    return y, final.astype(x.dtype)


def ssm_forward(cfg: ModelConfig, p, h, *, return_cache=False):
    """Train / prefill.  h (B,S,D) -> out (B,S,D) [, cache dict]."""
    B, S, D = h.shape
    nh, hp, ds, cw = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    z, x, Bc, Cc, dt_raw = _projections(cfg, p, h)
    dt_ = jnp.dtype(cfg.compute_dtype)

    x = _causal_conv(x, cast(p["conv_x"], dt_))
    Bc = _causal_conv(Bc, cast(p["conv_B"], dt_))
    Cc = _causal_conv(Cc, cast(p["conv_C"], dt_))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, S, nh, hp)
    if cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd_scan.ops import ssd_scan as _ssd

        y, final = _ssd(xh, dt, A, Bc, Cc, chunk=cfg.ssm_chunk,
                        interpret=(cfg.attn_impl == "pallas_interpret"))
    else:
        y, final = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk)
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, nh * hp)
    y = rmsnorm_gated(p["norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, cast(p["out"], dt_))
    out = constrain(out, "batch", "seq", "embed")
    if not return_cache:
        return out, None
    # prefill cache: final SSM state + last (cw-1) pre-activation conv inputs
    # (recompute raw projections' tail — cheap, avoids storing full streams)
    conv_tail = {
        "x": jax.lax.stop_gradient(_tail_raw(cfg, p, h, "in_x", cw)),
        "B": jax.lax.stop_gradient(_tail_raw(cfg, p, h, "in_B", cw)),
        "C": jax.lax.stop_gradient(_tail_raw(cfg, p, h, "in_C", cw)),
    }
    return out, {"ssm": final, "conv": conv_tail}


def _tail_raw(cfg, p, h, name, cw):
    dt_ = jnp.dtype(cfg.compute_dtype)
    tail = h[:, -(cw - 1) :, :]
    return jnp.einsum("bsd,dn->bsn", tail, cast(p[name], dt_))


def init_ssm_cache(cfg: ModelConfig, batch, dtype):
    nh, hp, ds, cw, din = (
        cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv, cfg.d_inner,
    )
    return {
        "ssm": jnp.zeros((batch, nh, hp, ds), dtype),
        "conv": {
            "x": jnp.zeros((batch, cw - 1, din), dtype),
            "B": jnp.zeros((batch, cw - 1, ds), dtype),
            "C": jnp.zeros((batch, cw - 1, ds), dtype),
        },
    }


def ssm_decode_forward(cfg: ModelConfig, p, h, cache):
    """One-token decode.  h (B,1,D); cache {'ssm' (B,nh,hp,ds), 'conv' {...}}."""
    B = h.shape[0]
    nh, hp, ds, cw = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    z, x_raw, B_raw, C_raw, dt_raw = _projections(cfg, p, h)
    dt_ = jnp.dtype(cfg.compute_dtype)

    def conv_step(raw_new, tail, w):
        # tail (B,cw-1,C) raw history; raw_new (B,1,C)
        window = jnp.concatenate([tail, raw_new], axis=1)  # (B,cw,C)
        out = jnp.einsum("bsc,sc->bc", window, w)[:, None, :]
        return jax.nn.silu(out), window[:, 1:, :]

    x, tail_x = conv_step(x_raw, cache["conv"]["x"], cast(p["conv_x"], dt_))
    Bc, tail_B = conv_step(B_raw, cache["conv"]["B"], cast(p["conv_B"], dt_))
    Cc, tail_C = conv_step(C_raw, cache["conv"]["C"], cast(p["conv_C"], dt_))

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    xh = x[:, 0].reshape(B, nh, hp).astype(jnp.float32)
    decay = jnp.exp(dt * A[None])  # (B,nh)
    state = cache["ssm"].astype(jnp.float32)
    state = state * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bc[:, 0].astype(jnp.float32), xh, dt
    )
    y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), state)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, 1 * nh * hp)[:, None, :].astype(h.dtype)
    y = rmsnorm_gated(p["norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, cast(p["out"], dt_))
    new_cache = {
        "ssm": state.astype(cache["ssm"].dtype),
        "conv": {"x": tail_x, "B": tail_B, "C": tail_C},
    }
    return out, new_cache
