"""Shared layers: norms, RoPE, SwiGLU MLP, embeddings, chunked cross-entropy.

All layers are functional: ``init_*`` returns ``(params, axes)`` where `axes`
mirrors `params` with logical dim-name tuples (consumed by the sharding
engine); ``*_forward`` are pure functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.distributed.sharding import constrain


def _dense_init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def cast(x, dtype_str):
    return x.astype(jnp.dtype(dtype_str))


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def rmsnorm(p, x, eps):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(dtype)


def rmsnorm_gated(scale, x, z, eps):
    """Mamba-2 gated norm: RMSNorm(x * silu(z)) * scale."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions, head_dim, theta):
    """positions (...,S) -> cos/sin (...,S, head_dim//2), fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B,S,H,hd); cos/sin (B,S,half) or (S,half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dtype = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    return jnp.concatenate([r1, r2], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------


def mlp_axes(cfg: ModelConfig):
    return {"w1": ("embed", "ffn"), "w3": ("embed", "ffn"), "w2": ("ffn", "embed")}


def init_mlp(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w1": _dense_init(k1, (D, F)),
        "w3": _dense_init(k2, (D, F)),
        "w2": _dense_init(k3, (F, D), scale=1.0 / np.sqrt(F) / np.sqrt(2 * cfg.num_layers)),
    }
    return params, mlp_axes(cfg)


def mlp_forward(cfg: ModelConfig, p, h):
    dt = jnp.dtype(cfg.compute_dtype)
    w1, w3, w2 = cast(p["w1"], dt), cast(p["w3"], dt), cast(p["w2"], dt)
    a = jnp.einsum("bsd,df->bsf", h, w1)
    g = jnp.einsum("bsd,df->bsf", h, w3)
    a = constrain(a, "batch", "seq", "ffn")
    z = jax.nn.silu(a) * g
    out = jnp.einsum("bsf,fd->bsd", z, w2)
    return constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy
# ---------------------------------------------------------------------------


def embedding_axes(cfg: ModelConfig):
    axes = {"tokens": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    return axes


def init_embedding(key, cfg: ModelConfig):
    V, D = cfg.vocab_size, cfg.d_model
    k1, k2 = jax.random.split(key)
    params = {"tokens": _dense_init(k1, (V, D), scale=0.02)}
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(k2, (D, V))
    return params, embedding_axes(cfg)


def embed_tokens(cfg: ModelConfig, p, tokens):
    table = cast(p["tokens"], cfg.compute_dtype)
    h = jnp.take(table, tokens, axis=0)
    return constrain(h, "batch", "seq", "embed")


def lm_head_weight(cfg: ModelConfig, embed_params):
    if cfg.tie_embeddings:
        return cast(embed_params["tokens"].T, cfg.compute_dtype)  # (D, V)
    return cast(embed_params["lm_head"], cfg.compute_dtype)


def chunked_cross_entropy(cfg: ModelConfig, h, w_head, labels):
    """Mean CE over labels >= 0; logits materialized loss_chunk tokens at a
    time along seq (bounds the (B, chunk, V) transient for 257k vocabs)."""
    B, S, D = h.shape
    C = min(cfg.loss_chunk, S)
    n_chunks = S // C
    rem = S - n_chunks * C

    def chunk_loss(h_c, y_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, w_head)
        logits = constrain(logits, "batch", "seq", "vocab").astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1
        )[..., 0]
        valid = (y_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    def body(carry, xs):
        tot, cnt = carry
        h_c, y_c = xs
        l, n = chunk_loss(h_c, y_c)
        return (tot + l, cnt + n), None

    hs = h[:, : n_chunks * C].reshape(B, n_chunks, C, D).swapaxes(0, 1)
    ys = labels[:, : n_chunks * C].reshape(B, n_chunks, C).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys))
    if rem:
        l, n = chunk_loss(h[:, n_chunks * C :], labels[:, n_chunks * C :])
        tot, cnt = tot + l, cnt + n
    return tot / jnp.maximum(cnt, 1.0)
