"""GQA attention: full / chunked-causal (flash-style online softmax in jnp)
train-prefill paths and a KV-cache decode path.

The Pallas flash kernel (repro.kernels.flash_attention) is dispatched via
``cfg.attn_impl``; the jnp paths here are the XLA production fallback and the
oracle the kernel is tested against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import _dense_init, apply_rope, cast, rope_angles


def attention_axes(cfg: ModelConfig):
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        axes["bq"] = ("heads", "head_dim")
        axes["bk"] = ("kv_heads", "head_dim")
        axes["bv"] = ("kv_heads", "head_dim")
    return axes


def init_attention(key, cfg: ModelConfig):
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(k1, (D, H, hd)),
        "wk": _dense_init(k2, (D, K, hd)),
        "wv": _dense_init(k3, (D, K, hd)),
        "wo": _dense_init(k4, (H, hd, D), scale=1.0 / np.sqrt(H * hd) / np.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((H, hd), jnp.float32)
        params["bk"] = jnp.zeros((K, hd), jnp.float32)
        params["bv"] = jnp.zeros((K, hd), jnp.float32)
    return params, attention_axes(cfg)


def _project_qkv(cfg: ModelConfig, p, h, positions):
    dt = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", h, cast(p["wq"], dt))
    k = jnp.einsum("bsd,dhk->bshk", h, cast(p["wk"], dt))
    v = jnp.einsum("bsd,dhk->bshk", h, cast(p["wv"], dt))
    if cfg.qkv_bias:
        q = q + cast(p["bq"], dt)
        k = k + cast(p["bk"], dt)
        v = v + cast(p["bv"], dt)
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _full_causal_attention(q, k, v, scale):
    """q (B,S,K,G,hd); k,v (B,S,K,hd).  Materializes (B,K,G,S,S)."""
    S = q.shape[1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask, scores.astype(jnp.float32), -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _chunked_causal_attention(q, k, v, scale, chunk_q, chunk_k):
    """Flash-style online softmax in jnp: O(S*chunk) memory, full S^2 FLOPs
    (masked); the Pallas kernel additionally skips fully-masked KV blocks."""
    B, S, K, G, hd = q.shape
    Cq = min(chunk_q, S)
    Ck = min(chunk_k, S)
    nq, nk = S // Cq, S // Ck
    assert nq * Cq == S and nk * Ck == S, (S, Cq, Ck)

    qs = q.reshape(B, nq, Cq, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, Ck, K, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, Ck, K, hd).transpose(1, 0, 2, 3, 4)
    q_pos = (jnp.arange(nq)[:, None] * Cq + jnp.arange(Cq)[None, :])  # (nq,Cq)
    k_pos = (jnp.arange(nk)[:, None] * Ck + jnp.arange(Ck)[None, :])  # (nk,Ck)

    def q_body(_, xs):
        q_c, qp = xs  # (B,Cq,K,G,hd), (Cq,)

        def kv_body(carry, kxs):
            m, l, acc = carry
            k_c, v_c, kp = kxs
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_c, k_c).astype(jnp.float32) * scale
            causal = qp[:, None] >= kp[None, :]  # (Cq,Ck)
            s = jnp.where(causal[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(q_c.dtype), v_c)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, Cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, K, G, Cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, k_pos))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4).astype(q_c.dtype)  # (B,Cq,K,G,hd)

    _, outs = jax.lax.scan(q_body, None, (qs, q_pos))  # (nq,B,Cq,K,G,hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)


def attention_forward(cfg: ModelConfig, p, h, positions):
    """Train / prefill attention.  Returns (out (B,S,D), (k, v)) — the final
    K/V (for prefill cache construction)."""
    B, S, D = h.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    q, k, v = _project_qkv(cfg, p, h, positions)
    qg = q.reshape(B, S, K, G, hd)
    scale = 1.0 / np.sqrt(hd)
    if cfg.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention.ops import flash_attention

        ctx = flash_attention(
            qg, k, v, scale=scale,
            interpret=(cfg.attn_impl == "pallas_interpret"),
        )
    elif S >= cfg.attn_chunk_threshold:
        ctx = _chunked_causal_attention(qg, k, v, scale, cfg.attn_chunk, cfg.attn_chunk)
    else:
        ctx = _full_causal_attention(qg, k, v, scale)
    ctx = constrain(ctx.reshape(B, S, H, hd), "batch", "seq", "heads", "head_dim")
    dt = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", ctx, cast(p["wo"], dt))
    return constrain(out, "batch", "seq", "embed"), (k, v)


def decode_attention_forward(cfg: ModelConfig, p, h, cache, cache_index):
    """One-token decode.  h (B,1,D); cache {'k','v'} (B,S_max,K,hd) with the
    seq dim sharded over 'model' (cache_seq) when kv_heads < |model|."""
    B, _, D = h.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // K
    positions = jnp.full((B, 1), cache_index, jnp.int32)
    q, k_new, v_new = _project_qkv(cfg, p, h, positions)

    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), cache_index, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), cache_index, axis=1)
    k_cache = constrain(k_cache, "batch", "cache_seq", "kv_heads", "head_dim")
    v_cache = constrain(v_cache, "batch", "cache_seq", "kv_heads", "head_dim")

    qg = q.reshape(B, K, G, hd)
    scale = 1.0 / np.sqrt(hd)
    S_max = k_cache.shape[1]
    if cfg.decode_split and S_max % cfg.decode_split == 0:
        # flash-decoding split softmax: per-chunk (m, l, acc) partials stay
        # on the shard that owns the KV chunk; only the (B,K,G,nc[,hd])
        # partials cross the mesh for the log-sum-exp merge — versus
        # all-gathering the whole (B,S,K,hd) cache (EXPERIMENTS.md §Perf).
        nc = cfg.decode_split
        Sc = S_max // nc
        kc = k_cache.reshape(B, nc, Sc, K, hd)
        vc = v_cache.reshape(B, nc, Sc, K, hd)
        kc = constrain(kc, "batch", "cache_seq", None, "kv_heads", "head_dim")
        vc = constrain(vc, "batch", "cache_seq", None, "kv_heads", "head_dim")
        s = jnp.einsum("bkgh,bcskh->bkgcs", qg, kc).astype(jnp.float32) * scale
        pos = (jnp.arange(nc)[:, None] * Sc + jnp.arange(Sc)[None, :])
        valid = pos[None, None, None] <= cache_index
        s = jnp.where(valid, s, -1e30)
        m_c = jnp.max(s, axis=-1)                       # (B,K,G,nc)
        pr = jnp.exp(s - m_c[..., None])
        l_c = jnp.sum(pr, axis=-1)                      # (B,K,G,nc)
        acc_c = jnp.einsum("bkgcs,bcskh->bkgch", pr.astype(qg.dtype), vc)
        acc_c = constrain(acc_c, "batch", "kv_heads", None, "cache_seq", "head_dim")
        # merge partials (tiny, crosses the model axis)
        m = jnp.max(m_c, axis=-1, keepdims=True)        # (B,K,G,1)
        w = jnp.exp(m_c - m)                            # (B,K,G,nc)
        l = jnp.sum(l_c * w, axis=-1)
        ctx = jnp.einsum("bkgch,bkgc->bkgh",
                         acc_c.astype(jnp.float32), w) / jnp.maximum(
            l, 1e-20)[..., None]
        ctx = ctx.astype(qg.dtype)
    else:
        s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * scale
        valid = jnp.arange(S_max)[None, None, None, :] <= cache_index
        s = jnp.where(valid, s, -jnp.inf)
        probs = jax.nn.softmax(s, axis=-1).astype(qg.dtype)
        ctx = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    dt = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bhk,hkd->bd", ctx.reshape(B, H, hd), cast(p["wo"], dt))
    return out[:, None, :], {"k": k_cache, "v": v_cache}
