"""LM backbone: period-structured blocks (attention / mamba2 mixers, dense /
MoE / no FFN) scanned over depth with per-block remat.

The scan-over-blocks layout keeps HLO size O(1) in depth, which is what makes
512-device dry-run compiles of 80-layer models tractable; block params are
stacked on a leading 'blocks' axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import (
    FFN_MOE, FFN_NONE, MIXER_ATTN, ModelConfig,
)
from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.frontends import apply_frontend
from repro.models.layers import (
    chunked_cross_entropy, embed_tokens, init_embedding, init_rmsnorm,
    lm_head_weight, rmsnorm,
)

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _is_axes_leaf(t):
    return isinstance(t, tuple) and all(isinstance(x, (str, type(None))) for x in t)


def _init_block(cfg: ModelConfig, key):
    params = {}
    specs = cfg.layer_specs()
    keys = jax.random.split(key, 2 * len(specs))
    for i, spec in enumerate(specs):
        pp = {}
        pp["norm1"], _ = init_rmsnorm(cfg.d_model)
        if spec.mixer == MIXER_ATTN:
            pp["mixer"], _ = attn_mod.init_attention(keys[2 * i], cfg)
        else:
            pp["mixer"], _ = ssm_mod.init_ssm(keys[2 * i], cfg)
        if spec.ffn != FFN_NONE:
            pp["norm2"], _ = init_rmsnorm(cfg.d_model)
            if spec.ffn == FFN_MOE:
                pp["ffn"], _ = moe_mod.init_moe(keys[2 * i + 1], cfg)
            else:
                from repro.models.layers import init_mlp

                pp["ffn"], _ = init_mlp(keys[2 * i + 1], cfg)
        params[f"pos{i}"] = pp
    return params


def _block_axes(cfg: ModelConfig):
    from repro.models.attention import attention_axes
    from repro.models.layers import embedding_axes, mlp_axes
    from repro.models.moe import moe_axes
    from repro.models.ssm import ssm_axes

    axes = {}
    for i, spec in enumerate(cfg.layer_specs()):
        pa = {"norm1": {"scale": ("embed",)}}
        pa["mixer"] = attention_axes(cfg) if spec.mixer == MIXER_ATTN else ssm_axes(cfg)
        if spec.ffn != FFN_NONE:
            pa["norm2"] = {"scale": ("embed",)}
            pa["ffn"] = moe_axes(cfg) if spec.ffn == FFN_MOE else mlp_axes(cfg)
        axes[f"pos{i}"] = pa
    return axes


def params_axes(cfg: ModelConfig):
    """Logical dim-name metadata tree matching init_params' params tree."""
    from repro.models.layers import embedding_axes

    axes = {
        "embed": embedding_axes(cfg),
        "blocks": jax.tree_util.tree_map(
            lambda t: ("blocks",) + t, _block_axes(cfg), is_leaf=_is_axes_leaf
        ),
        "final_norm": {"scale": ("embed",)},
    }
    return axes


def init_params(cfg: ModelConfig, key):
    """Returns (params, axes).  Block leaves are stacked on a 'blocks' axis.
    Leaves are stored in cfg.param_dtype (bf16 for serving profiles)."""
    k_embed, k_blocks = jax.random.split(key)
    params = {}
    params["embed"], _ = init_embedding(k_embed, cfg)
    block_keys = jax.random.split(k_blocks, cfg.num_blocks)
    params["blocks"] = jax.vmap(lambda k: _init_block(cfg, k))(block_keys)
    params["final_norm"], _ = init_rmsnorm(cfg.d_model)
    pdt = jnp.dtype(cfg.param_dtype)
    if pdt != jnp.float32:
        params = jax.tree_util.tree_map(lambda x: x.astype(pdt), params)
    return params, params_axes(cfg)


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _block_forward(cfg: ModelConfig, bp, h, positions, mode, caches, cache_index):
    """One period block (cfg.block_size layers).  Returns (h, new_caches, aux)."""
    specs = cfg.layer_specs()
    new_caches = {}
    aux = jnp.float32(0.0)
    for i, spec in enumerate(specs):
        pp = bp[f"pos{i}"]
        r = rmsnorm(pp["norm1"], h, cfg.norm_eps)
        if spec.mixer == MIXER_ATTN:
            if mode == "decode":
                out, nc = attn_mod.decode_attention_forward(
                    cfg, pp["mixer"], r, caches[f"pos{i}"], cache_index
                )
            else:
                out, (k, v) = attn_mod.attention_forward(cfg, pp["mixer"], r, positions)
                nc = {"k": k, "v": v} if mode == "prefill" else None
        else:
            if mode == "decode":
                out, nc = ssm_mod.ssm_decode_forward(cfg, pp["mixer"], r, caches[f"pos{i}"])
            else:
                out, nc = ssm_mod.ssm_forward(
                    cfg, pp["mixer"], r, return_cache=(mode == "prefill")
                )
        h = h + out
        if spec.ffn != FFN_NONE:
            r = rmsnorm(pp["norm2"], h, cfg.norm_eps)
            if spec.ffn == FFN_MOE:
                out, a = moe_mod.moe_forward(cfg, pp["ffn"], r)
                aux = aux + a
            else:
                from repro.models.layers import mlp_forward

                out = mlp_forward(cfg, pp["ffn"], r)
            h = h + out
        h = constrain(h, "batch", "seq", "embed")
        if mode in ("prefill", "decode"):
            new_caches[f"pos{i}"] = nc if nc is not None else {}
    return h, new_caches, aux


def _stack_forward(cfg: ModelConfig, blocks, h, positions, mode,
                   caches=None, cache_index=None):
    """Scan blocks over depth.  Returns (h, stacked_new_caches, aux_total)."""

    if mode == "train":

        def body(carry, bp):
            hh, aux = carry
            hh, _, a = _block_forward(cfg, bp, hh, positions, "train", None, None)
            return (hh, aux + a), None

        if cfg.remat:
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat_policy == "dots" else None
            )
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        if cfg.scan_layers:
            (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), blocks)
        else:
            carry = (h, jnp.float32(0.0))
            for b in range(cfg.num_blocks):
                carry, _ = body(carry, jax.tree_util.tree_map(lambda x: x[b], blocks))
            h, aux = carry
        return h, None, aux

    if mode == "prefill":

        def body(hh, bp):
            hh, nc, _ = _block_forward(cfg, bp, hh, positions, "prefill", None, None)
            return hh, nc

        if cfg.scan_layers:
            h, caches_out = jax.lax.scan(body, h, blocks)
        else:
            ncs = []
            for b in range(cfg.num_blocks):
                h, nc = body(h, jax.tree_util.tree_map(lambda x: x[b], blocks))
                ncs.append(nc)
            caches_out = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
        return h, caches_out, jnp.float32(0.0)

    # decode
    def body(hh, xs):
        bp, cache = xs
        hh, nc, _ = _block_forward(cfg, bp, hh, positions, "decode", cache, cache_index)
        return hh, nc

    if cfg.scan_layers:
        h, caches_out = jax.lax.scan(body, h, (blocks, caches))
    else:
        ncs = []
        for b in range(cfg.num_blocks):
            h, nc = body(
                h,
                (
                    jax.tree_util.tree_map(lambda x: x[b], blocks),
                    jax.tree_util.tree_map(lambda x: x[b], caches),
                ),
            )
            ncs.append(nc)
        caches_out = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
    return h, caches_out, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, params, tokens, frontend=None):
    """Full forward to final hidden states.  Returns (h, aux_loss)."""
    h = embed_tokens(cfg, params["embed"], tokens)
    h = apply_frontend(cfg, h, frontend)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    h, _, aux = _stack_forward(cfg, params["blocks"], h, positions, "train")
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux


def lm_loss(cfg: ModelConfig, params, batch):
    """batch: {'tokens' (B,S_text), 'labels' (B,S_total), ['frontend']}."""
    h, aux = forward(cfg, params, batch["tokens"], batch.get("frontend"))
    w_head = lm_head_weight(cfg, params["embed"])
    loss = chunked_cross_entropy(cfg, h, w_head, batch["labels"])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def prefill(cfg: ModelConfig, params, tokens, frontend=None):
    """Prefill: returns (last-position logits (B,V), caches, next_index)."""
    h = embed_tokens(cfg, params["embed"], tokens)
    h = apply_frontend(cfg, h, frontend)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    h, caches, _ = _stack_forward(cfg, params["blocks"], h, positions, "prefill")
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    w_head = lm_head_weight(cfg, params["embed"])
    logits = jnp.einsum("bd,dv->bv", h[:, -1], w_head).astype(jnp.float32)
    logits = constrain(logits, "batch", "vocab")
    return logits, caches, jnp.int32(S)


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int, prefilled: int = 0):
    """Zero-initialized decode caches (leaves stacked over blocks)."""
    dt = jnp.dtype(cfg.compute_dtype)
    per_pos = {}
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.mixer == MIXER_ATTN:
            c = {
                "k": jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dt),
            }
        else:
            c = ssm_mod.init_ssm_cache(cfg, batch, dt)
        per_pos[f"pos{i}"] = c
    caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_blocks,) + x.shape), per_pos
    )
    return {"caches": caches, "index": jnp.int32(prefilled)}


def decode_state_axes(cfg: ModelConfig):
    """Logical axes for the decode state (mirrors init_decode_state)."""
    per_pos = {}
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.mixer == MIXER_ATTN:
            c = {
                "k": ("blocks", "batch", "cache_seq", "kv_heads", "head_dim"),
                "v": ("blocks", "batch", "cache_seq", "kv_heads", "head_dim"),
            }
        else:
            c = {
                "ssm": ("blocks", "batch", "ssm_heads", None, "ssm_state"),
                "conv": {
                    "x": ("blocks", "batch", None, "d_inner"),
                    "B": ("blocks", "batch", None, "ssm_state"),
                    "C": ("blocks", "batch", None, "ssm_state"),
                },
            }
        per_pos[f"pos{i}"] = c
    return {"caches": per_pos, "index": ()}


def decode_step(cfg: ModelConfig, params, state, tokens):
    """One decode step.  tokens (B,1) -> (logits (B,V), new_state)."""
    h = embed_tokens(cfg, params["embed"], tokens)
    idx = state["index"]
    positions = jnp.full((h.shape[0], 1), idx, jnp.int32)
    h, new_caches, _ = _stack_forward(
        cfg, params["blocks"], h, positions, "decode",
        caches=state["caches"], cache_index=idx,
    )
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    w_head = lm_head_weight(cfg, params["embed"])
    logits = jnp.einsum("bd,dv->bv", h[:, 0], w_head).astype(jnp.float32)
    logits = constrain(logits, "batch", "vocab")
    return logits, {"caches": new_caches, "index": idx + 1}
