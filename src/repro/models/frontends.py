"""Modality frontends for [audio]/[vlm] archs — STUBS per assignment.

``input_specs()`` provides *precomputed* frame/patch embeddings; the traced,
simulated, and dry-run subject is the transformer backbone.

- audio (musicgen): EnCodec frame-conditioning embeddings (B, S, D), added to
  the token embeddings.
- vision (paligemma): SigLIP patch embeddings (B, frontend_tokens, D),
  prepended prefix-LM style.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.config import ModelConfig


def apply_frontend(cfg: ModelConfig, h_tokens, frontend):
    if cfg.frontend is None or frontend is None:
        return h_tokens
    frontend = frontend.astype(h_tokens.dtype)
    if cfg.frontend == "audio":
        return h_tokens + frontend
    if cfg.frontend == "vision":
        return jnp.concatenate([frontend, h_tokens], axis=1)
    raise ValueError(f"unknown frontend {cfg.frontend!r}")


def text_len(cfg: ModelConfig, total_seq: int) -> int:
    """Text-token portion of a total sequence length."""
    if cfg.frontend == "vision":
        return total_seq - cfg.frontend_tokens
    return total_seq
