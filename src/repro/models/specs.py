"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell — the
dry-run's no-allocation input contract (weak-type-correct, shardable)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import (
    KIND_DECODE, KIND_PREFILL, KIND_TRAIN, ModelConfig, ShapeConfig,
)
from repro.models.frontends import text_len
from repro.models.transformer import init_decode_state


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Model-input ShapeDtypeStructs for a shape cell.

    train:   {'tokens','labels'[,'frontend']}
    prefill: {'tokens'[,'frontend']}
    decode:  {'tokens'} + a decode state from decode_state_specs().
    """
    B, S = shape.global_batch, shape.seq_len
    tl = text_len(cfg, S)
    if shape.kind == KIND_TRAIN:
        out = {"tokens": _sd((B, tl), "int32"), "labels": _sd((B, S), "int32")}
    elif shape.kind == KIND_PREFILL:
        out = {"tokens": _sd((B, tl), "int32")}
    else:  # decode: one new token against a seq_len cache
        out = {"tokens": _sd((B, 1), "int32")}
    if cfg.frontend == "vision" and shape.kind != KIND_DECODE:
        out["frontend"] = _sd((B, cfg.frontend_tokens, cfg.d_model), cfg.compute_dtype)
    elif cfg.frontend == "audio" and shape.kind != KIND_DECODE:
        out["frontend"] = _sd((B, tl, cfg.d_model), cfg.compute_dtype)
    return out


def batch_axes_tree(cfg: ModelConfig, shape: ShapeConfig):
    """Logical axes for batch_specs (drives in_shardings)."""
    out = {"tokens": ("batch", "seq")}
    if shape.kind == KIND_TRAIN:
        out["labels"] = ("batch", "seq")
    if "frontend" in batch_specs(cfg, shape):
        out["frontend"] = ("batch", "seq", "embed")
    return out


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode state (KV caches of seq_len) via eval_shape."""
    return jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len,
                                  prefilled=0)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Everything the lowered step consumes (minus train state params)."""
    specs = {"batch": batch_specs(cfg, shape)}
    if shape.kind == KIND_DECODE:
        specs["state"] = decode_state_specs(cfg, shape)
    return specs
