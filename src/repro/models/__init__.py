from repro.models.transformer import (
    init_params,
    forward,
    prefill,
    decode_step,
    init_decode_state,
    lm_loss,
)
