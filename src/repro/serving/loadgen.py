"""Open-loop Poisson load generation for the plan-serving SLO benchmarks.

Open-loop means arrivals are SCHEDULED up front from a Poisson process and
submitted at their scheduled time regardless of how the server is doing —
latency is measured from the *scheduled* arrival, so a stalled server
accumulates the queueing delay it actually caused (no coordinated
omission; cf. "Parallelizing a modern GPU simulator"'s throughput-vs-
latency framing and standard serving-bench practice).
"""

from __future__ import annotations

import time
from concurrent.futures import wait
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.sampling.engine import PlanRequest


def synthetic_fleet(n_requests: int, d: int = 16, seed: int = 0,
                    n_lo: int = 20, n_hi: int = 60) -> list[PlanRequest]:
    """Blob-structured per-request embedding matrices (K selection has
    signal), sizes spread across point buckets like the scenario grid;
    per-request seeds exercise the mixed-seed chunk path."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_requests):
        k_true = int(rng.integers(2, 6))
        n_per = int(rng.integers(n_lo, n_hi)) // k_true + 2
        centers = rng.standard_normal((k_true, d)) * 40.0
        x = np.concatenate(
            [c + rng.standard_normal((n_per, d)) * 0.5 for c in centers]
        ).astype(np.float32)
        fleet.append(PlanRequest(x, np.arange(len(x)), "loadgen", seed=i))
    return fleet


def poisson_arrivals(n: int, rate_hz: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival times (seconds) of a rate-``rate_hz`` Poisson
    process: exponential inter-arrival gaps."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, size=n))


@dataclass
class LoadResult:
    """One open-loop run at one offered load."""
    offered_per_s: float
    n_requests: int
    n_ok: int
    n_err: int
    wall_s: float
    plans_per_s: float               # completed plans / wall
    latency_ms: dict                 # p50/p99/mean from scheduled arrival
    service: dict = field(default_factory=dict)  # PlanService.stats()

    def to_json(self) -> dict:
        return {
            "offered_per_s": self.offered_per_s,
            "n_requests": self.n_requests,
            "n_ok": self.n_ok, "n_err": self.n_err,
            "wall_s": self.wall_s, "plans_per_s": self.plans_per_s,
            "latency_ms": self.latency_ms,
            "service": self.service,
        }


def run_open_loop(service, requests: list[PlanRequest], rate_hz: float,
                  seed: int = 0,
                  arrivals: Optional[np.ndarray] = None) -> LoadResult:
    """Drive ``service`` with the request list at offered load ``rate_hz``.

    Submits each request at its scheduled Poisson arrival (sleeping between
    arrivals; a late generator submits immediately and the lateness counts
    against latency), records completion timestamps via done-callbacks, and
    summarizes p50/p99 latency and completed plans/s."""
    n = len(requests)
    if arrivals is None:
        arrivals = poisson_arrivals(n, rate_hz, seed)
    done_t = [None] * n
    futures = []
    service.reset_stats()
    t0 = time.perf_counter()
    for i, (req, t_arr) in enumerate(zip(requests, arrivals)):
        lag = t_arr - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        fut = service.submit(req)

        def _mark(f, i=i):
            done_t[i] = time.perf_counter() - t0

        fut.add_done_callback(_mark)
        futures.append(fut)
    wait(futures)
    wall = time.perf_counter() - t0
    errs = sum(1 for f in futures if f.exception() is not None)
    lat_ms = np.array([(done_t[i] - arrivals[i]) * 1e3 for i in range(n)])
    return LoadResult(
        offered_per_s=float(rate_hz), n_requests=n, n_ok=n - errs,
        n_err=errs, wall_s=wall,
        plans_per_s=(n - errs) / max(wall, 1e-9),
        latency_ms={
            "p50": float(np.percentile(lat_ms, 50)),
            "p99": float(np.percentile(lat_ms, 99)),
            "mean": float(lat_ms.mean()),
            "max": float(lat_ms.max()),
        },
        service=service.stats(),
    )
