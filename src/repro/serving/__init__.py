"""repro.serving — sampling-as-a-service (DESIGN.md §9).

Long-lived, continuous-batched plan serving over
:class:`repro.sampling.PlanEngine`:

    from repro.serving import PlanService

    with PlanService(max_batch=8, max_delay_ms=5.0) as svc:
        svc.warmup([(64, 16)])                  # compiles off the hot path
        fut = svc.submit(PlanRequest(emb, seqs, "gcl"))
        plan = fut.result()

:class:`PlanService` admits requests as they arrive, coalesces them into
the engine's ``(points-bucket, dim)`` groups, and dispatches a bucket when
it fills to ``max_batch`` OR its deadline expires — never
barrier-per-grid.  :mod:`repro.serving.loadgen` drives it with open-loop
Poisson traffic for the SLO benchmarks
(``benchmarks/bench_serve_latency.py``).

NOT to be confused with ``repro.launch.serve``, which serves model
*decode* traffic (prefill + KV-cache decode); this package serves
*sampling plans*.
"""

from repro.serving.loadgen import (
    LoadResult, poisson_arrivals, run_open_loop, synthetic_fleet,
)
from repro.serving.service import PlanService, parse_buckets

__all__ = [
    "LoadResult", "PlanService", "parse_buckets", "poisson_arrivals",
    "run_open_loop", "synthetic_fleet",
]
