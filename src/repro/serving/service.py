"""Continuous-batched plan serving (DESIGN.md §9).

Requests arrive one at a time; the engine is fastest many-at-a-time.  The
:class:`PlanService` bridges the two with the standard continuous-batching
loop (cf. SimNet's batched-inference serving and LLM decode servers):

- ``submit`` enqueues a :class:`~repro.sampling.engine.PlanRequest` into
  its ``(points-bucket, dim)`` queue — the SAME grouping key the engine
  pads and compiles by — and returns a ``Future``;
- one dispatcher thread watches every bucket queue and flushes a bucket
  when it reaches ``max_batch`` (fill) OR its oldest request has waited
  ``max_delay_ms`` (deadline).  Buckets flush independently — a slow/empty
  bucket never barriers another (no barrier-per-grid);
- dispatches run through ``PlanEngine.plan_many(errors="isolate")``: a
  poison request fails only its own future, and host-side plan building
  overlaps the next chunk's device work inside the engine;
- ``warmup`` pre-builds the executables for an expected bucket set
  (:meth:`repro.sampling.engine.PlanEngine.warmup`), taking cold-start
  compiles off the serving path entirely.

Tenant traffic enters through ``submit_program``: prepare (or REPLAY via
the content-hash :class:`~repro.sampling.store.ArtifactStore`, so repeated
tenants never refit an encoder) happens on the caller's thread, then the
method's engine-ready :class:`PlanRequest` joins the shared batch queues.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.sampling.engine import (
    PlanEngine, PlanRequest, bucket_key,
)


def parse_buckets(spec: str) -> list[tuple[int, int]]:
    """Parse a ``--warmup-buckets`` CLI spec: comma-separated
    ``<points>x<dim>`` pairs, e.g. ``"64x16,128x16"``."""
    out = []
    for part in str(spec).split(","):
        part = part.strip()
        if not part:
            continue
        p, _, d = part.partition("x")
        out.append((int(p), int(d)))
    return out


@dataclass
class _Pending:
    request: PlanRequest
    future: Future
    t_submit: float


class PlanService:
    """Long-lived continuous batcher over one :class:`PlanEngine`.

    Use as a context manager (the dispatcher thread starts on construction
    and ``close()`` drains every queue before returning)::

        with PlanService(max_batch=8, max_delay_ms=5.0) as svc:
            svc.warmup([(64, 16)])
            plan = svc.submit(req).result()

    ``engine`` defaults to a fresh :class:`PlanEngine` built from
    ``engine_overrides`` (k_max, iters, seed, ...) with per-request timing
    telemetry on; pass an explicit engine to share executables/config with
    other consumers.
    """

    def __init__(self, engine: Optional[PlanEngine] = None, *,
                 max_batch: Optional[int] = None,
                 max_delay_ms: float = 5.0,
                 sanitize: bool = False,
                 fault_hook=None,
                 **engine_overrides):
        if engine is None:
            kw = dict(max_batch=max_batch or 8, record_timings=True)
            kw.update(engine_overrides)
            engine = PlanEngine(**kw)
        elif engine_overrides:
            raise ValueError("pass engine_overrides only without engine")
        self.engine = engine
        if fault_hook is not None:
            # scale-out fault injection (tests / chaos drills): the engine
            # degrades — halves its shard width and retries — rather than
            # failing futures; the drop shows up in stats()["engine"]
            # (degraded_dispatches, data_shards)
            self.engine.fault_hook = fault_hook
        #: when on, every served plan passes the NaN/inf tripwire
        #: (repro.analysis.sanitize.check_finite); a non-finite plan fails
        #: only its own future, like any isolated engine error
        self.sanitize = bool(sanitize)
        self.max_batch = int(max_batch or engine.cfg.max_batch)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self._queues: dict[tuple, deque] = {}
        self._cv = threading.Condition()
        self._stop = False
        self._mlock = threading.Lock()
        self.metrics = self._fresh_metrics()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="plan-service-dispatch")
        self._thread.start()

    @staticmethod
    def _fresh_metrics() -> dict:
        return {
            "submitted": 0, "served": 0, "failed": 0, "dispatches": 0,
            "batch_sizes": [], "dispatch_s": [], "latencies_s": [],
            "queue_depth_samples": [], "sanitize_trips": 0,
            "flush_causes": {"fill": 0, "deadline": 0, "drain": 0},
        }

    # -- client surface ------------------------------------------------------
    def submit(self, request: PlanRequest) -> Future:
        """Enqueue one request; returns a Future resolving to its
        SamplingPlan (or raising the request's own isolated error)."""
        fut: Future = Future()
        try:
            key = bucket_key(request.embeddings)
        except Exception as e:
            # malformed embeddings: fail fast, never poison a queue
            with self._mlock:
                self.metrics["submitted"] += 1
                self.metrics["failed"] += 1
            fut.set_exception(e)
            return fut
        item = _Pending(request, fut, time.perf_counter())
        with self._cv:
            if self._stop:
                fut.set_exception(RuntimeError("PlanService is closed"))
                return fut
            self._queues.setdefault(key, deque()).append(item)
            depth = sum(len(q) for q in self._queues.values())
            self._cv.notify()
        with self._mlock:
            self.metrics["submitted"] += 1
            self.metrics["queue_depth_samples"].append(depth)
        return fut

    def plan(self, embeddings, seqs, method: str = "",
             seed: Optional[int] = None, extra: Optional[dict] = None):
        """Blocking convenience wrapper around one ``submit``."""
        return self.submit(PlanRequest(embeddings, seqs, method, seed=seed,
                                       extra=extra or {})).result()

    def submit_program(self, method, program, store=None) -> Future:
        """Serve a traced program end-to-end: ``run_prepare`` (load-or-
        prepare through ``store`` — a replayed gcl encoder never refits,
        and attaching the store also backs gcl ingestion with the run's
        packed-graph cache, so a warm tenant re-traces ZERO kernels on
        re-prepare: DESIGN.md §13), then the method's engine-ready request
        joins the batch queues.  Methods that don't plan through the
        engine (sieve, stem_root) resolve immediately via their own
        ``plan``.

        Runs prepare on the CALLER's thread — the expensive stage must
        never block the dispatcher.  Plans come from THIS service's engine
        config; keep it consistent with the tenant methods' clustering
        knobs (k_max, seed, ...) if request-for-request parity with
        ``method.plan`` matters."""
        artifacts = method.run_prepare(program, store)
        request = method.plan_request(program, artifacts)
        if request is None:
            fut: Future = Future()
            try:
                fut.set_result(method.plan(program, artifacts))
            except Exception as e:
                fut.set_exception(e)
            return fut
        return self.submit(request)

    def warmup(self, buckets, batch_sizes: Optional[list] = None) -> int:
        """Pre-build executables for the expected bucket set (see
        :meth:`PlanEngine.warmup`); accepts ``(points, dim)`` pairs,
        structured dicts, or a ``"64x16,128x16"`` spec string."""
        if isinstance(buckets, str):
            buckets = parse_buckets(buckets)
        return self.engine.warmup(buckets, batch_sizes=batch_sizes)

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        """Aggregated serving counters + the engine's own stats."""
        with self._mlock:
            m = {k: (list(v) if isinstance(v, list) else
                     dict(v) if isinstance(v, dict) else v)
                 for k, v in self.metrics.items()}
        with self._cv:
            m["queue_depth"] = sum(len(q) for q in self._queues.values())
        lat = np.asarray(m.pop("latencies_s")) * 1e3
        m["latency_ms"] = {
            "p50": float(np.percentile(lat, 50)) if len(lat) else None,
            "p99": float(np.percentile(lat, 99)) if len(lat) else None,
            "mean": float(lat.mean()) if len(lat) else None,
        }
        sizes = m.pop("batch_sizes")
        m["batch_occupancy"] = (float(np.mean(sizes)) / self.max_batch
                                if sizes else None)
        m["mean_batch"] = float(np.mean(sizes)) if sizes else None
        depth = m.pop("queue_depth_samples")
        m["mean_queue_depth"] = float(np.mean(depth)) if depth else 0.0
        disp = m.pop("dispatch_s")
        m["mean_dispatch_ms"] = (float(np.mean(disp)) * 1e3 if disp
                                 else None)
        m["engine"] = self.engine.engine_stats()
        return m

    def raw_latencies_s(self) -> list[float]:
        with self._mlock:
            return list(self.metrics["latencies_s"])

    def reset_stats(self) -> None:
        """Window the serving counters (and the engine's instance
        counters) — long-lived servers call this between measurement
        intervals."""
        with self._mlock:
            self.metrics = self._fresh_metrics()
        self.engine.reset_stats()

    # -- dispatcher ----------------------------------------------------------
    def _ready_key_locked(self, now: float):
        """The bucket to flush: full first, else expired deadline (oldest
        head wins); on close, any non-empty bucket drains."""
        best, best_t = None, None
        for key, q in self._queues.items():
            if not q:
                continue
            head_t = q[0].t_submit
            ready = (len(q) >= self.max_batch or self._stop
                     or now - head_t >= self.max_delay_s)
            if ready and (best is None or head_t < best_t):
                best, best_t = key, head_t
        return best

    def _next_timeout_locked(self, now: float):
        waits = [q[0].t_submit + self.max_delay_s - now
                 for q in self._queues.values() if q]
        return max(min(waits), 0.0) if waits else None

    def _loop(self):
        while True:
            with self._cv:
                while True:
                    now = time.perf_counter()
                    key = self._ready_key_locked(now)
                    if key is not None:
                        break
                    if self._stop:
                        return
                    self._cv.wait(self._next_timeout_locked(now))
                q = self._queues[key]
                n = min(len(q), self.max_batch)
                pending = [q.popleft() for _ in range(n)]
                cause = ("fill" if n >= self.max_batch else
                         "drain" if self._stop else "deadline")
            self._dispatch(key, pending, cause)

    def _dispatch(self, key, pending, cause: str):
        reqs = [p.request for p in pending]
        t0 = time.perf_counter()
        try:
            plans = self.engine.plan_many(reqs, errors="isolate")
        except Exception as e:  # engine-level failure: fail THIS batch only
            plans = [e] * len(pending)
        if self.sanitize:
            plans = [self._sanitize_plan(p) for p in plans]
        t1 = time.perf_counter()
        served = failed = 0
        lats = []
        for p, plan in zip(pending, plans):
            lats.append(time.perf_counter() - p.t_submit)
            if isinstance(plan, Exception) or plan is None:
                failed += 1
                p.future.set_exception(
                    plan if isinstance(plan, Exception)
                    else RuntimeError("engine returned no plan"))
            else:
                served += 1
                p.future.set_result(plan)
        with self._mlock:
            m = self.metrics
            m["dispatches"] += 1
            m["batch_sizes"].append(len(pending))
            m["dispatch_s"].append(t1 - t0)
            m["flush_causes"][cause] += 1
            m["served"] += served
            m["failed"] += failed
            m["latencies_s"].extend(lats)

    def _sanitize_plan(self, plan):
        """NaN/inf tripwire per served plan (``sanitize=True``).  Returns
        the plan or the NonFiniteError that replaces it."""
        from repro.analysis.sanitize import NonFiniteError, check_finite

        if isinstance(plan, Exception) or plan is None:
            return plan
        try:
            check_finite(plan, name="plan")
        except NonFiniteError as e:
            with self._mlock:
                self.metrics["sanitize_trips"] += 1
            return e
        return plan

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain every queue (pending requests still get served), then stop
        the dispatcher.  Idempotent."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
