from repro.kernels.kmeans_assign.ops import kmeans_assign
