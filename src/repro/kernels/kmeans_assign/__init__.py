from repro.kernels.kmeans_assign.ops import (
    kmeans_assign, kmeans_assign_fused, silhouette_sums,
)
