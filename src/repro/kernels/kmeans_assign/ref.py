"""Pure-jnp oracles for the blocked K-Means kernels."""

from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(x, cent):
    """x (n,d), cent (k,d) -> (labels (n,) int32, min_sq_dist (n,) f32)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(cent * cent, axis=1)
    d = jnp.maximum(x2 - 2.0 * x @ cent.T + c2[None], 0.0)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)


def kmeans_assign_fused_ref(x, cent, cmask, pmask):
    """Oracle for the fused assign + min-dist + per-cluster-sum kernel.

    Returns (labels (n,) int32, masked min_sq_dist (n,), cluster sums (k,d),
    cluster counts (k,)).  `cmask` marks live centroid slots (dead slots
    never win an argmin); `pmask` marks real points (padding contributes
    nothing to dists/sums/counts).
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(cent * cent, axis=1)
    d = jnp.maximum(x2 - 2.0 * x @ cent.T + c2[None], 0.0)
    d = jnp.where(cmask[None, :] > 0, d, jnp.inf)
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1) * pmask
    k = cent.shape[0]
    onehot = (lab[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    onehot = onehot * pmask[:, None]
    return lab, mind, onehot.T @ x, onehot.sum(0)


def silhouette_sums_ref(x, onehot):
    """Oracle for the blocked silhouette accumulator: per-(point, cluster)
    total euclidean distance, via the full (n, n) matrix."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    d2 = jnp.maximum(x2 - 2.0 * x @ x.T + x2.T, 0.0)
    return jnp.sqrt(d2) @ onehot
