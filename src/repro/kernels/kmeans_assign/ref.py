"""Pure-jnp oracle for blocked K-Means assignment."""

from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign_ref(x, cent):
    """x (n,d), cent (k,d) -> (labels (n,) int32, min_sq_dist (n,) f32)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(cent * cent, axis=1)
    d = jnp.maximum(x2 - 2.0 * x @ cent.T + c2[None], 0.0)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)
