"""Pallas TPU kernel: blocked K-Means assignment (paper §3.4 at scale).

At framework scale the sampler clusters millions of kernel embeddings
(every invocation of every program in a fleet trace), so assignment is a
streaming (n x d) x (d x k) MXU matmul with a fused row argmin — no (n, k)
distance matrix ever hits HBM.

Grid: (n / block_n,).  BlockSpecs: x (block_n, d) streams; centroids (k, d)
stay resident (k <= a few hundred, d = 256: ~0.25 MB).  block_n = 512 keeps
the distance tile (512 x k) in VMEM and the matmul 128-aligned for d=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_rows(x, block_n):
    n = x.shape[0]
    if n % block_n:
        x = jnp.pad(x, ((0, block_n - n % block_n), (0, 0)))
    return x


def _kmeans_kernel(x_ref, c_ref, lab_ref, dist_ref):
    x = x_ref[...]                                  # (bn, d)
    c = c_ref[...]                                  # (k, d)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)      # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)                     # (k,)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (bn, k)
    d = jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)
    lab_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_fwd(x, cent, *, block_n=512, interpret=False):
    n, d = x.shape
    k = cent.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        pad = block_n - n % block_n
        x = jnp.pad(x, ((0, pad), (0, 0)))
    np_ = x.shape[0]
    grid = (np_ // block_n,)
    labels, dists = pl.pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cent)
    return labels[:n], dists[:n]


def _kmeans_fused_kernel(x_ref, c_ref, cm_ref, pm_ref,
                         lab_ref, dist_ref, sum_ref, cnt_ref):
    """Fused assign + masked min-dist + per-cluster sums/counts.

    One streaming pass produces everything a mask-aware Lloyd step needs:
    the (k, d) cluster sums and (k,) counts accumulate across the sequential
    grid (constant out index maps), so the (n, k) distance tile never leaves
    VMEM and no (n, k) one-hot hits HBM.
    """
    i = pl.program_id(0)
    x = x_ref[...]                                  # (bn, d)
    c = c_ref[...]                                  # (k, d)
    cmask = cm_ref[...]                             # (k,)   1 = live centroid
    pmask = pm_ref[...]                             # (bn,)  1 = real point
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d = jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)
    d = jnp.where(cmask[None, :] > 0, d, jnp.inf)   # dead slots never win
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    lab_ref[...] = lab
    dist_ref[...] = jnp.min(d, axis=1) * pmask      # padding adds 0 inertia
    k = c.shape[0]
    onehot = (lab[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1))
    onehot = onehot.astype(jnp.float32) * pmask[:, None]

    @pl.when(i == 0)
    def _():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    sum_ref[...] += jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                               # (k, d)
    cnt_ref[...] += jnp.sum(onehot, axis=0)         # (k,)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_fused_fwd(x, cent, cmask, pmask, *, block_n=512,
                            interpret=False):
    n, d = x.shape
    k = cent.shape[0]
    block_n = min(block_n, n)
    x = _pad_rows(x, block_n)
    pmask = jnp.pad(pmask, (0, x.shape[0] - n))
    np_ = x.shape[0]
    grid = (np_ // block_n,)
    labels, dists, sums, cnts = pl.pallas_call(
        _kmeans_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cent, cmask, pmask)
    return labels[:n], dists[:n], sums, cnts


def _sil_sums_kernel(x_ref, xb_ref, oh_ref, sum_ref):
    """Blocked silhouette accumulator: sums[i, c] += sum_j d(i, j) oh[j, c]
    over one column block j.  The (n, bn) distance tile is consumed in VMEM —
    the full (n, n) matrix is never materialized."""
    j = pl.program_id(0)
    x = x_ref[...]                                  # (n, d)  resident
    xb = xb_ref[...]                                # (bn, d) streamed block
    oh = oh_ref[...]                                # (bn, k) masked one-hot
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    b2 = jnp.sum(xb * xb, axis=1)
    xb_t = jax.lax.dot_general(
        x, xb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    dist = jnp.sqrt(jnp.maximum(x2 - 2.0 * xb_t + b2[None, :], 0.0))

    @pl.when(j == 0)
    def _():
        sum_ref[...] = jnp.zeros_like(sum_ref)

    sum_ref[...] += jax.lax.dot_general(
        dist, oh, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def silhouette_sums_fwd(x, onehot, *, block_n=512, interpret=False):
    """x (n, d), onehot (n, k) (already point-masked) ->
    sums (n, k): total euclidean distance from each point to each cluster."""
    n, d = x.shape
    k = onehot.shape[1]
    block_n = min(block_n, n)
    xb = _pad_rows(x, block_n)
    oh = _pad_rows(onehot, block_n)                 # padded rows are all-zero
    nb = xb.shape[0]
    grid = (nb // block_n,)
    sums = pl.pallas_call(
        _sil_sums_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d), lambda j: (0, 0)),
            pl.BlockSpec((block_n, d), lambda j: (j, 0)),
            pl.BlockSpec((block_n, k), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((n, k), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, xb, oh)
    return sums
