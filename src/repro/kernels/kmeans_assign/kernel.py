"""Pallas TPU kernel: blocked K-Means assignment (paper §3.4 at scale).

At framework scale the sampler clusters millions of kernel embeddings
(every invocation of every program in a fleet trace), so assignment is a
streaming (n x d) x (d x k) MXU matmul with a fused row argmin — no (n, k)
distance matrix ever hits HBM.

Grid: (n / block_n,).  BlockSpecs: x (block_n, d) streams; centroids (k, d)
stay resident (k <= a few hundred, d = 256: ~0.25 MB).  block_n = 512 keeps
the distance tile (512 x k) in VMEM and the matmul 128-aligned for d=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(x_ref, c_ref, lab_ref, dist_ref):
    x = x_ref[...]                                  # (bn, d)
    c = c_ref[...]                                  # (k, d)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)      # (bn, 1)
    c2 = jnp.sum(c * c, axis=1)                     # (k,)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (bn, k)
    d = jnp.maximum(x2 - 2.0 * xc + c2[None, :], 0.0)
    lab_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_fwd(x, cent, *, block_n=512, interpret=False):
    n, d = x.shape
    k = cent.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        pad = block_n - n % block_n
        x = jnp.pad(x, ((0, pad), (0, 0)))
    np_ = x.shape[0]
    grid = (np_ // block_n,)
    labels, dists = pl.pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(x, cent)
    return labels[:n], dists[:n]
