"""jit'd wrappers for the K-Means / silhouette kernels (no grads needed —
Lloyd's algorithm and silhouette scoring are derivative-free).

``interpret=None`` resolves through :func:`repro.kernels.default_interpret`
(interpret on CPU, compiled on TPU/GPU) so call sites never hardcode the
backend.
"""

from __future__ import annotations

from typing import Optional

from repro.kernels import default_interpret
from repro.kernels.kmeans_assign.kernel import (
    kmeans_assign_fused_fwd, kmeans_assign_fwd, silhouette_sums_fwd,
)


def _resolve(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else interpret


def kmeans_assign(x, cent, *, block_n=512, interpret: Optional[bool] = None):
    """x (n,d), cent (k,d) -> (labels (n,) int32, min_sq_dist (n,))."""
    return kmeans_assign_fwd(x, cent, block_n=block_n,
                             interpret=_resolve(interpret))


def kmeans_assign_fused(x, cent, cmask, pmask, *, block_n=512,
                        interpret: Optional[bool] = None):
    """One streaming pass of a mask-aware Lloyd step: x (n,d), cent (k,d),
    cmask (k,) live-centroid mask, pmask (n,) real-point mask ->
    (labels (n,), masked min_sq_dist (n,), cluster sums (k,d), counts (k,))."""
    return kmeans_assign_fused_fwd(x, cent, cmask, pmask, block_n=block_n,
                                   interpret=_resolve(interpret))


def silhouette_sums(x, onehot, *, block_n=512,
                    interpret: Optional[bool] = None):
    """Blocked per-(point, cluster) euclidean distance totals: x (n,d),
    point-masked onehot (n,k) -> sums (n,k).  The (n,n) matrix is consumed
    one (n, block_n) tile at a time and never materialized."""
    return silhouette_sums_fwd(x, onehot, block_n=block_n,
                               interpret=_resolve(interpret))
