"""jit'd wrapper for the K-Means assignment kernel (no grads needed —
Lloyd's algorithm is derivative-free)."""

from __future__ import annotations

from repro.kernels.kmeans_assign.kernel import kmeans_assign_fwd


def kmeans_assign(x, cent, *, block_n=512, interpret=False):
    """x (n,d), cent (k,d) -> (labels (n,) int32, min_sq_dist (n,))."""
    return kmeans_assign_fwd(x, cent, block_n=block_n, interpret=interpret)
