"""Pallas TPU kernel: RGCN message aggregation as MXU one-hot matmuls.

TPU adaptation (DESIGN.md §3): TPUs have no fast random scatter, so the
gather (h[src]) and the scatter-add (segment-sum over dst) are both cast as
dense one-hot matmuls against the node axis — MXU work instead of serialized
memory traffic.  This is the standard trick for graphs whose node count fits
VMEM (trace HRGs: N <= 2048).

Grid: (B, nE) — edge blocks stream through VMEM; the (N, nb*D) accumulator
is the kernel OUTPUT block (constant index_map over the edge dim, so Pallas
keeps it resident in VMEM and revisits it), finalized by the basis
contraction OUTSIDE the kernel (a plain dense matmul XLA already does well).

BlockSpecs (f32): h (1,N,D) <= 2048x128x4 = 1 MB; edges (1,block_e) int32;
w (1,block_e,nb); out (1,N,nb*D) <= 2 MB.  block_e = 256 keeps the two
one-hot matmuls at (256,N)x(N,D) and (N,256)x(256,nb*D) — both 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rgcn_kernel(h_ref, src_ref, dst_ref, w_ref, out_ref, *, num_nodes,
                 block_e, nb):
    ei = pl.program_id(1)

    @pl.when(ei == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[0]                       # (N, D)
    src = src_ref[0]                   # (block_e,)
    dst = dst_ref[0]
    w = w_ref[0]                       # (block_e, nb)

    iota_n = jax.lax.broadcasted_iota(jnp.int32, (block_e, num_nodes), 1)
    onehot_src = (iota_n == src[:, None]).astype(h.dtype)   # (be, N)
    onehot_dst = (iota_n == dst[:, None]).astype(h.dtype)   # (be, N)

    gathered = jax.lax.dot_general(                         # (be, D) via MXU
        onehot_src, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    D = h.shape[-1]
    weighted = (gathered[:, None, :] * w[:, :, None]).reshape(block_e, nb * D)
    scat = jax.lax.dot_general(                             # (N, nb*D) via MXU
        onehot_dst.T, weighted, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[0] += scat.astype(out_ref.dtype)


def _rgcn_flat_kernel(h_ref, src_ref, dst_ref, w_ref, out_ref, *, num_nodes,
                      block_e, nb):
    ei = pl.program_id(0)

    @pl.when(ei == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[...]                     # (P, D)
    src = src_ref[0]                   # (block_e,)
    dst = dst_ref[0]
    w = w_ref[...]                     # (block_e, nb)

    iota_n = jax.lax.broadcasted_iota(jnp.int32, (block_e, num_nodes), 1)
    onehot_src = (iota_n == src[:, None]).astype(h.dtype)   # (be, P)
    onehot_dst = (iota_n == dst[:, None]).astype(h.dtype)   # (be, P)

    gathered = jax.lax.dot_general(                         # (be, D) via MXU
        onehot_src, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    D = h.shape[-1]
    weighted = (gathered[:, None, :] * w[:, :, None]).reshape(block_e, nb * D)
    scat = jax.lax.dot_general(                             # (P, nb*D) via MXU
        onehot_dst.T, weighted, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += scat.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "block_e", "interpret")
)
def rgcn_spmm_flat_fwd(h, src, dst, w, *, num_nodes, block_e=256,
                       interpret=False):
    """Flat (packed-batch) forward: returns the pre-basis accumulator
    s: (P, nb*D).  No batch dim — the grid streams blocks of the single flat
    edge list (sorted by dst in core/batching.py, so each block's scatter
    targets are near-contiguous) against the resident (P, D) node block.
    The packed micro-batch budget (batching.MAX_NODES_PER_MICROBATCH) keeps
    h + the accumulator within VMEM."""
    (E,) = src.shape
    P, D = h.shape
    nb = w.shape[-1]
    if E == 0:  # empty edge list: aggregation is identically zero
        return jnp.zeros((P, nb * D), jnp.float32)
    block_e = min(block_e, E)
    if E % block_e != 0:  # pad edges (w=0 rows are no-ops)
        pad = block_e - E % block_e
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        E = E + pad
    ne = E // block_e
    # TPU-friendly 2-D layout for the int32 edge-index streams
    src2 = src.reshape(1, E)
    dst2 = dst.reshape(1, E)

    kernel = functools.partial(
        _rgcn_flat_kernel, num_nodes=P, block_e=block_e, nb=nb
    )
    return pl.pallas_call(
        kernel,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((P, D), lambda e: (0, 0)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((block_e, nb), lambda e: (e, 0)),
        ],
        out_specs=pl.BlockSpec((P, nb * D), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, nb * D), jnp.float32),
        interpret=interpret,
    )(h, src2, dst2, w)


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "block_e", "interpret")
)
def rgcn_spmm_fwd(h, src, dst, w, *, num_nodes, block_e=256, interpret=False):
    """Returns the pre-basis accumulator s: (B, N, nb*D)."""
    B, E = src.shape
    _, N, D = h.shape
    nb = w.shape[-1]
    if E == 0:  # empty edge list: aggregation is identically zero
        return jnp.zeros((B, N, nb * D), jnp.float32)
    block_e = min(block_e, E)
    if E % block_e != 0:  # pad edges (w=0 rows are no-ops)
        pad = block_e - E % block_e
        src = jnp.pad(src, ((0, 0), (0, pad)))
        dst = jnp.pad(dst, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)))
        E = E + pad
    ne = E // block_e

    kernel = functools.partial(
        _rgcn_kernel, num_nodes=N, block_e=block_e, nb=nb
    )
    return pl.pallas_call(
        kernel,
        grid=(B, ne),
        in_specs=[
            pl.BlockSpec((1, N, D), lambda b, e: (b, 0, 0)),
            pl.BlockSpec((1, block_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, block_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, block_e, nb), lambda b, e: (b, e, 0)),
        ],
        out_specs=pl.BlockSpec((1, N, nb * D), lambda b, e: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, nb * D), jnp.float32),
        interpret=interpret,
    )(h, src, dst, w)
