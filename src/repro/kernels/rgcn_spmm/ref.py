"""Pure-jnp oracle for RGCN message aggregation.

Computes, for each node v and output dim o:
    agg[v] = sum_k basis[k] . sum_{e: dst_e = v} w[e,k] * h[src_e]
where w already folds the relation coefficient, the edge mask and the
1/|N_r(v)| normalization (see core/rgcn.py).

h: (B,N,D); basis: (nb,D,O); src/dst: (B,E); w: (B,E,nb) -> (B,N,O)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rgcn_message_agg_ref(h, basis, src, dst, w, num_nodes: int):
    h_src = jnp.take_along_axis(h, src[:, :, None], axis=1)  # (B,E,D)
    weighted = h_src[:, :, None, :] * w[..., None]           # (B,E,nb,D)
    s = jax.vmap(
        lambda m, d: jax.ops.segment_sum(m, d, num_segments=num_nodes)
    )(weighted, dst)                                         # (B,N,nb,D)
    return jnp.einsum("bnkd,kdo->bno", s, basis)


def rgcn_message_agg_flat_ref(h, basis, src, dst, w, num_nodes: int):
    """Flat (packed-batch) variant: h (P,D); src/dst (Q,); w (Q,nb) -> (P,O).
    One global segment-sum over the flat edge list — no batch dim."""
    h_src = jnp.take(h, src, axis=0)                         # (Q,D)
    weighted = h_src[:, None, :] * w[..., None]              # (Q,nb,D)
    s = jax.ops.segment_sum(weighted, dst, num_segments=num_nodes)
    return jnp.einsum("nkd,kdo->no", s, basis)
