from repro.kernels.rgcn_spmm.ops import rgcn_message_agg
