"""jit'd wrapper: Pallas forward + oracle-vjp backward (differentiable)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rgcn_spmm.kernel import rgcn_spmm_flat_fwd, rgcn_spmm_fwd
from repro.kernels.rgcn_spmm.ref import (
    rgcn_message_agg_flat_ref, rgcn_message_agg_ref,
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def rgcn_message_agg(h, basis, src, dst, w, num_nodes: int,
                     interpret: bool = False):
    """agg (B,N,O).  w: (B,E,nb) = comb[etype] * edge_mask * norm
    (relation coefficients folded by the caller; see core/rgcn.py)."""
    s = rgcn_spmm_fwd(h, src, dst, w, num_nodes=num_nodes, interpret=interpret)
    B, N, _ = s.shape
    nb, D, O = basis.shape
    return jnp.einsum("bnkd,kdo->bno", s.reshape(B, N, nb, D), basis)


def _fwd(h, basis, src, dst, w, num_nodes, interpret):
    out = rgcn_message_agg(h, basis, src, dst, w, num_nodes, interpret)
    return out, (h, basis, src, dst, w)


def _bwd(num_nodes, interpret, res, g):
    h, basis, src, dst, w = res

    def ref_fn(h_, basis_, w_):
        return rgcn_message_agg_ref(h_, basis_, src, dst, w_, num_nodes)

    _, vjp = jax.vjp(ref_fn, h, basis, w)
    dh, dbasis, dw = vjp(g)
    return dh, dbasis, None, None, dw


rgcn_message_agg.defvjp(_fwd, _bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def rgcn_message_agg_flat(h, basis, src, dst, w, num_nodes: int,
                          interpret: bool = False):
    """Flat (packed-batch) variant: agg (P,O).  h (P,D); src/dst (Q,);
    w (Q,nb) = comb[etype] * edge_mask * norm (see core/rgcn.py)."""
    s = rgcn_spmm_flat_fwd(h, src, dst, w, num_nodes=num_nodes,
                           interpret=interpret)
    P, _ = s.shape
    nb, D, O = basis.shape
    return jnp.einsum("nkd,kdo->no", s.reshape(P, nb, D), basis)


def _fwd_flat(h, basis, src, dst, w, num_nodes, interpret):
    out = rgcn_message_agg_flat(h, basis, src, dst, w, num_nodes, interpret)
    return out, (h, basis, src, dst, w)


def _bwd_flat(num_nodes, interpret, res, g):
    h, basis, src, dst, w = res

    def ref_fn(h_, basis_, w_):
        return rgcn_message_agg_flat_ref(h_, basis_, src, dst, w_, num_nodes)

    _, vjp = jax.vjp(ref_fn, h, basis, w)
    dh, dbasis, dw = vjp(g)
    return dh, dbasis, None, None, dw


rgcn_message_agg_flat.defvjp(_fwd_flat, _bwd_flat)
