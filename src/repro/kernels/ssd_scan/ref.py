"""Pure-jnp oracle for the Mamba-2 SSD chunked scan.

Delegates to the model-layer implementation (repro.models.ssm.ssd_chunked),
which is itself validated against a sequential recurrence in the tests.
"""

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, Bc, Cc, chunk):
    return ssd_chunked(x, dt, A, Bc, Cc, chunk)


def ssd_sequential_ref(x, dt, A, Bc, Cc):
    """O(S) sequential recurrence — the ground-truth semantics:
        state_t = exp(dt_t A) state_{t-1} + dt_t B_t (x) x_t
        y_t = C_t . state_t
    x (B,S,nh,hp); dt (B,S,nh); A (nh,); Bc/Cc (B,S,ds)."""
    import jax
    import jax.numpy as jnp

    B, S, nh, hp = x.shape
    ds = Bc.shape[-1]

    def step(state, xs):
        x_t, dt_t, B_t, C_t = xs
        decay = jnp.exp(dt_t * A[None])          # (B,nh)
        state = state * decay[..., None, None] + jnp.einsum(
            "bn,bhp,bh->bhpn", B_t, x_t * dt_t[..., None], jnp.ones_like(dt_t)
        )
        y = jnp.einsum("bn,bhpn->bhp", C_t, state)
        return state, y

    init = jnp.zeros((B, nh, hp, ds), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        Bc.transpose(1, 0, 2).astype(jnp.float32),
        Cc.transpose(1, 0, 2).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)
