"""jit'd wrapper: Pallas intra-chunk kernel + jnp inter-chunk recurrence.

Matches repro.models.ssm.ssd_chunked exactly (same math, same signature);
backward falls back to the oracle via custom_vjp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk
from repro.kernels.ssd_scan.ref import ssd_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def ssd_scan(x, dt, A, Bc, Cc, chunk: int, interpret: bool = False):
    """x (B,S,nh,hp); dt (B,S,nh) softplus'ed; A (nh,) negative;
    Bc/Cc (B,S,ds).  Returns (y (B,S,nh,hp), final_state (B,nh,hp,ds))."""
    B, S, nh, hp = x.shape
    ds = Bc.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    a = (dt * A[None, None, :]).astype(jnp.float32)
    cum = jnp.cumsum(a.reshape(B, nc, Q, nh), axis=2)      # (B,nc,Q,nh)
    cum_h = cum.transpose(0, 1, 3, 2)                      # (B,nc,nh,Q)
    dt_h = dt.reshape(B, nc, Q, nh).transpose(0, 1, 3, 2).astype(jnp.float32)

    xr = x.reshape(B, nc, Q, nh, hp)
    Br = Bc.reshape(B, nc, Q, ds).astype(jnp.float32)
    Cr = Cc.reshape(B, nc, Q, ds).astype(jnp.float32)

    y_intra, states = ssd_intra_chunk(
        xr.astype(jnp.float32), cum_h, dt_h, Br, Cr, interpret=interpret
    )

    # inter-chunk recurrence (sequential, tiny carry)
    chunk_decay = jnp.exp(cum_h[..., -1])                  # (B,nc,nh)

    def body(carry, xs):
        dec_c, st_c = xs
        new = carry * dec_c[..., None, None] + st_c
        return new, carry

    final, prevs = jax.lax.scan(
        body, jnp.zeros((B, nh, hp, ds), jnp.float32),
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)),
    )
    prevs = prevs.transpose(1, 0, 2, 3, 4)                 # (B,nc,nh,hp,ds)

    dec_in = jnp.exp(cum_h)                                # (B,nc,nh,Q)
    y_inter = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cr, prevs, dec_in)
    y = (y_intra + y_inter).reshape(B, S, nh, hp).astype(x.dtype)
    return y, final.astype(x.dtype)


def _fwd(x, dt, A, Bc, Cc, chunk, interpret):
    out = ssd_scan(x, dt, A, Bc, Cc, chunk, interpret)
    return out, (x, dt, A, Bc, Cc)


def _bwd(chunk, interpret, res, g):
    x, dt, A, Bc, Cc = res
    _, vjp = jax.vjp(lambda *a: ssd_ref(*a, chunk), x, dt, A, Bc, Cc)
    return vjp(g)


ssd_scan.defvjp(_fwd, _bwd)
