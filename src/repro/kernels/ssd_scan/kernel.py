"""Pallas TPU kernel: Mamba-2 / SSD intra-chunk compute.

Grid: (B, num_chunks, num_heads) — fully parallel; the O(S/Q) inter-chunk
recurrence runs OUTSIDE (lax.scan in ops.py) because it is sequential and
tiny ((nh,hp,ds) carry), while this kernel owns the MXU-heavy quadratic
per-chunk work:

    CB      = C_chunk @ B_chunk^T                      (Q x ds x Q matmul)
    w[q,s]  = CB[q,s] * exp(cum[q]-cum[s]) * dt[s]     (causal masked)
    y_intra = w @ x_chunk                              (Q x Q x hp matmul)
    state   = (B * exp(cum[-1]-cum) * dt)^T @ x_chunk  (ds x Q x hp matmul)

BlockSpecs (f32): x (1,1,Q,1,hp); cum/dt laid out (B,nc,nh,Q) -> (1,1,1,Q);
B/C (1,1,Q,ds) shared across the head grid dim.  Q=256, hp=64, ds=128 keeps
everything 128-lane aligned and the whole working set ~1 MB in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, cum_ref, dt_ref, b_ref, c_ref, y_ref, st_ref, *, Q):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)    # (Q, hp)
    cum = cum_ref[0, 0, 0, :]                        # (Q,)
    dt = dt_ref[0, 0, 0, :]                          # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)              # (Q, ds)
    C = c_ref[0, 0].astype(jnp.float32)              # (Q, ds)

    CB = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (Q, Q)
    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.where(qi >= si, diff, -1e30))  # mask BEFORE exp
    w = CB * decay * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (Q, hp)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    sd = jnp.exp(cum[-1] - cum) * dt                 # (Q,)
    st = jax.lax.dot_general(
        B * sd[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (ds, hp)
    st_ref[0, 0, 0] = st.T.astype(st_ref.dtype)      # (hp, ds)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, cum, dt, Bc, Cc, *, interpret=False):
    """x (B,nc,Q,nh,hp); cum/dt (B,nc,nh,Q); Bc/Cc (B,nc,Q,ds).
    Returns (y_intra (B,nc,Q,nh,hp), states (B,nc,nh,hp,ds))."""
    B, nc, Q, nh, hp = x.shape
    ds = Bc.shape[-1]
    kernel = functools.partial(_ssd_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, nc, nh),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, hp), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, ds), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, hp), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, hp, ds), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, hp, ds), jnp.float32),
        ],
        interpret=interpret,
    )(x, cum, dt, Bc, Cc)
