"""Pure-jnp oracles for the fused RGCN encode front-end.

Two fusions (DESIGN.md §12):

1. ``rgcn_fused_agg_flat_ref`` — the whole packed-layer aggregation in one
   expression: per-edge message gather, relation-coefficient weighting, the
   precomputed degree normalizer, the scatter over dst, and the basis
   contraction.  Equivalent to the rgcn_spmm triple
   (``segment_sum(deg)`` + SpMM + einsum) with the normalizer hoisted into
   ``wnorm`` (= edge_mask * edge_norm, computed once per packed batch in
   core/batching.pack_graphs).

2. ``two_level_readout_ref`` — the node→warp→graph masked-mean readout of
   ``encode_packed`` as four explicit segment-sums.  The fused op in
   ops.py collapses each level's sum+count pair into a single concatenated
   segment-sum; per-column sums are independent, so the fusion is bit-exact
   against this oracle.

h: (P,D); basis: (nb,D,O); src/dst: (Q,); coef: (Q,nb); wnorm: (Q,)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rgcn_fused_agg_flat_ref(h, basis, src, dst, coef, wnorm, num_nodes: int):
    """agg (P,O) = sum_k basis[k] . sum_{e: dst_e=v} coef[e,k]*wnorm[e]*h[src_e].

    Scatter-then-contract order (segment-sum of (Q,nb,D) then einsum) so the
    f32 reduction tree matches the historical unfused jnp path bit-for-bit.
    """
    w = coef * wnorm[:, None]                                # (Q,nb)
    h_src = jnp.take(h, src, axis=0)                         # (Q,D)
    weighted = h_src[:, None, :] * w[..., None]              # (Q,nb,D)
    s = jax.ops.segment_sum(weighted, dst, num_segments=num_nodes)
    return jnp.einsum("nkd,kdo->no", s, basis,
                      preferred_element_type=jnp.float32)


def two_level_readout_ref(h, node_mask, warp_seg, warp_graph,
                          num_warps: int, num_graphs: int):
    """(P,D) node states -> (G,D) graph embeddings via masked means, as four
    separate segment-sums (the pre-fusion encode_packed epilogue)."""
    nmask = node_mask.astype(h.dtype)
    wsum = jax.ops.segment_sum(h * nmask[:, None], warp_seg,
                               num_segments=num_warps)
    wcnt = jax.ops.segment_sum(nmask, warp_seg, num_segments=num_warps)
    warp_mean = wsum / jnp.maximum(wcnt, 1.0)[:, None]
    valid = (wcnt > 0).astype(h.dtype)
    gsum = jax.ops.segment_sum(warp_mean * valid[:, None], warp_graph,
                               num_segments=num_graphs)
    gcnt = jax.ops.segment_sum(valid, warp_graph, num_segments=num_graphs)
    return gsum / jnp.maximum(gcnt, 1.0)[:, None]
