"""Pallas TPU kernel: fused RGCN message + degree-norm + scatter + basis.

Single-pass flat-edge kernel for the packed encode path (DESIGN.md §12).
Where rgcn_spmm materializes the pre-basis accumulator s: (P, nb*D) in HBM
and finishes with a dense einsum outside the kernel, this kernel contracts
each edge block against the basis INSIDE the pass (contract-then-scatter:
msg_e = sum_k coef[e,k]*wnorm[e] * (h[src_e] @ basis[k]) is linear, so the
per-block matmul against basisflat (nb*D, O) is exact) and accumulates
straight into the final (P, O) aggregate.  Only (P, O) ever touches HBM —
no (P, nb*D) round trip, and the degree normalizer arrives precomputed as
``wnorm`` (edge_mask * edge_norm from core/batching.pack_graphs) instead of
being re-derived by two extra segment-sums per layer.

Precision: h enters in the message dtype (bf16 under the low-precision
policy), so the gather matmul streams bf16 messages through the MXU; the
edge weights w = coef * wnorm and every post-gather intermediate stay f32
(exactly like rgcn_spmm, whose accumulator is f32 — no extra bf16
round-trips the unfused path doesn't have), every matmul pins
``preferred_element_type=jnp.float32``, and the (P, O) output block
accumulates in f32 — bf16 messages, f32 accumulate.

Grid: (nE,) — edge blocks stream through VMEM; h, basisflat and the (P, O)
output block use constant index_maps so Pallas keeps them VMEM-resident
across the whole pass.  block_e = 256 keeps the three matmuls
(256,P)x(P,D), (256,nb*D)x(nb*D,O), (P,256)x(256,O) 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rgcn_fused_flat_kernel(h_ref, src_ref, dst_ref, coef_ref, wnorm_ref,
                            basis_ref, out_ref, *, num_nodes, block_e, nb):
    ei = pl.program_id(0)

    @pl.when(ei == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[...]                     # (P, D) message dtype
    src = src_ref[0]                   # (block_e,)
    dst = dst_ref[0]
    coef = coef_ref[...]               # (block_e, nb)
    wnorm = wnorm_ref[0]               # (block_e,) mask * 1/|N_r(dst)|
    basis = basis_ref[...]             # (nb*D, O)

    w = coef.astype(jnp.float32) * wnorm[:, None]           # (be, nb) f32

    iota_n = jax.lax.broadcasted_iota(jnp.int32, (block_e, num_nodes), 1)
    onehot_src = (iota_n == src[:, None]).astype(h.dtype)   # (be, P)
    onehot_dst = (iota_n == dst[:, None]).astype(jnp.float32)

    gathered = jax.lax.dot_general(                         # (be, D) via MXU
        onehot_src, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    D = h.shape[-1]
    weighted = (gathered[:, None, :] * w[:, :, None]).reshape(block_e, nb * D)
    msg = jax.lax.dot_general(                              # (be, O) via MXU
        weighted, basis, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    scat = jax.lax.dot_general(                             # (P, O) via MXU
        onehot_dst.T, msg, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += scat.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("num_nodes", "block_e", "interpret")
)
def rgcn_fused_flat_fwd(h, src, dst, coef, wnorm, basisflat, *, num_nodes,
                        block_e=256, interpret=False):
    """Fused flat forward: returns the FINAL per-node aggregate agg: (P, O)
    in f32.  h (P,D); src/dst (Q,) int32 (dst-sorted by core/batching.py so
    each block's scatter targets are near-contiguous); coef (Q,nb) =
    comb[etype]; wnorm (Q,) = edge_mask * edge_norm; basisflat (nb*D, O)."""
    (E,) = src.shape
    P, D = h.shape
    nb = coef.shape[-1]
    O = basisflat.shape[-1]
    if E == 0:  # empty edge list: aggregation is identically zero
        return jnp.zeros((P, O), jnp.float32)
    block_e = min(block_e, E)
    if E % block_e != 0:  # pad edges (wnorm=0 rows are no-ops)
        pad = block_e - E % block_e
        src = jnp.pad(src, (0, pad))
        dst = jnp.pad(dst, (0, pad))
        coef = jnp.pad(coef, ((0, pad), (0, 0)))
        wnorm = jnp.pad(wnorm, (0, pad))
        E = E + pad
    ne = E // block_e
    # TPU-friendly 2-D layout for the int32/f32 edge streams
    src2 = src.reshape(1, E)
    dst2 = dst.reshape(1, E)
    wnorm2 = wnorm.reshape(1, E)

    kernel = functools.partial(
        _rgcn_fused_flat_kernel, num_nodes=P, block_e=block_e, nb=nb
    )
    return pl.pallas_call(
        kernel,
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((P, D), lambda e: (0, 0)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((block_e, nb), lambda e: (e, 0)),
            pl.BlockSpec((1, block_e), lambda e: (0, e)),
            pl.BlockSpec((nb * D, O), lambda e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((P, O), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, O), jnp.float32),
        interpret=interpret,
    )(h, src2, dst2, coef, wnorm2, basisflat)
