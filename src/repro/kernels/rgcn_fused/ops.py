"""jit'd wrappers for the fused encode front-end (DESIGN.md §12).

``rgcn_fused_agg_flat``     Pallas forward + oracle-vjp backward for the
                            one-pass message+norm+scatter+basis layer.
``fused_two_level_readout`` node→warp→graph masked-mean readout as TWO
                            concatenated segment-sums (sum|count share one
                            scatter pass per level) instead of four.
                            Per-column sums are independent, so this is
                            bit-exact vs the unfused four-sum epilogue
                            (ref.two_level_readout_ref).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rgcn_fused.kernel import rgcn_fused_flat_fwd
from repro.kernels.rgcn_fused.ref import rgcn_fused_agg_flat_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, num_nodes: int,
                        interpret: bool = False):
    """agg (P,O).  h (P,D); basis (nb,D,O); src/dst (Q,); coef (Q,nb) =
    comb[etype]; wnorm (Q,) = edge_mask * edge_norm (precomputed degree
    normalizer; see core/batching.pack_graphs and core/rgcn.py).

    The gather matmul runs in h's dtype (the policy message dtype); edge
    weights and everything downstream accumulate in f32 inside the kernel —
    the same precision profile as the rgcn_spmm triple it replaces (which
    kept the post-gather accumulator f32)."""
    nb, D, O = basis.shape
    basisflat = basis.reshape(nb * D, O)
    return rgcn_fused_flat_fwd(
        h, src, dst, coef, wnorm, basisflat,
        num_nodes=num_nodes, interpret=interpret,
    )


def _fwd(h, basis, src, dst, coef, wnorm, num_nodes, interpret):
    out = rgcn_fused_agg_flat(h, basis, src, dst, coef, wnorm, num_nodes,
                              interpret)
    return out, (h, basis, src, dst, coef, wnorm)


def _bwd(num_nodes, interpret, res, g):
    h, basis, src, dst, coef, wnorm = res

    def ref_fn(h_, basis_, coef_, wnorm_):
        return rgcn_fused_agg_flat_ref(h_, basis_, src, dst, coef_, wnorm_,
                                       num_nodes)

    _, vjp = jax.vjp(ref_fn, h, basis, coef, wnorm)
    dh, dbasis, dcoef, dwnorm = vjp(g)
    return dh, dbasis, None, None, dcoef, dwnorm


rgcn_fused_agg_flat.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=("num_graphs",))
def fused_two_level_readout(h, node_mask, warp_seg, warp_graph,
                            num_graphs: int):
    """(P,D) node states -> (G,D) graph embeddings.  Each level's (sum,
    count) pair rides ONE segment-sum over a (·, D+1) concatenation —
    half the scatter passes of the unfused epilogue, bit-exact."""
    num_warps = warp_graph.shape[0]
    nmask = node_mask.astype(h.dtype)
    x = jnp.concatenate([h * nmask[:, None], nmask[:, None]], axis=1)
    wagg = jax.ops.segment_sum(x, warp_seg, num_segments=num_warps)
    wsum, wcnt = wagg[:, :-1], wagg[:, -1]
    warp_mean = wsum / jnp.maximum(wcnt, 1.0)[:, None]
    valid = (wcnt > 0).astype(h.dtype)                      # (W,)
    y = jnp.concatenate([warp_mean * valid[:, None], valid[:, None]], axis=1)
    gagg = jax.ops.segment_sum(y, warp_graph, num_segments=num_graphs)
    gsum, gcnt = gagg[:, :-1], gagg[:, -1]
    return gsum / jnp.maximum(gcnt, 1.0)[:, None]
