"""Fused RGCN encode front-end: one-pass message+norm+scatter+basis kernel
and the two-segment-sum readout (DESIGN.md §12)."""

from repro.kernels.rgcn_fused.ops import (  # noqa: F401
    fused_two_level_readout, rgcn_fused_agg_flat,
)
from repro.kernels.rgcn_fused.ref import (  # noqa: F401
    rgcn_fused_agg_flat_ref, two_level_readout_ref,
)
