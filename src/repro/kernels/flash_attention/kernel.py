"""Pallas TPU kernel: GQA causal flash attention (FlashAttention-2 schedule).

Grid: (B, H, nQ, nK) — the innermost kv dimension streams KV blocks through
VMEM while fp32 running-max / running-sum / accumulator live in VMEM scratch
(they persist across the innermost grid steps; the output block's index_map
is constant in kv, so the block is revisited and written once at the end).

BlockSpecs (VMEM working set per step, bf16 inputs):
  q:   (1, block_q, 1, 1, hd)   — one query tile of one (b, head)
  k/v: (1, block_k, 1, hd)      — kv head = head // G (GQA sharing)
  o:   (1, block_q, 1, 1, hd)
  scratch: acc (block_q, hd) f32, m/l (block_q, 128) f32
With block_q = block_k = 512, hd = 128: ~1.1 MB << 16 MB VMEM; MXU matmul
dims (512x128x512) are 128-aligned.

Causality: kv blocks strictly above the diagonal are skipped via pl.when
(the FLOP savings the chunked-jnp fallback cannot express — see
EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces (available in interpret mode too)
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_k, seq_len, num_kv_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(k_start <= q_start + block_q - 1)  # skip fully-masked kv blocks
    def _compute():
        q = q_ref[0, :, 0, 0, :].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                      # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[:, 0] = l_scr[:, 0] * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[:, 0] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[:, 0], 1e-20)[:, None]
        o_ref[0, :, 0, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_q", "block_k", "interpret")
)
def flash_attention_fwd(q, k, v, *, scale, block_q=512, block_k=512,
                        interpret=False):
    B, S, K, G, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    H = K * G

    grid = (B, H, nq, nk)
    q_spec = pl.BlockSpec(
        (1, block_q, 1, 1, hd), lambda b, h, qi, ki: (b, qi, h // G, h % G, 0)
    )
    kv_spec = pl.BlockSpec(
        (1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)
    )
    o_spec = pl.BlockSpec(
        (1, block_q, 1, 1, hd), lambda b, h, qi, ki: (b, qi, h // G, h % G, 0)
    )
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _VMEM((block_q, 128), jnp.float32),
            _VMEM((block_q, 128), jnp.float32),
            _VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
