"""Pure-jnp oracle for GQA causal flash attention.

q: (B, S, K, G, hd) grouped queries; k, v: (B, S, K, hd).
Returns (B, S, K, G, hd).  fp32 softmax, causal mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, scale: float):
    B, S, K, G, hd = q.shape
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(q.dtype), v)
    return out
