"""jit'd public wrapper for the flash attention kernel.

Forward runs the Pallas kernel (interpret=True executes the kernel body on
CPU for validation; False targets TPU).  Backward falls back to the jnp
oracle via custom_vjp — training through the kernel stays differentiable
while serving gets the fused forward.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, scale: float = 1.0, interpret: bool = False):
    return flash_attention_fwd(q, k, v, scale=scale, interpret=interpret)


def _fwd(q, k, v, scale, interpret):
    out = flash_attention_fwd(q, k, v, scale=scale, interpret=interpret)
    return out, (q, k, v)


def _bwd(scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
