"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention  GQA causal attention, online softmax, KV-block streaming
rgcn_spmm        RGCN message aggregation as MXU one-hot matmuls (TPU-native
                 adaptation of scatter-gather SpMM; DESIGN.md §3)
ssd_scan         Mamba-2/SSD intra-chunk compute (per-chunk MXU matmuls)

Each kernel ships <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd wrapper + custom_vjp fallback), <name>/ref.py
(pure-jnp oracle).  All are validated against their oracle in interpret
mode on CPU (tests/test_kernels_*.py); `interpret=False` targets real TPUs.
"""
