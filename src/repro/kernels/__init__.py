"""Pallas TPU kernels for the framework's compute hot-spots.

flash_attention  GQA causal attention, online softmax, KV-block streaming
rgcn_spmm        RGCN message aggregation as MXU one-hot matmuls (TPU-native
                 adaptation of scatter-gather SpMM; DESIGN.md §3)
rgcn_fused       one-pass message+degree-norm+scatter+basis layer for the
                 packed encode path, plus the fused two-level readout
                 (DESIGN.md §12)
kmeans_assign    blocked K-Means assignment + fused Lloyd-step statistics +
                 blocked silhouette sums (planning engine; DESIGN.md §8)
ssd_scan         Mamba-2/SSD intra-chunk compute (per-chunk MXU matmuls)

Each kernel ships <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd wrapper + custom_vjp fallback), <name>/ref.py
(pure-jnp oracle).  All are validated against their oracle in interpret
mode on CPU (tests/test_kernels_*.py); `interpret=False` targets real TPUs.
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Backend-aware interpret default for every Pallas wrapper: interpret
    on CPU (where Mosaic cannot compile), compiled everywhere else.  Call
    sites that used to hardcode ``interpret=True`` now resolve through this
    so TPU/GPU runs hit the real kernels."""
    return jax.default_backend() == "cpu"
