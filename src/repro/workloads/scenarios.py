"""Scenario families: seeded generators composing the kernel template
library (`tracing/templates.py`) into phase-structured synthetic programs.

Each family stresses one axis of the paper's evaluation space that the fixed
11-program suite samples only once (or not at all):

  iterative   — loop-heavy convergence phases: a stencil sweep + periodic
                residual reduction repeated per phase, with per-phase
                locality shifts (the `nw` structure, parameterized)
  phase_shift — distinct behavior regimes back-to-back (gemm phase ->
                elementwise phase -> traversal phase ...), every invocation
                distinctly named so name-keyed methods find no reduction
  mem_mix     — compute-bound / memory-bound interleaving with a seeded mix
                ratio (roofline coverage: both sides of the ridge)
  divergent   — graph-traversal phases with frontier growth/decay and
                per-phase branch divergence (the `bfs` axis, generalized)
  pipeline    — multi-kernel pipelines repeated per frame (preproc ->
                gemm -> softmax -> postproc), steady-state invocation reuse
  long_tail   — Zipf-skewed invocation counts over a pool of distinct
                kernels: few hot kernels dominate, many appear once (the
                reduction-opportunity profile of real LLM serving traces)

Every generator is a pure function of its :class:`ScenarioSpec`: same spec
-> identical kernel stream (names, templates, params, seeds).
"""

from __future__ import annotations

import numpy as np

from repro.tracing.programs import Program
from repro.tracing.templates import make_kernel
from repro.utils.registry import Registry
from repro.workloads.spec import ScenarioSpec, is_scenario_name, spec_from_name

# family id -> generator(spec, rng) yielding (name, template, params)
FAMILIES: Registry = Registry("scenario family")


def _dim(rng, lo, hi, scale, quant=64):
    """Seeded problem dimension in [lo, hi] * scale, quantized."""
    v = int(rng.integers(lo, hi + 1) * scale)
    return max(quant, (v // quant) * quant)


@FAMILIES.register("iterative")
def _gen_iterative(spec: ScenarioSpec, rng):
    for p in range(spec.phases):
        nx = _dim(rng, 512, 4096, spec.scale)
        ny = int(rng.integers(8, 32))
        pts = int(rng.choice([5, 9]))
        stride = int(rng.choice([32, 128, 512]))
        reuse = float(rng.choice([1.0, 2.0, 4.0]))
        for it in range(spec.phase_len):
            yield (f"sweep_p{p}_it{it}", "stencil",
                   {"nx": nx, "ny": ny, "pts": pts, "iters": 8,
                    "stride": stride, "reuse": reuse})
            if it % 4 == 3:  # periodic convergence check
                yield (f"residual_norm_p{p}", "reduction", {"n": nx * ny})


@FAMILIES.register("phase_shift")
def _gen_phase_shift(spec: ScenarioSpec, rng):
    regimes = ["gemm", "elementwise", "traversal", "softmax", "gemv"]
    seq = 0
    for p in range(spec.phases):
        tmpl = regimes[int(rng.integers(0, len(regimes)))]
        if tmpl == "gemm":
            d = _dim(rng, 128, 1024, spec.scale)
            params = {"M": d, "N": d, "K": _dim(rng, 128, 2048, spec.scale)}
        elif tmpl == "elementwise":
            params = {"n": _dim(rng, 65536, 1 << 20, spec.scale),
                      "nops": int(rng.integers(1, 6)), "iters": 4}
        elif tmpl == "traversal":
            params = {"nodes": _dim(rng, 1 << 16, 1 << 20, spec.scale),
                      "degree": int(rng.integers(4, 16)),
                      "frontier": _dim(rng, 256, 4096, 1.0),
                      "divergence": float(rng.uniform(0.1, 0.6))}
        elif tmpl == "softmax":
            params = {"rows": _dim(rng, 64, 512, spec.scale),
                      "cols": _dim(rng, 256, 4096, spec.scale)}
        else:  # gemv
            params = {"n": _dim(rng, 256, 2048, spec.scale),
                      "m": _dim(rng, 1024, 8192, spec.scale)}
        for it in range(spec.phase_len):
            # distinct names per invocation: name-keyed methods see no reuse
            yield (f"{tmpl}_phase{p}_call{seq + it}", tmpl, params)
        seq += spec.phase_len


@FAMILIES.register("mem_mix")
def _gen_mem_mix(spec: ScenarioSpec, rng):
    ratio = float(rng.uniform(0.2, 0.8))  # fraction of compute-bound calls
    d = _dim(rng, 256, 1024, spec.scale)
    k_big = _dim(rng, 1024, 4096, spec.scale)
    n_stream = _dim(rng, 1 << 18, 1 << 21, spec.scale)
    for p in range(spec.phases):
        for it in range(spec.phase_len):
            if rng.random() < ratio:  # compute-bound: deep-K gemm
                yield (f"compute_gemm_p{p}_{it}", "gemm",
                       {"M": d, "N": d, "K": k_big})
            else:  # memory-bound: 1-op streaming pass
                yield (f"stream_pass_p{p}_{it}", "elementwise",
                       {"n": n_stream, "nops": 1, "iters": 2})


@FAMILIES.register("divergent")
def _gen_divergent(spec: ScenarioSpec, rng):
    nodes = _dim(rng, 1 << 18, 1 << 21, spec.scale)
    degree = int(rng.integers(4, 16))
    frontier = 256.0
    for p in range(spec.phases):
        div = float(rng.uniform(0.1, 0.8))
        growth = float(rng.uniform(2.0, 4.0)) if p < spec.phases / 2 \
            else float(rng.uniform(0.25, 0.6))
        for it in range(spec.phase_len):
            yield (f"expand_frontier_p{p}", "traversal",
                   {"nodes": nodes, "degree": degree,
                    "frontier": int(max(frontier, 64)), "divergence": div})
            yield (f"compact_frontier_p{p}", "elementwise",
                   {"n": int(max(frontier, 64)) * 4, "nops": 2, "iters": 2})
            frontier = min(frontier * growth, nodes / 4)


@FAMILIES.register("pipeline")
def _gen_pipeline(spec: ScenarioSpec, rng):
    # one steady-state pipeline shape per program; `phases * phase_len` frames
    d_in = _dim(rng, 128, 512, spec.scale)
    d_mid = _dim(rng, 256, 1024, spec.scale)
    rows = _dim(rng, 64, 256, spec.scale)
    stages = [
        ("pre_normalize", "elementwise",
         {"n": rows * d_in, "nops": 3, "iters": 4}),
        ("stage_gemm_a", "gemm", {"M": rows, "N": d_mid, "K": d_in}),
        ("stage_softmax", "softmax", {"rows": rows, "cols": d_mid}),
        ("stage_gemm_b", "gemm", {"M": rows, "N": d_in, "K": d_mid}),
        ("post_reduce", "reduction", {"n": rows * d_in}),
    ]
    for frame in range(spec.phases * spec.phase_len):
        for nm, tmpl, params in stages:
            yield (nm, tmpl, params)


@FAMILIES.register("long_tail")
def _gen_long_tail(spec: ScenarioSpec, rng):
    # pool of distinct kernels; rank r gets ~ N / r^skew invocations
    pool = []
    templates = ["gemm", "elementwise", "stencil", "softmax", "gemv",
                 "reduction"]
    n_distinct = max(2, spec.phases * spec.phase_len // 2)
    for r in range(n_distinct):
        tmpl = templates[int(rng.integers(0, len(templates)))]
        if tmpl == "gemm":
            d = _dim(rng, 128, 768, spec.scale)
            params = {"M": d, "N": d, "K": d}
        elif tmpl == "elementwise":
            params = {"n": _dim(rng, 1 << 16, 1 << 19, spec.scale),
                      "nops": int(rng.integers(1, 5)), "iters": 3}
        elif tmpl == "stencil":
            params = {"nx": _dim(rng, 512, 2048, spec.scale),
                      "ny": int(rng.integers(8, 32)), "pts": 5, "iters": 6}
        elif tmpl == "softmax":
            params = {"rows": _dim(rng, 64, 256, spec.scale),
                      "cols": _dim(rng, 256, 2048, spec.scale)}
        elif tmpl == "gemv":
            params = {"n": _dim(rng, 256, 1024, spec.scale),
                      "m": _dim(rng, 1024, 4096, spec.scale)}
        else:
            params = {"n": _dim(rng, 1 << 17, 1 << 20, spec.scale)}
        count = max(1, int(spec.phases * spec.phase_len
                           / float(r + 1) ** spec.skew))
        pool.append((f"hot_{tmpl}_{r}", tmpl, params, count))
    # interleave invocations in a seeded shuffled order
    stream = [entry[:3] for entry in pool for _ in range(entry[3])]
    for i in rng.permutation(len(stream)):
        yield stream[int(i)]


def build_scenario(spec: ScenarioSpec) -> Program:
    """Materialize the kernel-invocation stream for one spec.

    KernelInvocation objects are lightweight (traces are generated lazily),
    so building the Program is cheap; the streaming path
    (`repro.workloads.streaming`) keeps the expensive trace->graph stage
    bounded.
    """
    gen = FAMILIES.get(spec.family)
    rng = np.random.default_rng(spec.rng_seed())
    kseed = spec.kernel_seed()
    kernels = [
        make_kernel(name, tmpl, params, seq, seed=kseed)
        for seq, (name, tmpl, params) in enumerate(gen(spec, rng))
    ]
    if not kernels:
        raise ValueError(f"scenario {spec.name!r} generated no kernels")
    return Program(spec.name, kernels, fingerprint_extra=spec.content_hash())


def scenario_program(name: str) -> Program:
    """`scn:<family>[:k=v,...]` -> Program (the `get_program` hook)."""
    return build_scenario(spec_from_name(name))


def scenario_families() -> list[str]:
    return FAMILIES.names()


def scenario_matrix(families=None, seeds=(0,), *, phases=None, phase_len=None,
                    scale=None) -> list[str]:
    """Spec names for a family x seed grid (the `--suite scenarios` axis)."""
    kwargs = {k: v for k, v in
              [("phases", phases), ("phase_len", phase_len), ("scale", scale)]
              if v is not None}
    return [
        ScenarioSpec(family=f, seed=int(s), **kwargs).name
        for f in (families or scenario_families())
        for s in seeds
    ]


def scenario_family_of(program_name: str) -> str:
    """Grouping key for results rows: scenario family, or 'paper'."""
    if is_scenario_name(program_name):
        return spec_from_name(program_name).family
    return "paper"
