"""The in-repo model zoo as a trace-pack workload suite.

``model:<arch_id>[:phase]`` derives a `Program` from an assigned
architecture config (`repro.configs.ARCHS`) by walking its per-layer specs
(attention / mamba mixers, dense / MoE FFNs) through the same library-kernel
stream builder the paper's LLM workloads use — but at REAL phase shapes and
with a 10-100x larger trace window than the scenario families:

    phase     shapes                       trace window (cap_warps, cap_instr)
    prefill   seq 2048, full-layer gemms   (4, 2048)   -> ~42x default graphs
    decode    4 steps against a 4096 ctx   (4, 1024)   -> ~21x default graphs

The window rides on `Program.trace_caps` (resolved by
``repro.config.resolve_trace_caps``) and is ALSO folded into
``fingerprint_extra``, so artifacts and cached graphs for one window can
never be replayed at another.  This is the ROADMAP's "real-model trace pack"
item — the SimNet/NPS-style real-workload stress test for the ingestion
engine (DESIGN.md §13).
"""

from __future__ import annotations

from repro.tracing.programs import Program, _lm_layer_kernels

#: default model-zoo grid: one dense-attention, one pure-SSM, one MoE arch
MODEL_ZOO = ("llama3.2-3b", "mamba2-780m", "dbrx-132b")
PHASES = ("prefill", "decode")

#: per-phase trace window — the "10-100x larger graphs" knob
PHASE_CAPS = {"prefill": (4, 2048), "decode": (4, 1024)}
#: prefill sequence length / decode KV-context length
PHASE_SEQ = {"prefill": 2048, "decode": 4096}
#: decode emits several steps (real decode is many small identical launches
#: — the ingest engine's dedup memo is what makes this cheap)
DECODE_STEPS = 4


def zoo_names(archs=MODEL_ZOO, phases=PHASES) -> list[str]:
    return [f"model:{a}:{p}" for a in archs for p in phases]


def model_program(name: str) -> Program:
    """Build ``model:<arch_id>[:phase]`` (phase defaults to prefill)."""
    parts = name.split(":")
    if len(parts) not in (2, 3) or parts[0] != "model":
        raise KeyError(f"bad model program name {name!r} "
                       "(want model:<arch_id>[:phase])")
    arch_id = parts[1]
    phase = parts[2] if len(parts) == 3 else "prefill"
    if phase not in PHASES:
        raise KeyError(f"unknown phase {phase!r} (want one of {PHASES})")

    from repro.config import FFN_MOE, MIXER_MAMBA2
    from repro.configs import get_arch

    cfg = get_arch(arch_id)
    seq_len = PHASE_SEQ[phase]
    decode = phase == "decode"
    steps = DECODE_STEPS if decode else 1
    seed = 211 if decode else 199

    ks = []
    s = 0
    for _step in range(steps):
        for layer in range(cfg.num_layers):
            spec = cfg.layer_specs()[layer % cfg.block_size]
            moe = (
                {"experts": cfg.num_experts, "top_k": cfg.top_k}
                if spec.ffn == FFN_MOE else None
            )
            mamba = (
                {"d_inner": cfg.d_inner}
                if spec.mixer == MIXER_MAMBA2 else None
            )
            lk, s = _lm_layer_kernels(
                f"L{layer}", cfg.d_model, cfg.d_ff, max(cfg.num_heads, 1),
                seq_len, decode, s, seed=seed, moe=moe, mamba=mamba,
            )
            ks.extend(lk)
        ks.append(
            make_head_kernel(cfg, seq_len, decode, s, seed))
        s += 1
    for i, k in enumerate(ks):
        k.seq = i

    caps = PHASE_CAPS[phase]
    full_name = f"model:{arch_id}:{phase}"
    return Program(
        full_name, ks,
        fingerprint_extra=f"modelzoo|{arch_id}|{phase}"
                          f"|cw{caps[0]}ci{caps[1]}",
        trace_caps=caps,
    )


def make_head_kernel(cfg, seq_len, decode, seq, seed):
    from repro.tracing.templates import make_kernel

    if decode:
        return make_kernel("lm_head_logits", "gemv",
                           {"n": cfg.vocab_size, "m": cfg.d_model},
                           seq, seed)
    return make_kernel("lm_head_logits", "gemm",
                       {"M": max(seq_len, 64), "N": cfg.vocab_size,
                        "K": cfg.d_model}, seq, seed)
