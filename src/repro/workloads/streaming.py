"""Streaming trace -> graph ingestion.

Scenario populations can reach hundreds of programs x thousands of
invocations; materializing every trace and every KernelGraph before packing
would hold the whole population in memory.  This module keeps the expensive
stages lazy end-to-end:

    Program.kernels (lightweight specs)
      --iter_program_graphs-->  KernelGraph, one at a time (trace built,
                                graph built, trace dropped)
      --stream_pack-->          packed bucket batches, at most ONE
                                micro-batch of graphs resident
      --ContrastiveTrainer.embed_stream-->  embeddings (content-hash cached)

Peak resident graphs are bounded by one micro-batch budget
(`core.batching.MAX_*_PER_MICROBATCH`), asserted in tests/test_workloads.py.
"""

from __future__ import annotations

from typing import Iterable

import numpy as np

from repro.core.batching import (
    MAX_EDGES_PER_MICROBATCH, MAX_GRAPHS_PER_MICROBATCH,
    MAX_NODES_PER_MICROBATCH, bucket_size, pack_graphs, stream_bins,
)
from repro.core.graphs import KernelGraph, iter_kernel_graphs

def iter_program_graphs(program, cap_warps=None, cap_instr=None, *,
                        engine=None):
    """Canonical lazy trace->graph generator (the ingestion entry point).

    Default: the sequential per-invocation path (`core.graphs`).  Pass an
    `repro.ingest.IngestEngine` to ingest through the parallel cache-backed
    path instead — same order, same bits, bounded residency either way.
    Omitted caps resolve per program (`repro.config.resolve_trace_caps`)."""
    if engine is not None:
        return engine.iter_graphs(program, cap_warps, cap_instr)
    return iter_kernel_graphs(program, cap_warps, cap_instr)


def stream_pack(
    graphs: Iterable[KernelGraph],
    *,
    max_nodes: int = MAX_NODES_PER_MICROBATCH,
    max_edges: int = MAX_EDGES_PER_MICROBATCH,
    max_graphs: int = MAX_GRAPHS_PER_MICROBATCH,
    stats: dict | None = None,
):
    """Yield (packed batch, PackMeta, graphs) bucket-by-bucket from a graph
    iterator.  The graph axis is padded to a small power-of-two bucket so
    downstream jit retraces stay bounded; per-graph node/edge caps keep a
    single oversized graph from blowing the bucket (truncation is accounted
    in PackMeta)."""
    for bin_graphs in stream_bins(
            graphs, lambda g: (g.n_nodes, g.n_edges), max_nodes=max_nodes,
            max_edges=max_edges, max_graphs=max_graphs, stats=stats):
        batch, meta = pack_graphs(
            bin_graphs,
            pad_graphs_to=bucket_size(len(bin_graphs), 8),
            max_nodes_per_graph=max_nodes,
            max_edges_per_graph=max_edges,
        )
        yield batch, meta, bin_graphs


def materialized_peak(graphs: list[KernelGraph]) -> dict:
    """Peak residency of the non-streaming path (everything at once) — the
    benchmark baseline for the streaming comparison."""
    return {
        "peak_resident_graphs": len(graphs),
        "peak_resident_nodes": int(np.sum([g.n_nodes for g in graphs])),
        "peak_resident_edges": int(np.sum([g.n_edges for g in graphs])),
    }
