"""repro.workloads — scenario-diverse generated programs + streaming
trace->graph ingestion.

    from repro.workloads import ScenarioSpec, build_scenario, scenario_matrix

    prog = build_scenario(ScenarioSpec("pipeline", seed=3))
    names = scenario_matrix(["iterative", "long_tail"], seeds=(0, 1))

Generated programs are addressable by name (``scn:<family>[:k=v,...]``)
through ``repro.tracing.programs.get_program`` and the launch grid
(``python -m repro.launch.sample --suite scenarios``).  See
`repro.workloads.streaming` for the bounded-memory ingestion path.
"""

from repro.workloads.modelzoo import (
    MODEL_ZOO, PHASES, model_program, zoo_names,
)
from repro.workloads.scenarios import (
    FAMILIES, build_scenario, scenario_families, scenario_family_of,
    scenario_matrix, scenario_program,
)
from repro.workloads.spec import (
    SCN_PREFIX, ScenarioSpec, is_scenario_name, spec_from_name,
)
from repro.workloads.streaming import (
    iter_program_graphs, materialized_peak, stream_pack,
)

__all__ = [
    "FAMILIES", "MODEL_ZOO", "PHASES", "SCN_PREFIX", "ScenarioSpec",
    "build_scenario", "is_scenario_name", "iter_program_graphs",
    "materialized_peak", "model_program", "scenario_families",
    "scenario_family_of", "scenario_matrix", "scenario_program",
    "spec_from_name", "stream_pack", "zoo_names",
]
