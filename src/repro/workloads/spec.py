"""Seeded, reproducible scenario specifications.

A :class:`ScenarioSpec` fully determines one generated program: the family
(which generator composes kernel templates into a phase-structured stream),
the seed (every stochastic choice inside the generator), and a small set of
size knobs.  Specs round-trip through program names (``scn:<family>:k=v,...``)
so the launch grid, the `PROGRAMS` registry, and the artifact store can all
address generated programs by string, and two specs that differ in ANY field
— including the seed — hash to different content keys.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, fields

SCN_PREFIX = "scn:"


@dataclass(frozen=True)
class ScenarioSpec:
    """One generated workload.  All fields are JSON-safe and round-trip
    through :meth:`name` / :func:`spec_from_name`.

    family    — generator id in `repro.workloads.scenarios.FAMILIES`
    seed      — drives every stochastic choice (sizes, mixes, orderings)
    phases    — number of program phases (meaning is family-specific:
                convergence stages, pipeline frames, behavior shifts)
    phase_len — invocations (or distinct kernels, for `long_tail`) per phase
    scale     — multiplier on problem sizes (working sets, matrix dims)
    skew      — Zipf exponent for invocation-count skew (`long_tail`)
    """

    family: str
    seed: int = 0
    phases: int = 3
    phase_len: int = 12
    scale: float = 1.0
    skew: float = 1.2

    def __post_init__(self):
        # canonicalize field types so ScenarioSpec(scale=2) and
        # ScenarioSpec(scale=2.0) are the SAME spec (equal, same hash,
        # same name) — the name round-trip below depends on it
        for f in ("seed", "phases", "phase_len"):
            object.__setattr__(self, f, int(getattr(self, f)))
        for f in ("scale", "skew"):
            object.__setattr__(self, f, float(getattr(self, f)))

    def to_dict(self) -> dict:
        return asdict(self)

    @property
    def name(self) -> str:
        """Program name; omits fields left at their default.  Floats use
        repr (exact shortest round-trip), so spec -> name -> spec is
        lossless for every representable value."""
        parts = []
        for f in fields(self):
            if f.name == "family":
                continue
            v = getattr(self, f.name)
            if v != f.default:
                parts.append(f"{f.name}={v!r}")
        suffix = f":{','.join(parts)}" if parts else ""
        return f"{SCN_PREFIX}{self.family}{suffix}"

    def content_hash(self) -> str:
        """Stable hash over ALL fields (not just the non-default ones in the
        name) — folded into `program_fingerprint` so same-named programs
        from different specs/seeds never collide in the ArtifactStore."""
        blob = json.dumps(self.to_dict(), sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]

    def rng_seed(self) -> list:
        """Entropy for numpy Generators: every field contributes."""
        return [int(hashlib.sha1(self.content_hash().encode())
                    .hexdigest()[:8], 16)]

    def kernel_seed(self) -> int:
        """Per-program seed handed to `make_kernel` (feeds the tracer RNG),
        so two seeds produce different traces, not just different params."""
        return int(self.content_hash()[:8], 16) % (2**31 - 1)


_FIELD_TYPES = {f.name: f.type for f in fields(ScenarioSpec)}


def is_scenario_name(name: str) -> bool:
    return name.startswith(SCN_PREFIX)


def spec_from_name(name: str) -> ScenarioSpec:
    """Inverse of :attr:`ScenarioSpec.name`.

    ``scn:pipeline`` / ``scn:long_tail:seed=3,phase_len=24`` ->
    :class:`ScenarioSpec`.  Raises ValueError on malformed names.
    """
    if not is_scenario_name(name):
        raise ValueError(f"not a scenario name (want {SCN_PREFIX!r} prefix): "
                         f"{name!r}")
    body = name[len(SCN_PREFIX):]
    family, _, kvs = body.partition(":")
    if not family:
        raise ValueError(f"scenario name {name!r} has no family")
    kwargs: dict = {}
    for part in filter(None, kvs.split(",")):
        key, eq, val = part.partition("=")
        if not eq or key not in _FIELD_TYPES or key == "family":
            raise ValueError(f"bad scenario field {part!r} in {name!r}")
        try:
            kwargs[key] = float(val) if key in ("scale", "skew") else int(val)
        except ValueError:
            raise ValueError(
                f"bad scenario value {part!r} in {name!r}: "
                f"{key} wants {'a float' if key in ('scale', 'skew') else 'an int'}"
            ) from None
    return ScenarioSpec(family=family, **kwargs)
