"""Fault-tolerant sharded checkpointing.

Design (works at single-host scale here, laid out for multi-host):
- one directory per step: ``step_<n>/``, one .npy per leaf (flat key paths),
  plus ``manifest.json`` recording tree structure, global shapes, dtypes and
  the PartitionSpec each leaf was saved under;
- ATOMIC publish: everything is written to ``step_<n>.tmp`` then renamed —
  a crash mid-save never corrupts the latest checkpoint (restart-safe);
- ASYNC save: a background thread serializes while training continues
  (wait() joins before the next save — single outstanding snapshot);
- ELASTIC restore: leaves are loaded from their global arrays and
  device_put with the CURRENT mesh's shardings, so a checkpoint saved on a
  16x16 mesh restores onto 2x16x16 (or a debug 1x1) unchanged — the
  manifest's global shapes make the checkpoint mesh-independent;
- retention: keep the last N steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state, *, specs=None, blocking: bool = False):
        """Snapshot `state` (pytree of arrays).  specs: optional matching
        pytree of PartitionSpecs recorded in the manifest."""
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        # pull to host NOW (so training can mutate donated buffers after)
        host = [(self._key(path), np.asarray(leaf)) for path, leaf in flat]
        spec_strs = None
        if specs is not None:
            sflat = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: hasattr(x, "spec") or x is None
            )
            spec_strs = [str(getattr(s, "spec", s)) for s in sflat]

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": [], "time": time.time()}
            for i, (key, arr) in enumerate(host):
                fn = f"leaf_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append(
                    {
                        "key": key,
                        "file": fn,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "spec": spec_strs[i] if spec_strs else None,
                    }
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    @staticmethod
    def _key(path) -> str:
        from repro.utils.trees import path_str

        return path_str(path)

    # -- restore ---------------------------------------------------------------
    def restore_tree(self, step: int | None = None):
        """Rebuild a checkpoint WITHOUT an abstract pytree: the manifest's
        '/'-joined key paths are re-nested into dicts (digit-only components
        rebuild lists), so callers whose leaf SHAPES are unknown up front —
        e.g. the training engine's growing metrics history — can restore.
        Returns (tree, step)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        nest: dict = {}
        for leaf in manifest["leaves"]:
            arr = np.load(os.path.join(d, leaf["file"]))
            parts = leaf["key"].split("/")
            node = nest
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr

        def rebuild(node):
            if not isinstance(node, dict):
                return node
            # list levels (from tuple/list pytrees) flatten to contiguous
            # digit keys 0..n-1; anything else — including dicts that merely
            # HAVE digit string keys — stays a dict
            if node and all(k.isdigit() for k in node) \
                    and sorted(int(k) for k in node) == list(range(len(node))):
                return [rebuild(node[str(i)]) for i in range(len(node))]
            return {k: rebuild(v) for k, v in node.items()}

        return rebuild(nest), step

    def restore(self, abstract_state, step: int | None = None, shardings=None):
        """Rebuild `abstract_state`'s pytree from disk.  With `shardings`
        (a matching pytree of NamedShardings for the CURRENT mesh) leaves are
        device_put sharded — elastic across mesh changes."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {l["key"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        sflat = (
            jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None
            )
            if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (path, aval), sh in zip(flat, sflat):
            key = self._key(path)
            meta = by_key[key]
            arr = np.load(os.path.join(d, meta["file"]))
            if tuple(arr.shape) != tuple(aval.shape):
                raise ValueError(
                    f"checkpoint leaf {key} shape {arr.shape} != {aval.shape}"
                )
            arr = arr.astype(aval.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None else
                          jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
