"""Mixed-precision policy for the training stack (DESIGN.md §7).

One explicit, hashable `Policy` object threads through the encoder
(`core/rgcn.py`), the augmentations (`core/augment.py`), the InfoNCE loss
(`core/contrastive.py`) and the optimizer (`optim/adamw.py`):

- ``param_dtype``    master parameters (always float32 here: AdamW keeps
                     f32 master copies regardless of compute dtype),
- ``compute_dtype``  activation/message dtype inside the encoder layers
                     (bf16 halves activation traffic on accelerators;
                     LayerNorm statistics and the readout stay f32),
- ``loss_scale``     static loss scaling for low-precision gradients: the
                     trainer multiplies the loss before differentiation and
                     ``adamw_update`` divides the gradients back out (the
                     hook a dynamic scaler would plug into).

The default policy is pure float32 and is numerically a no-op: every cast
is an identity, so the f32 path is bit-identical to the pre-policy code.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Policy:
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    loss_scale: float = 1.0

    @property
    def compute(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def param(self):
        return jnp.dtype(self.param_dtype)

    def cast_compute(self, x):
        """Cast an activation to the compute dtype (identity under f32)."""
        return x.astype(self.compute) if x.dtype != self.compute else x

    def cast_f32(self, x):
        """Upcast back to f32 for numerically sensitive reductions."""
        return x.astype(jnp.float32) if x.dtype != jnp.float32 else x


#: named presets, the registry-style surface used by configs and the CLI
POLICIES = {
    "f32": Policy(),
    "bf16": Policy(compute_dtype="bfloat16"),
    # bf16 compute with a static loss scale: the backward pass runs in the
    # compute dtype, so small gradients benefit from scaling before the
    # f32 master update unscales them
    "bf16_scaled": Policy(compute_dtype="bfloat16", loss_scale=1024.0),
}


def get_policy(name) -> Policy:
    """Resolve a policy by preset name (a `Policy` passes through)."""
    if isinstance(name, Policy):
        return name
    if name not in POLICIES:
        raise KeyError(f"unknown precision policy {name!r}; "
                       f"known: {sorted(POLICIES)}")
    return POLICIES[name]
