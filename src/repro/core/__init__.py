"""The paper's primary contribution: GCL-Sampler.

graphs       SASS trace -> Heterogeneous Relational Graph (HRG)
augment      contrastive views (node drop / edge drop / feature noise)
rgcn         RGCN encoder + projection head (features built in-model)
contrastive  symmetric InfoNCE
train        distributed contrastive trainer
clustering   K-Means + silhouette K-selection
sampler      end-to-end GCL-Sampler pipeline
baselines    PKA / Sieve / STEM+ROOT
"""

from repro.core.graphs import KernelGraph, build_kernel_graph, pad_batch
from repro.core.sampler import GCLSampler, GCLSamplerConfig
