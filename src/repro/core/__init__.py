"""The paper's primary contribution: GCL-Sampler.

graphs       SASS trace -> Heterogeneous Relational Graph (HRG)
batching     packed, bucketed graph batching (flat segment arrays)
augment      contrastive views (node drop / edge drop / feature noise)
rgcn         RGCN encoder + projection head (features built in-model)
contrastive  symmetric InfoNCE
train        distributed contrastive trainer
clustering   K-Means + silhouette K-selection
sampler      end-to-end GCL-Sampler pipeline (engine of the `gcl` method)
baselines    PKA / Sieve / STEM+ROOT partitions (engines of the baselines)

The public, method-agnostic surface lives in ``repro.sampling``:
``get_method(id)`` / ``SamplingMethod`` / ``ArtifactStore`` / ``evaluate``.
"""

from repro.core.batching import (
    bucket_key, bucket_size, graph_content_hash, pack_graphs,
    plan_microbatches,
)
from repro.core.graphs import KernelGraph, build_kernel_graph, pad_batch
from repro.core.sampler import GCLSampler, GCLSamplerConfig
