"""Distributed contrastive trainer for the RGCN encoder (paper §3.3, §4).

Training config mirrors the paper: AdamW, lr 7e-4 with cosine annealing,
temperature tau=0.05, 80/20 train/validation split of the program's kernels.

Batching: graphs are PACKED (core/batching.py) — one flat node/edge array per
batch with segment ids, padded to power-of-two size buckets, so jit
recompilation is bounded by the bucket count and no kernel pays for the
batch-wide max size.  The dense `pad_batch` path is kept as `embed_dense`
for parity tests and the batching benchmark baseline.

Engine (DESIGN.md §4): the default ``engine='scan'`` pre-packs the whole
epoch on the host (`core.batching.plan_epoch`), stages each same-bucket
segment to the device once, and drives training with fixed-length
`jax.lax.scan` chunks — donated `TrainState`, fold-in per-step RNG, per-step
metrics accumulated on device and pulled to the host only at ``log_every``
boundaries.  Compiled chunk executables are shared process-wide (keyed on
the model/optimizer config), so repeated fits pay zero recompiles.  Host
pack/upload staging for chunk i+1 (and the next embed micro-batch) is
double-buffered behind the device's work on chunk i (`_OneAhead`,
DESIGN.md §12) — pure pipelining, bit-exact vs ``prefetch=False``.  The
pre-engine per-step Python loop survives as ``engine='python'``, a parity
shim for tests and the benchmark baseline: it packs, uploads and syncs every
step and re-jits per fit, exactly like the seed trainer.

Resume (DESIGN.md §6): with ``checkpoint_dir`` the scan engine snapshots
(TrainState, base RNG key, metrics history, step cursor) every
``checkpoint_every`` steps through `repro.checkpoint.CheckpointManager`; an
interrupted fit restarted with the same config replays the deterministic
epoch plan and continues from the cursor BIT-EXACTLY (chunks are masked per
step, so chunk boundaries never change the math).

Distribution: batches shard over the mesh's batch axes (the packed
node/edge/graph axes carry the 'batch' logical name — see
`distributed.sharding.constrain_batch`); the InfoNCE logits matrix
z1 @ z2^T makes GSPMD all-gather the projected embeddings — global
negatives across data shards (SimCLR-at-scale adaptation, DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import time
from dataclasses import dataclass, field
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config import TrainConfig
from repro.core import rgcn as rgcn_mod
from repro.core.augment import augment_view, augment_view_packed
from repro.core.batching import (
    MAX_EDGES_PER_MICROBATCH, MAX_NODES_PER_MICROBATCH, bucket_key,
    bucket_size, graph_content_hash, pack_graphs, plan_epoch,
    plan_microbatches, stream_bins,
)
from repro.core.contrastive import info_nce
from repro.core.graphs import KernelGraph, pad_batch
from repro.core.rgcn import RGCNConfig
from repro.distributed.fault import DeviceLost, Watchdog
from repro.distributed.sharding import (
    MeshRules, constrain_batch, set_mesh_rules, shard_batch_put,
)
from repro.optim import TrainState, adamw_init, apply_gradients

#: fixed metric layout of a training step (the scan emits them as one
#: (chunk, len(METRIC_KEYS)) device array; checkpoints store one column per key)
METRIC_KEYS = ("loss", "nce_acc", "pos_sim", "neg_sim", "lr", "grad_norm")


class FitInterrupted(RuntimeError):
    """Raised by ``fit(interrupt_after=k)`` right after the checkpoint at the
    first chunk boundary >= k — the hook tests/CI use to simulate a killed
    training job without killing the process."""


class _OneAhead:
    """One-slot host->device staging pipeline (DESIGN.md §12).

    Wraps an iterable of work items and a ``stage`` callable (host pack +
    ``device_put``); iterating yields ``(item, staged)`` pairs where item
    i+1's staging runs on a single background thread WHILE the caller
    consumes item i — jax dispatch is async, so the device crunches chunk i
    while the host packs chunk i+1.  Items are staged strictly in order on
    one worker, so the staged arrays, their order, and any rng-key
    derivation are identical to inline staging: pure pipelining, bit-exact
    trajectories.  Staged batches are never donated (only TrainState is),
    so a prefetched buffer can never be invalidated by the running chunk.

    ``enabled=False`` degrades to inline staging (the parity baseline);
    ``stage_s`` (host seconds spent staging) and ``wait_s`` (main-thread
    seconds blocked waiting for a stage) quantify the overlap:
    ``overlap_fraction = 1 - wait_s / stage_s``.

    ``depth=k`` keeps up to k staged items queued ahead of the consumer
    (still ONE worker thread, so items stage strictly in submission order
    and bit-exactness is preserved); the default k=1 is the PR 9
    behaviour, while the ingestion pipeline runs deeper so a slow trace
    upstream can't starve the device (DESIGN.md §13).  Peak staged
    residency is bounded by ``depth + 1``.
    """

    def __init__(self, stage, items, *, enabled: bool = True, depth: int = 1):
        self._stage = stage
        self._items = items
        self.enabled = bool(enabled)
        self.depth = max(1, int(depth))
        self.stage_s = 0.0
        self.wait_s = 0.0

    def _timed_stage(self, item):
        t = time.time()
        try:
            return self._stage(item)
        finally:
            self.stage_s += time.time() - t

    @property
    def overlap_fraction(self) -> float:
        if self.stage_s <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.wait_s / self.stage_s)

    def __iter__(self):
        it = iter(self._items)
        if not self.enabled:
            for item in it:  # inline staging: all staging time is wait time
                staged = self._timed_stage(item)
                self.wait_s = self.stage_s
                yield item, staged
            return
        from concurrent.futures import ThreadPoolExecutor

        pool = ThreadPoolExecutor(max_workers=1,
                                  thread_name_prefix="stage-prefetch")
        try:
            def task():
                try:
                    item = next(it)
                except StopIteration:
                    return None
                return item, self._timed_stage(item)

            from collections import deque

            q = deque(pool.submit(task) for _ in range(self.depth))
            while True:
                t = time.time()
                res = q.popleft().result()
                self.wait_s += time.time() - t
                if res is None:
                    return
                q.append(pool.submit(task))  # refill the look-ahead window
                yield res
        finally:
            pool.shutdown(wait=True)


@dataclass(frozen=True)
class GCLTrainConfig:
    steps: int = 120
    batch_size: int = 16
    tau: float = 0.05
    val_fraction: float = 0.2
    log_every: int = 50
    seed: int = 0
    #: 'scan' = compiled device-resident epochs (default);
    #: 'python' = the pre-engine per-step loop, kept as a parity shim
    engine: str = "scan"
    #: scan chunk length (fixed per fit: chunks shorter than this are padded
    #: with masked no-op steps, so ONE executable per bucket serves any step
    #: count).  Effective length is min(scan_chunk, next_pow2(steps)).
    scan_chunk: int = 32
    #: snapshot (state, rng, history, cursor) every N steps (0 = off;
    #: scan engine only) — cadence is rounded up to chunk boundaries
    checkpoint_every: int = 0
    #: validation eval key = fold_in(PRNGKey(seed), eval_fold): seed-derived
    #: and deterministic, disjoint from the per-step fold_in(base_key, i)
    #: stream (was a hard-coded PRNGKey(123) before the linter's R3)
    eval_fold: int = 123
    #: double-buffered host->device staging (DESIGN.md §12): while the device
    #: runs scan chunk i / embed micro-batch i, a background thread packs and
    #: `device_put`s i+1.  Pure pipelining — the staged arrays, their order,
    #: and the fold-in key stream are identical, so trajectories are
    #: bit-exact vs ``prefetch=False`` (asserted by tests/test_train_engine).
    prefetch: bool = True
    #: staged look-ahead window (k slots on ONE worker — order and bits
    #: unchanged).  >1 lets a deep trace->pack->device pipeline ride out
    #: jittery upstream ingestion (DESIGN.md §13).
    prefetch_depth: int = 1
    opt: TrainConfig = field(
        default_factory=lambda: TrainConfig(
            learning_rate=7e-4, weight_decay=0.01, warmup_steps=20,
            total_steps=120, schedule="cosine", grad_clip=1.0,
        )
    )


# ---------------------------------------------------------------------------
# Loss (shared by both engines so they cannot diverge mathematically)
# ---------------------------------------------------------------------------


def packed_loss(params, rc: RGCNConfig, tau: float, batch, rng, *,
                train: bool = True):
    """Packed-batch InfoNCE.  The graph axis is exact (G == batch size), so
    the logits matrix never sees padding graphs.

    ``train=True``: stochastic augs + feature-noise gates, dropout on.
    ``train=False`` (validation): augmentations drawn from the CALLER'S rng
    (pass a fixed key for deterministic "fixed augs"), no feature noise, no
    dropout — the eval-mode path `fit` uses for ``val_loss``/``val_acc``.
    """
    if train:
        r1, r2, rp1, rp2 = jax.random.split(rng, 4)
        v1, noise1 = augment_view_packed(r1, batch)
        v2, noise2 = augment_view_packed(r2, batch)
        z1 = rgcn_mod.encode_packed(params, rc, v1, rng=r1, train=True,
                                    noise_gate=noise1)
        z2 = rgcn_mod.encode_packed(params, rc, v2, rng=r2, train=True,
                                    noise_gate=noise2)
        p1 = rgcn_mod.project(params, rc, z1, rng=rp1, train=True)
        p2 = rgcn_mod.project(params, rc, z2, rng=rp2, train=True)
    else:
        r1, r2 = jax.random.split(rng)
        v1, _ = augment_view_packed(r1, batch)
        v2, _ = augment_view_packed(r2, batch)
        z1 = rgcn_mod.encode_packed(params, rc, v1)
        z2 = rgcn_mod.encode_packed(params, rc, v2)
        p1 = rgcn_mod.project(params, rc, z1)
        p2 = rgcn_mod.project(params, rc, z2)
    return info_nce(p1, p2, tau)


class EngineFns(NamedTuple):
    """Compiled training-engine entry points (one cache entry per
    (RGCNConfig, TrainConfig, tau, MeshRules) — shared across trainer
    instances and fits, so refits never recompile)."""
    scan: callable     # jit (state, stacked batch, keys, live) -> (state, ys)
    step: callable     # UNJITTED single step (the python shim jits per fit)
    eval_loss: callable  # jit (params, batch, rng) -> (loss, metrics)


@functools.lru_cache(maxsize=64)
def _engine_fns(rc: RGCNConfig, opt: TrainConfig, tau: float,
                rules: Optional[MeshRules]) -> EngineFns:
    scale = rc.policy.loss_scale

    def step(state: TrainState, batch, rng):
        batch = constrain_batch(batch, rules)

        def lossf(p):
            loss, metrics = packed_loss(p, rc, tau, batch, rng, train=True)
            # loss-scale hook (precision policy): differentiate the scaled
            # loss; adamw_update unscales via opt.loss_scale.  scale == 1.0
            # multiplies by exactly 1.0 — bit-neutral.
            return loss * scale, (loss, metrics)

        (_, (loss, metrics)), grads = jax.value_and_grad(
            lossf, has_aux=True)(state.params)
        state, opt_metrics = apply_gradients(state, grads, opt)
        return state, dict(metrics, loss=loss, **opt_metrics)

    def chunk(state: TrainState, stacked, keys, live):
        """One fixed-length scan segment.  `live` masks padded / already-done
        steps: a dead step still computes (fixed shapes) but its state update
        and metrics are discarded, which makes chunk boundaries — and hence
        resume points — bit-neutral."""

        def body(st, xs):
            batch, k, lv = xs
            new_st, m = step(st, batch, k)
            st = jax.tree_util.tree_map(
                lambda new, old: jnp.where(lv, new, old), new_st, st)
            return st, jnp.stack([m[x] for x in METRIC_KEYS])

        return jax.lax.scan(body, state, (stacked, keys, live))

    return EngineFns(
        scan=jax.jit(chunk, donate_argnums=(0,)),
        step=step,
        eval_loss=jax.jit(
            lambda p, b, r: packed_loss(p, rc, tau, b, r, train=False)),
    )


class ContrastiveTrainer:
    def __init__(self, rc: RGCNConfig, tc: GCLTrainConfig,
                 mesh_rules: Optional[MeshRules] = None):
        self.rc = rc
        self.tc = tc
        self.mesh_rules = mesh_rules
        self._embed_fn = None          # packed jit'd encode
        self._embed_fn_dense = None    # dense-path jit cache (per max_warps)
        self._embed_cache: dict[str, np.ndarray] = {}
        self._embed_cache_fp: Optional[str] = None
        # LRU-evicted above this many entries: cache hits move the entry to
        # the dict's insertion-order tail, so eviction pops the least
        # recently USED key, not merely the oldest inserted
        self.embed_cache_max = 65536
        self.embed_stats: dict = {}

    # -- loss ---------------------------------------------------------------
    @property
    def _opt(self) -> TrainConfig:
        """Optimizer config with the precision policy's loss scale threaded
        through.  The policy is the ONE source of truth for this trainer —
        a conflicting explicit `opt.loss_scale` is rejected rather than
        silently overridden."""
        if self.tc.opt.loss_scale == self.rc.policy.loss_scale:
            return self.tc.opt
        if self.tc.opt.loss_scale != 1.0:
            raise ValueError(
                f"conflicting loss scales: TrainConfig.loss_scale="
                f"{self.tc.opt.loss_scale} vs policy.loss_scale="
                f"{self.rc.policy.loss_scale}; set it on the precision "
                f"policy (RGCNConfig.policy) only")
        return dataclasses.replace(
            self.tc.opt, loss_scale=self.rc.policy.loss_scale)

    def _engine(self) -> EngineFns:
        return _engine_fns(self.rc, self._opt, self.tc.tau, self.mesh_rules)

    def _loss(self, params, batch, max_warps, rng):
        """Dense-batch InfoNCE (kept for parity tests / benchmarks)."""
        r1, r2, rp1, rp2 = jax.random.split(rng, 4)
        v1, noise1 = augment_view(r1, batch)
        v2, noise2 = augment_view(r2, batch)
        z1 = rgcn_mod.encode(params, self.rc, v1, max_warps, rng=r1,
                             train=True, noise_gate=noise1)
        z2 = rgcn_mod.encode(params, self.rc, v2, max_warps, rng=r2,
                             train=True, noise_gate=noise2)
        p1 = rgcn_mod.project(params, self.rc, z1, rng=rp1, train=True)
        p2 = rgcn_mod.project(params, self.rc, z2, rng=rp2, train=True)
        return info_nce(p1, p2, self.tc.tau)

    def _loss_packed(self, params, batch, rng, *, train=True):
        """Back-compat wrapper over the module-level `packed_loss`."""
        return packed_loss(params, self.rc, self.tc.tau, batch, rng,
                           train=train)

    def _make_step(self, max_warps=None):
        """Seed-faithful per-fit jit of one training step (the python shim's
        executable; `max_warps` is accepted for old callers and ignored).
        A FRESH closure is built per call — like the seed trainer, every fit
        re-traces and re-compiles (jax would otherwise reuse the executable
        cached on the shared engine callable, which is exactly the
        amortization the scan engine claims and the baseline must not get)."""
        raw = self._engine().step

        def step(state, batch, rng):
            return raw(state, batch, rng)

        # lint: allow[R2] parity shim re-jits per fit by design (see above)
        return jax.jit(step, donate_argnums=(0,))

    # -- data ---------------------------------------------------------------
    @staticmethod
    def prepad(graphs: list[KernelGraph], pad_to=None):
        """Dense-batch compatibility shim (see core/graphs.pad_batch)."""
        batch, max_warps = pad_batch(graphs, *(pad_to or (None, None, None)))
        return batch, max_warps

    # -- fit -----------------------------------------------------------------
    def fit(self, graphs: list[KernelGraph], verbose=False, *,
            checkpoint_dir: Optional[str] = None, resume: bool = True,
            interrupt_after: Optional[int] = None,
            fault_hook: Optional[callable] = None,
            watchdog: Optional[Watchdog] = None):
        """Train on an 80/20 split of the program's kernels; returns
        (params, info).

        ``checkpoint_dir`` (scan engine only) enables the resume protocol:
        snapshots every ``tc.checkpoint_every`` steps; when the directory
        already holds a snapshot and ``resume`` is True, training continues
        from its cursor instead of refitting.  ``interrupt_after=k`` raises
        :class:`FitInterrupted` after the checkpoint at the first chunk
        boundary >= k (test/CI hook).

        Scale-out fault protocol (scan engine only, DESIGN.md §11):
        ``fault_hook(done_step)`` runs at every chunk boundary and may raise
        :class:`repro.distributed.fault.DeviceLost` (injection hook for
        fault tests and real lost-participant detectors); a ``watchdog``
        brackets each chunk with step_start/step_end and converts a fired
        straggler SLO into DeviceLost at the SAME boundary.  Either way the
        engine checkpoints at the boundary before re-raising, so
        :func:`fit_resilient` can shrink the mesh and resume — losing at
        most the current chunk, never the fit.
        """
        tc, rc = self.tc, self.rc
        rng_np = np.random.default_rng(tc.seed)
        n = len(graphs)
        perm = rng_np.permutation(n)
        n_val = max(1, int(n * tc.val_fraction)) if n >= 5 else 0
        train_idx = perm[n_val:] if n_val else perm
        val_idx = perm[:n_val]

        key = jax.random.PRNGKey(tc.seed)
        base_key, k_init = jax.random.split(key)
        params = rgcn_mod.init_rgcn(k_init, rc)
        state = adamw_init(params, self._opt)

        # the whole epoch's batch selections, drawn up front with the SAME
        # rng stream the per-step loop used — deterministic given the seed,
        # which is what makes the resume replay exact
        bs = min(tc.batch_size, len(train_idx))
        selections = np.stack([
            train_idx[rng_np.choice(len(train_idx), size=bs,
                                    replace=len(train_idx) < bs)]
            for _ in range(tc.steps)
        ]) if tc.steps else np.zeros((0, bs), np.int64)

        # per-graph caps bound each graph's footprint (and the bucket blowup
        # a pathological graph would cause); with use_pallas the WHOLE batch
        # (~batch_size * graph size) must additionally fit the flat kernel's
        # VMEM budget — size tc.batch_size accordingly (see rgcn_spmm_flat)
        caps = dict(
            max_nodes_per_graph=MAX_NODES_PER_MICROBATCH,
            max_edges_per_graph=MAX_EDGES_PER_MICROBATCH,
        )

        ctx = set_mesh_rules(self.mesh_rules) if self.mesh_rules else None
        if ctx:
            ctx.__enter__()
        try:
            if tc.engine == "python":
                if checkpoint_dir is not None:
                    raise ValueError(
                        "checkpointing requires engine='scan' (the python "
                        "path is a parity shim)")
                if fault_hook is not None or watchdog is not None:
                    raise ValueError(
                        "the fault protocol (fault_hook/watchdog) requires "
                        "engine='scan' — degradation resumes from chunk-"
                        "boundary checkpoints the python shim never writes")
                state, info = self._fit_python(
                    graphs, selections, state, base_key, caps, verbose)
            elif tc.engine == "scan":
                state, info = self._fit_scan(
                    graphs, selections, state, base_key, caps, verbose,
                    checkpoint_dir=checkpoint_dir, resume=resume,
                    interrupt_after=interrupt_after,
                    fault_hook=fault_hook, watchdog=watchdog)
            else:
                raise ValueError(f"unknown engine {tc.engine!r}")

            # validation InfoNCE — eval mode: no dropout, no feature noise,
            # augmentations drawn from a seed-derived key (deterministic)
            trunc_nodes = info["trunc_nodes"]
            if n_val:
                packed, vmeta = pack_graphs(
                    [graphs[i] for i in val_idx], **caps)
                trunc_nodes += int(vmeta.trunc_nodes.sum())
                vb = {k: jnp.asarray(v) for k, v in packed.items()}
                eval_key = jax.random.fold_in(
                    jax.random.PRNGKey(tc.seed), tc.eval_fold)
                loss, m = self._engine().eval_loss(
                    state.params, vb, eval_key)
                info["val_loss"] = float(loss)
                info["val_acc"] = float(m["nce_acc"])
                info["host_syncs"] += 1
        finally:
            if ctx:
                ctx.__exit__(None, None, None)

        if trunc_nodes:
            import warnings

            warnings.warn(
                f"training packed {trunc_nodes} node(s) over the per-graph "
                f"budget; graphs were truncated (see batching caps)",
                stacklevel=2,
            )
        info["trunc_nodes"] = trunc_nodes
        return state.params, info

    # lint: allow[R1] engine="python" parity shim syncs per step by design
    def _fit_python(self, graphs, selections, state, base_key, caps, verbose):
        """The pre-engine per-step loop, preserved as a parity shim and the
        per-step benchmark baseline: packs on the host, uploads, and blocks
        on a device->host metrics sync EVERY step, and re-jits per fit
        (exactly the seed trainer's behavior).  Shares `packed_loss` with the
        scan engine so the two can only differ in execution, not math."""
        tc = self.tc
        step_fn = self._make_step()
        history = []
        bucket_keys = set()
        trunc_nodes = 0
        t0 = time.time()
        for step in range(len(selections)):
            packed, meta = pack_graphs(
                [graphs[i] for i in selections[step]], **caps)
            trunc_nodes += int(meta.trunc_nodes.sum())
            bucket_keys.add(bucket_key(packed))
            batch = {k: jnp.asarray(v) for k, v in packed.items()}
            k_step = jax.random.fold_in(base_key, step)
            state, metrics = step_fn(state, batch, k_step)
            if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
                m = {k: float(v) for k, v in metrics.items()}
                print(
                    f"  step {step:4d} loss={m['loss']:.4f} "
                    f"acc={m['nce_acc']:.3f} lr={m['lr']:.2e} "
                    f"({time.time() - t0:.1f}s)"
                )
            history.append({k: float(v) for k, v in metrics.items()})
        info = {
            "history": history,
            "bucket_keys": sorted(bucket_keys),
            "step_compiles": _jit_cache_size(step_fn),
            "trunc_nodes": trunc_nodes,
            "engine": "python",
            "host_syncs": len(history),
            "resumed_from": 0,
            "checkpoint_saves": 0,
        }
        return state, info

    def _fit_scan(self, graphs, selections, state, base_key, caps, verbose,
                  *, checkpoint_dir, resume, interrupt_after,
                  fault_hook=None, watchdog=None):
        """Compiled engine: pre-packed epoch plan, per-segment device
        staging (sharded over the mesh's batch axes under MeshRules),
        fixed-length masked scan chunks, log_every-gated host syncs,
        chunk-boundary checkpoints.  With ``tc.prefetch`` the host side of
        chunk i+1 (row slicing + shard_batch_put + key derivation) rides a
        background thread behind chunk i's async dispatch (_OneAhead) —
        bit-exact either way."""
        tc = self.tc
        eng = self._engine()
        wd_fired0 = watchdog.fired if watchdog is not None else 0
        plan = plan_epoch(graphs, selections, **caps)
        steps = plan.n_steps
        chunk_len = min(tc.scan_chunk, bucket_size(max(steps, 1), 1))

        mgr = CheckpointManager(checkpoint_dir) if checkpoint_dir else None
        start_step = 0
        history: list[dict] = []
        if mgr is not None and resume and mgr.latest_step() is not None:
            state, history, start_step = self._restore_fit(mgr, base_key)

        host_syncs = 0
        saves = 0
        last_save = start_step
        next_log = ((start_step // tc.log_every) + 1) * tc.log_every
        pending: list[tuple] = []   # (ys device array, live bool mask)
        n_chunks = 0
        t0 = time.time()

        def flush():
            """Pull all buffered per-step metrics to the host in ONE sync."""
            nonlocal host_syncs
            if not pending:
                return
            host_syncs += 1
            for ys, live in pending:
                vals = np.asarray(ys)
                for j in np.nonzero(live)[0]:
                    history.append(
                        {k: float(vals[j, i])
                         for i, k in enumerate(METRIC_KEYS)})
            pending.clear()
            if verbose and history:
                m = history[-1]
                print(
                    f"  step {len(history) - 1:4d} loss={m['loss']:.4f} "
                    f"acc={m['nce_acc']:.3f} lr={m['lr']:.2e} "
                    f"({time.time() - t0:.1f}s)"
                )

        def chunk_descs():
            for seg in plan.segments:
                for lo in range(seg.start, seg.stop, chunk_len):
                    hi = min(lo + chunk_len, seg.stop)
                    if hi <= start_step:
                        continue
                    yield (seg, lo, hi)

        def stage_chunk(desc):
            """Host side of one chunk: slice + edge-pad the segment rows,
            shard/upload them, and derive the fold-in key stream.  Runs on
            the prefetch thread — deterministic in (desc, base_key), so
            overlap cannot change the math."""
            seg, lo, hi = desc
            r0, r1 = lo - seg.start, hi - seg.start
            rows_np = {}
            for f, arr in seg.batches.items():
                rows = arr[r0:r1]
                if len(rows) < chunk_len:  # edge-pad dead tail steps
                    pad = np.repeat(rows[-1:], chunk_len - len(rows),
                                    axis=0)
                    rows = np.concatenate([rows, pad], axis=0)
                rows_np[f] = rows
            # multi-device staging: each device receives only its own
            # shard of the batch axes (leading scan-steps axis stays
            # replicated); plain upload on a 1-device data axis
            stacked = shard_batch_put(rows_np, self.mesh_rules, leading=1)
            abs_idx = np.arange(lo, lo + chunk_len)
            live = (abs_idx < hi) & (abs_idx >= start_step)
            keys = jax.vmap(
                lambda i: jax.random.fold_in(base_key, i)
            )(jnp.asarray(abs_idx))
            return stacked, keys, live

        pipe = _OneAhead(stage_chunk, chunk_descs(), enabled=tc.prefetch,
                         depth=tc.prefetch_depth)
        for (_, _, hi), (stacked, keys, live) in pipe:
            n_chunks += 1
            if watchdog is not None:
                watchdog.step_start()
            state, ys = eng.scan(state, stacked, keys,
                                 jnp.asarray(live))
            pending.append((ys, live))
            if watchdog is not None:
                # SLO timing needs REAL chunk completion — an opt-in
                # sync per chunk, only when a watchdog is armed
                # lint: allow[R1] watchdog SLO measurement is a deliberate per-chunk sync
                jax.block_until_ready(ys)
                watchdog.step_end()

            done = hi
            if done >= next_log or done == steps:
                flush()
                next_log = ((done // tc.log_every) + 1) * tc.log_every
            due = (mgr is not None and tc.checkpoint_every > 0
                   and done - last_save >= tc.checkpoint_every)
            interrupt = (interrupt_after is not None
                         and done >= interrupt_after)
            if due or (interrupt and mgr is not None):
                flush()
                self._save_fit(mgr, state, base_key, history, done)
                last_save = done
                saves += 1
            if interrupt:
                if mgr is not None:
                    mgr.wait()
                raise FitInterrupted(
                    f"fit interrupted at step {done} "
                    f"(interrupt_after={interrupt_after})")
            # fault boundary: a lost/straggling participant surfaces
            # HERE (never mid-chunk) — checkpoint, then let the caller
            # degrade (see fit_resilient)
            lost = None
            if fault_hook is not None:
                try:
                    fault_hook(done)
                except DeviceLost as e:
                    lost = e
            if (lost is None and watchdog is not None
                    and watchdog.fired > wd_fired0):
                lost = DeviceLost(
                    f"chunk ending at step {done} exceeded the "
                    f"watchdog SLO (straggling participant)")
            if lost is not None:
                flush()
                if mgr is not None:
                    if done > last_save:
                        self._save_fit(mgr, state, base_key, history,
                                       done)
                        last_save = done
                        saves += 1
                    mgr.wait()
                raise lost
        flush()

        info = {
            "history": history,
            "bucket_keys": list(plan.bucket_keys),
            "step_compiles": _jit_cache_size(eng.scan),
            "trunc_nodes": plan.trunc_nodes,
            "engine": "scan",
            "host_syncs": host_syncs,
            "resumed_from": start_step,
            "checkpoint_saves": saves,
            "scan_chunks": n_chunks,
            "chunk_len": chunk_len,
            "prefetch": pipe.enabled,
            "prefetch_stage_s": pipe.stage_s,
            "prefetch_wait_s": pipe.wait_s,
            "prefetch_overlap": pipe.overlap_fraction,
            "data_shards": (self.mesh_rules.fsdp_size
                            if self.mesh_rules else 1),
        }
        return state, info

    # -- resume protocol -----------------------------------------------------
    @staticmethod
    def _save_fit(mgr: CheckpointManager, state: TrainState, base_key,
                  history: list[dict], cursor: int):
        tree = {
            "state": {
                "step": state.step, "params": state.params,
                "mu": state.mu, "nu": state.nu,
                **({"compress_err": state.compress_err}
                   if state.compress_err is not None else {}),
            },
            "rng": np.asarray(base_key),
            "history": {
                k: np.asarray([h[k] for h in history], np.float32)
                for k in METRIC_KEYS
            },
            "cursor": np.int64(cursor),
        }
        mgr.save(cursor, tree)

    def _restore_fit(self, mgr: CheckpointManager, base_key):
        """Rebuild (TrainState, history, cursor) from the latest snapshot;
        refuses checkpoints from a different seed (the epoch plan would not
        replay)."""
        tree, ck_step = mgr.restore_tree()
        if not np.array_equal(np.asarray(tree["rng"]),
                              np.asarray(base_key)):
            raise ValueError(
                f"checkpoint in {mgr.directory} was written with a "
                f"different seed; pass resume=False to refit")
        sd = tree["state"]
        state = TrainState(
            step=jnp.asarray(sd["step"]),
            params=jax.tree_util.tree_map(jnp.asarray, sd["params"]),
            mu=jax.tree_util.tree_map(jnp.asarray, sd["mu"]),
            nu=jax.tree_util.tree_map(jnp.asarray, sd["nu"]),
            compress_err=(
                jax.tree_util.tree_map(jnp.asarray, sd["compress_err"])
                if "compress_err" in sd else None),
        )
        cursor = int(tree["cursor"])
        hist = tree["history"]
        history = [
            {k: float(hist[k][i]) for k in METRIC_KEYS}
            for i in range(cursor)
        ]
        return state, history, cursor

    # -- inference ----------------------------------------------------------
    def _embed_setup(self, params, n_cap, e_cap):
        """Shared embed prologue: the content cache is valid only for the
        (params, truncation caps) it was built with; the packed encode fn
        is jit'd once."""
        fp = f"{_params_fingerprint(params)}:{n_cap}:{e_cap}"
        if fp != self._embed_cache_fp:
            self._embed_cache.clear()
            self._embed_cache_fp = fp
        if self._embed_fn is None:
            self._embed_fn = jax.jit(
                lambda p, b: rgcn_mod.encode_packed(p, self.rc, b)
            )
        return self._embed_fn

    def _stage_bin(self, bin_graphs, n_cap, e_cap):
        """Pack + upload one micro-batch (the host half of an encode; runs
        on the prefetch thread).  Per-graph caps: a single graph larger
        than the budget is truncated (with accounting) instead of silently
        blowing the bucket past the Pallas kernel's VMEM budget.
        Returns (device batch, PackMeta, bucket key)."""
        packed, meta = pack_graphs(
            bin_graphs,
            pad_graphs_to=bucket_size(len(bin_graphs), 8),
            max_nodes_per_graph=n_cap, max_edges_per_graph=e_cap,
        )
        batch = {k: jnp.asarray(v) for k, v in packed.items()}
        return batch, meta, bucket_key(packed)

    def _embed_finish(self, label, hashes, fn, stats):
        """Shared embed epilogue: assemble rows from the cache, warn on
        truncation, LRU-evict, publish `self.embed_stats`."""
        if stats["trunc_nodes"] or stats["trunc_edges"]:
            import warnings

            warnings.warn(
                f"{label} truncated {stats['trunc_nodes']} node(s) / "
                f"{stats['trunc_edges']} edge(s) over the micro-batch "
                f"budget; embeddings for the affected graphs are computed "
                f"on truncated graphs",
                stacklevel=3,
            )
        out = np.stack([self._embed_cache[h] for h in hashes]) if hashes \
            else np.zeros((0, self.rc.dims[-1]), np.float32)
        # LRU eviction: hits were moved to the insertion-order tail when
        # looked up, so the dict's first key is the least recently used
        while len(self._embed_cache) > self.embed_cache_max:
            self._embed_cache.pop(next(iter(self._embed_cache)))
        self.embed_stats = {
            "graphs": len(hashes),
            "compiles": _jit_cache_size(fn),
            **stats,
        }
        return out

    def embed(self, params, graphs: list[KernelGraph], batch_size=64,
              max_nodes=None, max_edges=None) -> np.ndarray:
        """256-d kernel embeddings for all graphs (paper §3.4 uses z_k, not
        the projection head output).

        Micro-batched pass over size buckets with a content-hash embedding
        cache: repeated kernel invocations (identical traces) are encoded
        once; micro-batches are size-sorted so jit retraces stay bounded by
        the bucket count.  Stats land in `self.embed_stats`.
        """
        n_cap = max_nodes or MAX_NODES_PER_MICROBATCH
        e_cap = max_edges or MAX_EDGES_PER_MICROBATCH
        fn = self._embed_setup(params, n_cap, e_cap)

        n = len(graphs)
        hashes = [graph_content_hash(g) for g in graphs]
        todo: list[int] = []
        scheduled: set[str] = set()
        for i, hsh in enumerate(hashes):
            if hsh in self._embed_cache:
                # LRU touch: move the hit to the insertion-order tail so
                # hot entries survive eviction pressure
                self._embed_cache[hsh] = self._embed_cache.pop(hsh)
            elif hsh not in scheduled:
                scheduled.add(hsh)
                todo.append(i)

        bucket_keys = set()
        trunc_nodes = trunc_edges = 0
        bins = plan_microbatches(
            [graphs[i] for i in todo],
            max_nodes=n_cap, max_edges=e_cap, max_graphs=batch_size,
        )

        def stage(bin_idx):
            sel = [todo[j] for j in bin_idx]
            return sel, self._stage_bin(
                [graphs[i] for i in sel], n_cap, e_cap)

        pipe = _OneAhead(stage, bins, enabled=self.tc.prefetch,
                         depth=self.tc.prefetch_depth)
        for _, (sel, (batch, meta, bkey)) in pipe:
            z = np.asarray(fn(params, batch))
            trunc_nodes += int(meta.trunc_nodes.sum())
            trunc_edges += int(meta.trunc_edges.sum())
            bucket_keys.add(bkey)
            for k, i in enumerate(sel):
                self._embed_cache[hashes[i]] = z[k]

        return self._embed_finish("embed", hashes, fn, {
            "cache_hits": n - len(todo),
            "encoded": len(todo),
            "microbatches": len(bins),
            "bucket_keys": sorted(bucket_keys),
            "trunc_nodes": trunc_nodes,
            "trunc_edges": trunc_edges,
            "prefetch": pipe.enabled,
            "prefetch_stage_s": pipe.stage_s,
            "prefetch_wait_s": pipe.wait_s,
            "prefetch_overlap": pipe.overlap_fraction,
        })

    def embed_stream(self, params, graphs, batch_size=64, max_nodes=None,
                     max_edges=None) -> np.ndarray:
        """Streaming-iterator variant of `embed`: consumes ANY iterable of
        KernelGraphs (e.g. `repro.workloads.iter_program_graphs`, which
        traces lazily) holding at most one micro-batch of graphs resident
        inside the binner — plus, with ``tc.prefetch``, ONE staged
        micro-batch riding the background upload thread (so peak residency
        is bounded by two micro-batches, never the stream length).

        Unlike `embed`, no global size-sort is possible (the stream is
        consumed in arrival order), so distinct bucket keys may be slightly
        higher; the content-hash cache and pow-2 buckets still apply.
        Peak residency lands in `self.embed_stats` (the bound asserted by
        tests/test_workloads.py).
        """
        n_cap = max_nodes or MAX_NODES_PER_MICROBATCH
        e_cap = max_edges or MAX_EDGES_PER_MICROBATCH
        fn = self._embed_setup(params, n_cap, e_cap)

        order: list[str] = []          # content hash per input position
        scheduled: set[str] = set()
        cache_hits = 0

        def pending():
            nonlocal cache_hits
            for g in graphs:
                h = graph_content_hash(g)
                order.append(h)
                if h in self._embed_cache:
                    # LRU touch (see embed): hot entries survive eviction
                    self._embed_cache[h] = self._embed_cache.pop(h)
                    cache_hits += 1
                    continue
                if h in scheduled:
                    cache_hits += 1
                    continue
                scheduled.add(h)
                yield (h, g)

        bucket_keys = set()
        trunc_nodes = trunc_edges = 0
        stream_stats: dict = {}

        def stage(bin_items):
            return self._stage_bin([g for _, g in bin_items], n_cap, e_cap)

        pipe = _OneAhead(
            stage,
            stream_bins(
                pending(), lambda hg: (hg[1].n_nodes, hg[1].n_edges),
                max_nodes=n_cap, max_edges=e_cap, max_graphs=batch_size,
                stats=stream_stats),
            enabled=self.tc.prefetch,
        )
        for bin_items, (batch, meta, bkey) in pipe:
            z = np.asarray(fn(params, batch))
            trunc_nodes += int(meta.trunc_nodes.sum())
            trunc_edges += int(meta.trunc_edges.sum())
            bucket_keys.add(bkey)
            for k, (h, _) in enumerate(bin_items):
                self._embed_cache[h] = z[k]

        return self._embed_finish("embed_stream", order, fn, {
            "cache_hits": cache_hits,
            "encoded": len(scheduled),
            "microbatches": stream_stats.pop("bins", 0),
            "bucket_keys": sorted(bucket_keys),
            "trunc_nodes": trunc_nodes,
            "trunc_edges": trunc_edges,
            "streaming": True,
            "prefetch": pipe.enabled,
            "prefetch_stage_s": pipe.stage_s,
            "prefetch_wait_s": pipe.wait_s,
            "prefetch_overlap": pipe.overlap_fraction,
            **stream_stats,
        })

    def embed_dense(self, params, graphs: list[KernelGraph], batch_size=64,
                    pad_shapes=None) -> np.ndarray:
        """Dense `pad_batch` embed path — the pre-packing baseline, kept for
        parity tests and benchmarks/bench_batching.py."""
        full, max_warps = self.prepad(graphs, pad_shapes)
        full = {k: np.asarray(v) for k, v in full.items()}
        n = len(graphs)
        if self._embed_fn_dense is None:
            self._embed_fn_dense = {}
        if max_warps not in self._embed_fn_dense:
            self._embed_fn_dense[max_warps] = jax.jit(
                lambda p, b, mw=max_warps: rgcn_mod.encode(p, self.rc, b, mw),
            )
        fn = self._embed_fn_dense[max_warps]
        outs = []
        for i in range(0, n, batch_size):
            sel = slice(i, min(i + batch_size, n))
            batch = {k: jnp.asarray(v[sel]) for k, v in full.items()}
            outs.append(np.asarray(fn(params, batch)))
        return np.concatenate(outs, axis=0)


def fit_resilient(rc: RGCNConfig, tc: GCLTrainConfig,
                  graphs: list[KernelGraph], *, checkpoint_dir: str,
                  device_counts: Optional[list] = None,
                  fault_hook: Optional[callable] = None,
                  watchdog: Optional[Watchdog] = None,
                  mesh_axes: tuple = ("data", "model"),
                  verbose: bool = False):
    """Degrade-don't-abort scale-out driver (DESIGN.md §11).

    Fits on a data-parallel mesh of ``device_counts[0]`` devices; when a
    participant is lost or straggles (the fit raises
    :class:`repro.distributed.fault.DeviceLost` from its fault boundary,
    AFTER checkpointing), the mesh SHRINKS to the next width and training
    resumes from that checkpoint instead of aborting.  ``device_counts``
    defaults to halving widths down to 1 (e.g. 8, 4, 2, 1).

    Returns ``(params, info)`` from the surviving fit, with
    ``info["degradations"]`` recording each shrink and
    ``info["data_shards"]`` the width that finished.  Raises DeviceLost
    only when every width — including the single-device floor — failed.
    """
    from repro.launch.mesh import make_data_mesh

    if not checkpoint_dir:
        raise ValueError("fit_resilient requires a checkpoint_dir — "
                         "degradation resumes from checkpoints")
    if device_counts is None:
        n = jax.device_count()
        device_counts = []
        while n >= 1:
            device_counts.append(n)
            n //= 2
    degradations: list[dict] = []
    last: Optional[DeviceLost] = None
    for i, ndev in enumerate(device_counts):
        rules = make_data_mesh(ndev, axes=mesh_axes)
        trainer = ContrastiveTrainer(rc, tc, mesh_rules=rules)
        try:
            params, info = trainer.fit(
                graphs, verbose, checkpoint_dir=checkpoint_dir,
                resume=True, fault_hook=fault_hook, watchdog=watchdog)
            info["degradations"] = degradations
            info["data_shards"] = ndev
            return params, info
        except DeviceLost as e:
            last = e
            nxt = device_counts[i + 1] if i + 1 < len(device_counts) else None
            degradations.append({"from_devices": ndev, "to_devices": nxt,
                                 "error": str(e)})
            if verbose:
                print(f"[fit_resilient] {e} — degrading "
                      f"{ndev} -> {nxt} devices", flush=True)
    raise DeviceLost(
        f"training failed at every mesh width {device_counts} "
        f"(last: {last})") from last


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _params_fingerprint(params) -> str:
    """Cheap content fingerprint of a param pytree (embedding cache is only
    valid for the params it was computed with).  Every leaf contributes — a
    prefix of its bytes is enough to catch any realistic update."""
    h = hashlib.blake2b(digest_size=8)
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf).tobytes()[:4096])
    return h.hexdigest()
