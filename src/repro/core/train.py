"""Distributed contrastive trainer for the RGCN encoder (paper §3.3, §4).

Training config mirrors the paper: AdamW, lr 7e-4 with cosine annealing,
temperature tau=0.05, 80/20 train/validation split of the program's kernels.

Batching: graphs are PACKED (core/batching.py) — one flat node/edge array per
batch with segment ids, padded to power-of-two size buckets, so jit
recompilation is bounded by the bucket count and no kernel pays for the
batch-wide max size.  The dense `pad_batch` path is kept as `embed_dense`
for parity tests and the batching benchmark baseline.

Distribution: batches shard over the mesh's batch axes (the packed node axis
carries the 'batch' logical name); the InfoNCE logits matrix z1 @ z2^T makes
GSPMD all-gather the projected embeddings — global negatives across data
shards (SimCLR-at-scale adaptation, DESIGN.md §3).
"""

from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.core import rgcn as rgcn_mod
from repro.core.augment import augment_view, augment_view_packed
from repro.core.batching import (
    MAX_EDGES_PER_MICROBATCH, MAX_NODES_PER_MICROBATCH, bucket_key,
    bucket_size, graph_content_hash, pack_graphs, plan_microbatches,
    stream_bins,
)
from repro.core.contrastive import info_nce
from repro.core.graphs import KernelGraph, pad_batch
from repro.core.rgcn import RGCNConfig
from repro.distributed.sharding import MeshRules, set_mesh_rules
from repro.optim import TrainState, adamw_init, apply_gradients


@dataclass(frozen=True)
class GCLTrainConfig:
    steps: int = 120
    batch_size: int = 16
    tau: float = 0.05
    val_fraction: float = 0.2
    log_every: int = 50
    seed: int = 0
    opt: TrainConfig = field(
        default_factory=lambda: TrainConfig(
            learning_rate=7e-4, weight_decay=0.01, warmup_steps=20,
            total_steps=120, schedule="cosine", grad_clip=1.0,
        )
    )


class ContrastiveTrainer:
    def __init__(self, rc: RGCNConfig, tc: GCLTrainConfig,
                 mesh_rules: Optional[MeshRules] = None):
        self.rc = rc
        self.tc = tc
        self.mesh_rules = mesh_rules
        self._step_fn = None
        self._embed_fn = None          # packed jit'd encode
        self._embed_fn_dense = None    # dense-path jit cache (per max_warps)
        self._embed_cache: dict[str, np.ndarray] = {}
        self._embed_cache_fp: Optional[str] = None
        self.embed_cache_max = 65536  # FIFO-evicted above this many entries
        self.embed_stats: dict = {}

    # -- loss ---------------------------------------------------------------
    def _loss(self, params, batch, max_warps, rng):
        """Dense-batch InfoNCE (kept for parity tests / benchmarks)."""
        r1, r2, rp1, rp2 = jax.random.split(rng, 4)
        v1, noise1 = augment_view(r1, batch)
        v2, noise2 = augment_view(r2, batch)
        z1 = rgcn_mod.encode(params, self.rc, v1, max_warps, rng=r1,
                             train=True, noise_gate=noise1)
        z2 = rgcn_mod.encode(params, self.rc, v2, max_warps, rng=r2,
                             train=True, noise_gate=noise2)
        p1 = rgcn_mod.project(params, self.rc, z1, rng=rp1, train=True)
        p2 = rgcn_mod.project(params, self.rc, z2, rng=rp2, train=True)
        return info_nce(p1, p2, self.tc.tau)

    def _loss_packed(self, params, batch, rng):
        """Packed-batch InfoNCE.  The graph axis is exact (G == batch size),
        so the logits matrix never sees padding graphs."""
        r1, r2, rp1, rp2 = jax.random.split(rng, 4)
        v1, noise1 = augment_view_packed(r1, batch)
        v2, noise2 = augment_view_packed(r2, batch)
        z1 = rgcn_mod.encode_packed(params, self.rc, v1, rng=r1,
                                    train=True, noise_gate=noise1)
        z2 = rgcn_mod.encode_packed(params, self.rc, v2, rng=r2,
                                    train=True, noise_gate=noise2)
        p1 = rgcn_mod.project(params, self.rc, z1, rng=rp1, train=True)
        p2 = rgcn_mod.project(params, self.rc, z2, rng=rp2, train=True)
        return info_nce(p1, p2, self.tc.tau)

    def _make_step(self):
        tc = self.tc

        def step(state: TrainState, batch, rng):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: self._loss_packed(p, batch, rng), has_aux=True
            )(state.params)
            state, opt_metrics = apply_gradients(state, grads, tc.opt)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return state, metrics

        return jax.jit(step, donate_argnums=(0,))

    # -- data ---------------------------------------------------------------
    @staticmethod
    def prepad(graphs: list[KernelGraph], pad_to=None):
        """Dense-batch compatibility shim (see core/graphs.pad_batch)."""
        batch, max_warps = pad_batch(graphs, *(pad_to or (None, None, None)))
        return batch, max_warps

    def fit(self, graphs: list[KernelGraph], verbose=False):
        """Train on an 80/20 split of the program's kernels; returns
        (params, history)."""
        tc, rc = self.tc, self.rc
        rng_np = np.random.default_rng(tc.seed)
        n = len(graphs)
        perm = rng_np.permutation(n)
        n_val = max(1, int(n * tc.val_fraction)) if n >= 5 else 0
        train_idx = perm[n_val:] if n_val else perm
        val_idx = perm[:n_val]

        key = jax.random.PRNGKey(tc.seed)
        key, k_init = jax.random.split(key)
        params = rgcn_mod.init_rgcn(k_init, rc)
        state = adamw_init(params, tc.opt)
        step_fn = self._make_step()

        history = []
        bucket_keys = set()
        trunc_nodes = 0
        # per-graph caps bound each graph's footprint (and the bucket blowup
        # a pathological graph would cause); with use_pallas the WHOLE batch
        # (~batch_size * graph size) must additionally fit the flat kernel's
        # VMEM budget — size tc.batch_size accordingly (see rgcn_spmm_flat)
        caps = dict(
            max_nodes_per_graph=MAX_NODES_PER_MICROBATCH,
            max_edges_per_graph=MAX_EDGES_PER_MICROBATCH,
        )
        bs = min(tc.batch_size, len(train_idx))
        ctx = set_mesh_rules(self.mesh_rules) if self.mesh_rules else None
        if ctx:
            ctx.__enter__()
        try:
            t0 = time.time()
            for step in range(tc.steps):
                idx = rng_np.choice(len(train_idx), size=bs,
                                    replace=len(train_idx) < bs)
                sel = train_idx[idx]
                packed, meta = pack_graphs([graphs[i] for i in sel], **caps)
                trunc_nodes += int(meta.trunc_nodes.sum())
                bucket_keys.add(bucket_key(packed))
                batch = {k: jnp.asarray(v) for k, v in packed.items()}
                key, k_step = jax.random.split(key)
                state, metrics = step_fn(state, batch, k_step)
                if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
                    m = {k: float(v) for k, v in metrics.items()}
                    print(
                        f"  step {step:4d} loss={m['loss']:.4f} "
                        f"acc={m['nce_acc']:.3f} lr={m['lr']:.2e} "
                        f"({time.time() - t0:.1f}s)"
                    )
                history.append({k: float(v) for k, v in metrics.items()})
        finally:
            if ctx:
                ctx.__exit__(None, None, None)

        # validation InfoNCE (no dropout/noise, fixed augs)
        val = {}
        if n_val:
            packed, vmeta = pack_graphs([graphs[i] for i in val_idx], **caps)
            trunc_nodes += int(vmeta.trunc_nodes.sum())
            vb = {k: jnp.asarray(v) for k, v in packed.items()}
            loss, m = jax.jit(self._loss_packed)(
                state.params, vb, jax.random.PRNGKey(123)
            )
            val = {"val_loss": float(loss), "val_acc": float(m["nce_acc"])}
        if trunc_nodes:
            import warnings

            warnings.warn(
                f"training packed {trunc_nodes} node(s) over the per-graph "
                f"budget; graphs were truncated (see batching caps)",
                stacklevel=2,
            )
        info = {
            "history": history,
            "bucket_keys": sorted(bucket_keys),
            "step_compiles": _jit_cache_size(step_fn),
            "trunc_nodes": trunc_nodes,
            **val,
        }
        return state.params, info

    # -- inference ----------------------------------------------------------
    def _embed_setup(self, params, n_cap, e_cap):
        """Shared embed prologue: the content cache is valid only for the
        (params, truncation caps) it was built with; the packed encode fn
        is jit'd once."""
        fp = f"{_params_fingerprint(params)}:{n_cap}:{e_cap}"
        if fp != self._embed_cache_fp:
            self._embed_cache.clear()
            self._embed_cache_fp = fp
        if self._embed_fn is None:
            self._embed_fn = jax.jit(
                lambda p, b: rgcn_mod.encode_packed(p, self.rc, b)
            )
        return self._embed_fn

    def _encode_bin(self, fn, params, bin_graphs, n_cap, e_cap):
        """Pack + encode one micro-batch.  Per-graph caps: a single graph
        larger than the budget is truncated (with accounting) instead of
        silently blowing the bucket past the Pallas kernel's VMEM budget.
        Returns (embeddings row-per-graph, PackMeta, bucket key)."""
        packed, meta = pack_graphs(
            bin_graphs,
            pad_graphs_to=bucket_size(len(bin_graphs), 8),
            max_nodes_per_graph=n_cap, max_edges_per_graph=e_cap,
        )
        batch = {k: jnp.asarray(v) for k, v in packed.items()}
        return np.asarray(fn(params, batch)), meta, bucket_key(packed)

    def _embed_finish(self, label, hashes, fn, stats):
        """Shared embed epilogue: assemble rows from the cache, warn on
        truncation, FIFO-evict, publish `self.embed_stats`."""
        if stats["trunc_nodes"] or stats["trunc_edges"]:
            import warnings

            warnings.warn(
                f"{label} truncated {stats['trunc_nodes']} node(s) / "
                f"{stats['trunc_edges']} edge(s) over the micro-batch "
                f"budget; embeddings for the affected graphs are computed "
                f"on truncated graphs",
                stacklevel=3,
            )
        out = np.stack([self._embed_cache[h] for h in hashes]) if hashes \
            else np.zeros((0, self.rc.dims[-1]), np.float32)
        while len(self._embed_cache) > self.embed_cache_max:  # FIFO eviction
            self._embed_cache.pop(next(iter(self._embed_cache)))
        self.embed_stats = {
            "graphs": len(hashes),
            "compiles": _jit_cache_size(fn),
            **stats,
        }
        return out

    def embed(self, params, graphs: list[KernelGraph], batch_size=64,
              max_nodes=None, max_edges=None) -> np.ndarray:
        """256-d kernel embeddings for all graphs (paper §3.4 uses z_k, not
        the projection head output).

        Micro-batched pass over size buckets with a content-hash embedding
        cache: repeated kernel invocations (identical traces) are encoded
        once; micro-batches are size-sorted so jit retraces stay bounded by
        the bucket count.  Stats land in `self.embed_stats`.
        """
        n_cap = max_nodes or MAX_NODES_PER_MICROBATCH
        e_cap = max_edges or MAX_EDGES_PER_MICROBATCH
        fn = self._embed_setup(params, n_cap, e_cap)

        n = len(graphs)
        hashes = [graph_content_hash(g) for g in graphs]
        todo: list[int] = []
        scheduled: set[str] = set()
        for i, hsh in enumerate(hashes):
            if hsh not in self._embed_cache and hsh not in scheduled:
                scheduled.add(hsh)
                todo.append(i)

        bucket_keys = set()
        trunc_nodes = trunc_edges = 0
        bins = plan_microbatches(
            [graphs[i] for i in todo],
            max_nodes=n_cap, max_edges=e_cap, max_graphs=batch_size,
        )
        for bin_idx in bins:
            sel = [todo[j] for j in bin_idx]
            z, meta, bkey = self._encode_bin(
                fn, params, [graphs[i] for i in sel], n_cap, e_cap)
            trunc_nodes += int(meta.trunc_nodes.sum())
            trunc_edges += int(meta.trunc_edges.sum())
            bucket_keys.add(bkey)
            for k, i in enumerate(sel):
                self._embed_cache[hashes[i]] = z[k]

        return self._embed_finish("embed", hashes, fn, {
            "cache_hits": n - len(todo),
            "encoded": len(todo),
            "microbatches": len(bins),
            "bucket_keys": sorted(bucket_keys),
            "trunc_nodes": trunc_nodes,
            "trunc_edges": trunc_edges,
        })

    def embed_stream(self, params, graphs, batch_size=64, max_nodes=None,
                     max_edges=None) -> np.ndarray:
        """Streaming-iterator variant of `embed`: consumes ANY iterable of
        KernelGraphs (e.g. `repro.workloads.iter_program_graphs`, which
        traces lazily) holding at most one micro-batch of graphs resident.

        Unlike `embed`, no global size-sort is possible (the stream is
        consumed in arrival order), so distinct bucket keys may be slightly
        higher; the content-hash cache and pow-2 buckets still apply.
        Peak residency lands in `self.embed_stats` (the bound asserted by
        tests/test_workloads.py).
        """
        n_cap = max_nodes or MAX_NODES_PER_MICROBATCH
        e_cap = max_edges or MAX_EDGES_PER_MICROBATCH
        fn = self._embed_setup(params, n_cap, e_cap)

        order: list[str] = []          # content hash per input position
        scheduled: set[str] = set()
        cache_hits = 0

        def pending():
            nonlocal cache_hits
            for g in graphs:
                h = graph_content_hash(g)
                order.append(h)
                if h in self._embed_cache or h in scheduled:
                    cache_hits += 1
                    continue
                scheduled.add(h)
                yield (h, g)

        bucket_keys = set()
        trunc_nodes = trunc_edges = 0
        stream_stats: dict = {}
        for bin_items in stream_bins(
                pending(), lambda hg: (hg[1].n_nodes, hg[1].n_edges),
                max_nodes=n_cap, max_edges=e_cap, max_graphs=batch_size,
                stats=stream_stats):
            z, meta, bkey = self._encode_bin(
                fn, params, [g for _, g in bin_items], n_cap, e_cap)
            trunc_nodes += int(meta.trunc_nodes.sum())
            trunc_edges += int(meta.trunc_edges.sum())
            bucket_keys.add(bkey)
            for k, (h, _) in enumerate(bin_items):
                self._embed_cache[h] = z[k]

        return self._embed_finish("embed_stream", order, fn, {
            "cache_hits": cache_hits,
            "encoded": len(scheduled),
            "microbatches": stream_stats.pop("bins", 0),
            "bucket_keys": sorted(bucket_keys),
            "trunc_nodes": trunc_nodes,
            "trunc_edges": trunc_edges,
            "streaming": True,
            **stream_stats,
        })

    def embed_dense(self, params, graphs: list[KernelGraph], batch_size=64,
                    pad_shapes=None) -> np.ndarray:
        """Dense `pad_batch` embed path — the pre-packing baseline, kept for
        parity tests and benchmarks/bench_batching.py."""
        full, max_warps = self.prepad(graphs, pad_shapes)
        full = {k: np.asarray(v) for k, v in full.items()}
        n = len(graphs)
        if self._embed_fn_dense is None:
            self._embed_fn_dense = {}
        if max_warps not in self._embed_fn_dense:
            self._embed_fn_dense[max_warps] = jax.jit(
                lambda p, b, mw=max_warps: rgcn_mod.encode(p, self.rc, b, mw),
            )
        fn = self._embed_fn_dense[max_warps]
        outs = []
        for i in range(0, n, batch_size):
            sel = slice(i, min(i + batch_size, n))
            batch = {k: jnp.asarray(v[sel]) for k, v in full.items()}
            outs.append(np.asarray(fn(params, batch)))
        return np.concatenate(outs, axis=0)


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _params_fingerprint(params) -> str:
    """Cheap content fingerprint of a param pytree (embedding cache is only
    valid for the params it was computed with).  Every leaf contributes — a
    prefix of its bytes is enough to catch any realistic update."""
    h = hashlib.blake2b(digest_size=8)
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf).tobytes()[:4096])
    return h.hexdigest()
