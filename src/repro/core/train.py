"""Distributed contrastive trainer for the RGCN encoder (paper §3.3, §4).

Training config mirrors the paper: AdamW, lr 7e-4 with cosine annealing,
temperature tau=0.05, 80/20 train/validation split of the program's kernels.

Distribution: batches shard over the mesh's batch axes; the InfoNCE logits
matrix z1 @ z2^T makes GSPMD all-gather the projected embeddings — global
negatives across data shards (SimCLR-at-scale adaptation, DESIGN.md §3).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.core import rgcn as rgcn_mod
from repro.core.augment import augment_view
from repro.core.contrastive import info_nce
from repro.core.graphs import KernelGraph, pad_batch
from repro.core.rgcn import RGCNConfig
from repro.distributed.sharding import MeshRules, constrain, set_mesh_rules
from repro.optim import TrainState, adamw_init, apply_gradients


@dataclass(frozen=True)
class GCLTrainConfig:
    steps: int = 120
    batch_size: int = 16
    tau: float = 0.05
    val_fraction: float = 0.2
    log_every: int = 50
    seed: int = 0
    opt: TrainConfig = field(
        default_factory=lambda: TrainConfig(
            learning_rate=7e-4, weight_decay=0.01, warmup_steps=20,
            total_steps=120, schedule="cosine", grad_clip=1.0,
        )
    )


class ContrastiveTrainer:
    def __init__(self, rc: RGCNConfig, tc: GCLTrainConfig,
                 mesh_rules: Optional[MeshRules] = None):
        self.rc = rc
        self.tc = tc
        self.mesh_rules = mesh_rules
        self._step_fn = None
        self._embed_fn = None

    # -- loss ---------------------------------------------------------------
    def _loss(self, params, batch, max_warps, rng):
        r1, r2, rp1, rp2 = jax.random.split(rng, 4)
        v1, noise1 = augment_view(r1, batch)
        v2, noise2 = augment_view(r2, batch)
        z1 = rgcn_mod.encode(params, self.rc, v1, max_warps, rng=r1,
                             train=True, noise_gate=noise1)
        z2 = rgcn_mod.encode(params, self.rc, v2, max_warps, rng=r2,
                             train=True, noise_gate=noise2)
        p1 = rgcn_mod.project(params, self.rc, z1, rng=rp1, train=True)
        p2 = rgcn_mod.project(params, self.rc, z2, rng=rp2, train=True)
        return info_nce(p1, p2, self.tc.tau)

    def _make_step(self, max_warps):
        tc = self.tc

        def step(state: TrainState, batch, rng):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: self._loss(p, batch, max_warps, rng), has_aux=True
            )(state.params)
            state, opt_metrics = apply_gradients(state, grads, tc.opt)
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return state, metrics

        return jax.jit(step, donate_argnums=(0,))

    # -- data ---------------------------------------------------------------
    @staticmethod
    def prepad(graphs: list[KernelGraph], pad_to=None):
        batch, max_warps = pad_batch(graphs, *(pad_to or (None, None, None)))
        return batch, max_warps

    def fit(self, graphs: list[KernelGraph], verbose=False):
        """Train on an 80/20 split of the program's kernels; returns
        (params, history)."""
        tc, rc = self.tc, self.rc
        rng_np = np.random.default_rng(tc.seed)
        n = len(graphs)
        perm = rng_np.permutation(n)
        n_val = max(1, int(n * tc.val_fraction)) if n >= 5 else 0
        train_idx = perm[n_val:] if n_val else perm
        val_idx = perm[:n_val]

        full, max_warps = self.prepad(graphs)
        full = {k: np.asarray(v) for k, v in full.items()}

        key = jax.random.PRNGKey(tc.seed)
        key, k_init = jax.random.split(key)
        params = rgcn_mod.init_rgcn(k_init, rc)
        state = adamw_init(params, tc.opt)
        step_fn = self._make_step(max_warps)

        history = []
        bs = min(tc.batch_size, len(train_idx))
        ctx = set_mesh_rules(self.mesh_rules) if self.mesh_rules else None
        if ctx:
            ctx.__enter__()
        try:
            t0 = time.time()
            for step in range(tc.steps):
                idx = rng_np.choice(len(train_idx), size=bs,
                                    replace=len(train_idx) < bs)
                sel = train_idx[idx]
                batch = {k: jnp.asarray(v[sel]) for k, v in full.items()}
                key, k_step = jax.random.split(key)
                state, metrics = step_fn(state, batch, k_step)
                if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
                    m = {k: float(v) for k, v in metrics.items()}
                    print(
                        f"  step {step:4d} loss={m['loss']:.4f} "
                        f"acc={m['nce_acc']:.3f} lr={m['lr']:.2e} "
                        f"({time.time() - t0:.1f}s)"
                    )
                history.append({k: float(v) for k, v in metrics.items()})
        finally:
            if ctx:
                ctx.__exit__(None, None, None)

        # validation InfoNCE (no dropout/noise, fixed augs)
        val = {}
        if n_val:
            vb = {k: jnp.asarray(v[val_idx]) for k, v in full.items()}
            loss, m = jax.jit(
                lambda p, b, r: self._loss(p, b, max_warps, r)
            )(state.params, vb, jax.random.PRNGKey(123))
            val = {"val_loss": float(loss), "val_acc": float(m["nce_acc"])}
        return state.params, {"history": history, "max_warps": max_warps, **val}

    # -- inference ----------------------------------------------------------
    def embed(self, params, graphs: list[KernelGraph], batch_size=64,
              pad_shapes=None) -> np.ndarray:
        """256-d kernel embeddings for all graphs (paper §3.4 uses z_k,
        not the projection head output)."""
        full, max_warps = self.prepad(graphs, pad_shapes)
        full = {k: np.asarray(v) for k, v in full.items()}
        n = len(graphs)
        if self._embed_fn is None:
            self._embed_fn = {}
        if max_warps not in self._embed_fn:
            self._embed_fn[max_warps] = jax.jit(
                lambda p, b, mw=max_warps: rgcn_mod.encode(p, self.rc, b, mw),
            )
        fn = self._embed_fn[max_warps]
        outs = []
        for i in range(0, n, batch_size):
            sel = slice(i, min(i + batch_size, n))
            batch = {k: jnp.asarray(v[sel]) for k, v in full.items()}
            outs.append(np.asarray(fn(params, batch)))
        return np.concatenate(outs, axis=0)
