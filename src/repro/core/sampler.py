"""GCL-Sampler end-to-end pipeline (paper Fig. 2):

  program -> NVBit-like traces -> HRGs -> RGCN contrastive training ->
  kernel embeddings z_k -> K-Means (silhouette K) -> representatives
  (first invocation per cluster) -> SamplingPlan.

This class is the MODEL behind the registered ``gcl`` sampling method;
prefer the unified API (``repro.sampling.get_method("gcl")``) for new code.
``plan_from_labels`` lives in ``repro.sampling`` (shared by all methods)
and is re-exported here for backward compatibility; the K-selection /
clustering stage routes through the compiled planning engine
(``repro.sampling.PlanEngine`` over the swept K-Means in
``core/clustering.py`` — DESIGN.md §8), with the sequential
``select_k_and_cluster`` loop kept as its parity reference.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core.graphs import KernelGraph
from repro.core.rgcn import RGCNConfig
from repro.core.train import ContrastiveTrainer, GCLTrainConfig
from repro.sampling.base import plan_from_labels  # noqa: F401  (compat shim)
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program

if TYPE_CHECKING:  # layering: ingest imports core, so core types it lazily
    from repro.ingest.engine import IngestConfig


def _default_ingest():
    # lazy: repro.ingest sits ABOVE core in the layering (it imports
    # core.graphs), so core must not import it at module load time
    from repro.ingest.engine import IngestConfig

    return IngestConfig()


@dataclass(frozen=True)
class GCLSamplerConfig:
    #: trace window; None = resolve per program (its `trace_caps`, else the
    #: repo defaults in repro.config) — model-zoo programs carry their own
    cap_warps: Optional[int] = None
    cap_instr: Optional[int] = None
    k_max: int = 48
    rgcn: RGCNConfig = field(default_factory=RGCNConfig)
    train: GCLTrainConfig = field(default_factory=GCLTrainConfig)
    train_subsample: int = 400   # cap on kernels used for contrastive training
    #: trace->graph ingestion (workers/depth/cache) — never affects results,
    #: only how fast graphs arrive (excluded from artifact content keys)
    ingest: "IngestConfig" = field(default_factory=_default_ingest)


class GCLSampler:
    def __init__(self, cfg: Optional[GCLSamplerConfig] = None):
        self.cfg = cfg or GCLSamplerConfig()
        self.trainer = ContrastiveTrainer(self.cfg.rgcn, self.cfg.train)
        from repro.ingest.engine import IngestEngine

        self.ingest = IngestEngine(self.cfg.ingest)
        self.params = None

    # -- stages --------------------------------------------------------------
    def attach_graph_store(self, graph_store) -> None:
        """Back the ingestion engine with an on-disk `GraphStore`: warm runs
        then skip tracing entirely (repro.sampling wires this from the
        ArtifactStore's run directory)."""
        self.ingest.store = graph_store

    def build_graphs(self, program: Program) -> list[KernelGraph]:
        return list(self.iter_graphs(program))

    def iter_graphs(self, program: Program):
        """Lazy per-invocation trace + graph build through the ingestion
        engine (parallel workers, dedup memo, optional graph cache) —
        deterministic program order, bounded peak residency."""
        c = self.cfg
        return self.ingest.iter_graphs(program, c.cap_warps, c.cap_instr)

    def train_stream(self, graphs_iter, n_total=None, verbose=False,
                     checkpoint_dir=None, resume=True):
        """Fit on a bounded subset of a graph ITERATOR without materializing
        it.  When `n_total` is known (the Program case: one graph per
        invocation), the subset is the SAME `rng.choice` draw as the
        materialized `train(build_graphs(...))` path — streaming and
        materialized ingestion then train the identical encoder.  Without
        `n_total`, falls back to reservoir sampling (same cap, different
        subset).  Either way at most `train_subsample` graphs are retained.
        `checkpoint_dir`/`resume` thread through to the trainer's resume
        protocol (core/train.py, DESIGN.md §6).
        """
        cap = self.cfg.train_subsample
        rng = np.random.default_rng(self.cfg.train.seed)
        kw = dict(verbose=verbose, checkpoint_dir=checkpoint_dir,
                  resume=resume)
        if n_total is not None:
            if n_total <= cap:
                return self.train(list(graphs_iter), **kw)
            # replicate train()'s selection exactly (indices AND order)
            sel = rng.choice(n_total, cap, replace=False)
            want = set(int(i) for i in sel)
            picked = {i: g for i, g in enumerate(graphs_iter) if i in want}
            # train() sees len == cap <= train_subsample: no re-subsampling
            return self.train([picked[int(i)] for i in sel], **kw)
        buf: list[KernelGraph] = []
        for i, g in enumerate(graphs_iter):
            if len(buf) < cap:
                buf.append(g)
            else:
                j = int(rng.integers(0, i + 1))
                if j < cap:
                    buf[j] = g
        return self.train(buf, **kw)

    def train(self, graphs: list[KernelGraph], verbose=False,
              checkpoint_dir=None, resume=True):
        rng = np.random.default_rng(self.cfg.train.seed)
        if len(graphs) > self.cfg.train_subsample:
            sel = rng.choice(len(graphs), self.cfg.train_subsample, replace=False)
            train_graphs = [graphs[i] for i in sel]
        else:
            train_graphs = graphs
        self.params, info = self.trainer.fit(
            train_graphs, verbose=verbose, checkpoint_dir=checkpoint_dir,
            resume=resume)
        return info

    def embed(self, graphs: list[KernelGraph]) -> np.ndarray:
        """Streaming packed-bucketed embed with a content-hash cache:
        repeated kernel invocations are encoded once (see trainer.embed)."""
        if self.params is None:
            raise RuntimeError(
                "GCLSampler has no trained encoder: call train(graphs) (or "
                "the end-to-end fit(program)) before embed(), or adopt "
                "pretrained params via repro.sampling's ArtifactStore replay"
            )
        return self.trainer.embed(self.params, graphs)

    def embed_stream(self, graphs_iter) -> np.ndarray:
        """Streaming `embed` over a graph iterator (see trainer.embed_stream);
        peak resident graphs bounded by one micro-batch budget."""
        if self.params is None:
            raise RuntimeError(
                "GCLSampler has no trained encoder: call train/train_stream "
                "before embed_stream(), or adopt pretrained params via "
                "repro.sampling's ArtifactStore replay"
            )
        return self.trainer.embed_stream(self.params, graphs_iter)

    def plan_engine(self):
        """The compiled planning engine configured for this sampler:
        `k_max`/seed from the config, `use_pallas` threaded through from
        `RGCNConfig` (the same switch that picks the rgcn_spmm kernel now
        also picks the fused kmeans_assign / silhouette kernels)."""
        from repro.sampling.engine import PlanEngine

        return PlanEngine(k_max=self.cfg.k_max, seed=self.cfg.train.seed,
                          use_pallas=self.cfg.rgcn.use_pallas)

    def cluster(self, embeddings: np.ndarray, seqs: np.ndarray) -> SamplingPlan:
        return self.plan_engine().plan(embeddings, seqs, "GCL-Sampler")

    # -- end-to-end ------------------------------------------------------------
    def fit(self, program: Program, verbose=False) -> SamplingPlan:
        """End-to-end streaming fit: graphs are traced lazily per pass
        (`iter_graphs`), trained via `train_stream` (same subset draw as the
        materialized path) and embedded via `embed_stream`, so peak graph
        residency stays bounded by one micro-batch instead of 2x the
        program (PR 3's guarantee, previously bypassed here)."""
        t0 = time.time()
        train_info = self.train_stream(self.iter_graphs(program),
                                       n_total=len(program), verbose=verbose)
        t2 = time.time()
        emb = self.embed_stream(self.iter_graphs(program))
        t3 = time.time()
        seqs = np.array([k.seq for k in program.kernels])
        plan = self.cluster(emb, seqs)
        plan.extra.update(
            train=train_info,
            embed=dict(self.trainer.embed_stats),
            timings={
                "train_s": t2 - t0,  # includes the lazy trace->graph pass
                "embed_s": t3 - t2, "cluster_s": time.time() - t3,
            },
        )
        return plan
