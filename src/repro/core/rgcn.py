"""RGCN encoder with basis decomposition + projection head (paper §3.3.2).

Architecture (faithful): 3 relational conv layers, input 64 / hidden 128 /
output 256, basis decomposition per layer, LayerNorm + ReLU + Dropout (last
layer keeps the full representation — no dropout), mean-pool readout to warp
embeddings, warp-mean to the kernel embedding z_k in R^256.  Training-time
projection head: 256 -> 128 (ReLU, dropout) -> 64.

Node features are built in-model (paper §3.3.1):
  instruction: 64-d token embedding + positional encoding of normalized PC
  variable:    32-d token embedding ++ 8-d dynamic-value summary -> 40, pad 64
  pseudo:      16-d token embedding, pad 64

TPU adaptation (DESIGN.md §3): messages use the basis trick — one dense
(B,N,D)x(nb,D,O) einsum on the MXU, per-edge relation coefficients, then a
segment-sum aggregation; the Pallas kernel (kernels/rgcn_spmm) implements the
sorted-edge blocked version of the same contraction.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import NUM_RELATIONS
from repro.core.precision import Policy
from repro.distributed.sharding import constrain
from repro.tracing.isa import NUM_OPCODES, PSEUDO_KINDS, VAR_KINDS


@dataclass(frozen=True)
class RGCNConfig:
    dims: tuple = (64, 128, 128, 256)
    num_bases: int = 2
    num_relations: int = NUM_RELATIONS
    proj_hidden: int = 128
    proj_out: int = 64
    dropout: float = 0.1
    feat_noise_sigma: float = 0.01
    use_pallas: bool = False          # dispatch Pallas kernels: rgcn_spmm here,
                                      # fused kmeans_assign in the plan engine
                                      # (interpret resolves per backend)
    message_dtype: str = "float32"    # 'bfloat16' halves message-passing traffic
    #: mixed-precision policy (core/precision.py): activations run in
    #: `policy.compute_dtype`, LayerNorm stats / readout / InfoNCE stay f32,
    #: params stay f32 masters.  The default f32 policy is bit-neutral.
    policy: Policy = field(default_factory=Policy)
    # ablation switches (benchmarks/bench_ablations.py)
    use_vstats: bool = True           # dynamic-value summary features
    relations_used: tuple = (0, 1, 2, 3)  # subset of edge relations


def init_rgcn(key, rc: RGCNConfig):
    ks = iter(jax.random.split(key, 4 * len(rc.dims) + 8))
    p = {
        "embed_instr": jax.random.normal(next(ks), (NUM_OPCODES, 64)) * 0.1,
        "embed_var": jax.random.normal(next(ks), (len(VAR_KINDS), 32)) * 0.1,
        "embed_pseudo": jax.random.normal(next(ks), (len(PSEUDO_KINDS), 16)) * 0.1,
        "layers": [],
    }
    for li in range(len(rc.dims) - 1):
        din, dout = rc.dims[li], rc.dims[li + 1]
        p["layers"].append(
            {
                "basis": jax.random.normal(next(ks), (rc.num_bases, din, dout))
                / np.sqrt(din),
                "comb": jax.random.normal(next(ks), (rc.num_relations, rc.num_bases))
                / np.sqrt(rc.num_bases),
                "w0": jax.random.normal(next(ks), (din, dout)) / np.sqrt(din),
                "b": jnp.zeros((dout,)),
                "ln_scale": jnp.ones((dout,)),
                "ln_bias": jnp.zeros((dout,)),
            }
        )
    p["proj"] = {
        "w1": jax.random.normal(next(ks), (rc.dims[-1], rc.proj_hidden))
        / np.sqrt(rc.dims[-1]),
        "b1": jnp.zeros((rc.proj_hidden,)),
        "w2": jax.random.normal(next(ks), (rc.proj_hidden, rc.proj_out))
        / np.sqrt(rc.proj_hidden),
        "b2": jnp.zeros((rc.proj_out,)),
    }
    return p


def _positional_encoding(pc_norm, dim):
    """Sinusoidal PE of normalized PC (B,N) -> (B,N,dim)."""
    half = dim // 2
    freqs = jnp.exp(jnp.arange(half) * (-np.log(10_000.0) / half))
    ang = pc_norm[..., None] * 1000.0 * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _stats_encode(vstats):
    # signed sqrt: compresses large dynamic values less aggressively than a
    # second log (addresses are already ~footprint-scaled), so problem-size
    # differences survive the mean-pool readout.
    return jnp.sign(vstats) * jnp.sqrt(jnp.abs(vstats)) * 0.3


def node_features(p, rc: RGCNConfig, batch, noise_rng=None):
    tok = batch["token"]
    ntype = batch["node_type"]
    instr = jnp.take(p["embed_instr"], jnp.clip(tok, 0, NUM_OPCODES - 1), axis=0)
    instr = instr + _positional_encoding(batch["pc_norm"], 64)
    var32 = jnp.take(p["embed_var"], jnp.clip(tok, 0, len(VAR_KINDS) - 1), axis=0)
    vstats = batch["vstats"] if rc.use_vstats else jnp.zeros_like(batch["vstats"])
    var = jnp.concatenate(
        [var32, _stats_encode(vstats),
         jnp.zeros(var32.shape[:-1] + (64 - 40,))], axis=-1,
    )
    pse16 = jnp.take(p["embed_pseudo"], jnp.clip(tok, 0, len(PSEUDO_KINDS) - 1), axis=0)
    pseudo = jnp.concatenate([pse16, jnp.zeros(pse16.shape[:-1] + (48,))], axis=-1)
    h = jnp.where(
        (ntype == 0)[..., None], instr,
        jnp.where((ntype == 1)[..., None], pseudo, var),
    )
    if noise_rng is not None:
        h = h + rc.feat_noise_sigma * jax.random.normal(noise_rng, h.shape)
    return h * batch["node_mask"][..., None]


def _layer_epilogue(lp, rc: RGCNConfig, agg, h, node_mask, *, last, rng,
                    train):
    """Self-loop + LayerNorm + ReLU + dropout + node-mask, shared by the
    dense and packed layers (rank-agnostic) so the two paths cannot
    silently diverge.  Under a low-precision policy the self-loop matmul
    runs in the compute dtype while the LayerNorm statistics are taken in
    f32; the result is cast back down except for the last layer, whose
    output feeds the f32 readout.  All casts are identities under f32."""
    out = agg + h @ lp["w0"].astype(h.dtype) + lp["b"]
    out = out.astype(jnp.float32)
    mu = out.mean(-1, keepdims=True)
    sig = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(sig + 1e-5) * lp["ln_scale"] + lp["ln_bias"]
    out = jax.nn.relu(out)
    if not last and train and rng is not None and rc.dropout > 0:
        keep = jax.random.bernoulli(rng, 1 - rc.dropout, out.shape)
        out = out * keep / (1 - rc.dropout)
    out = out * node_mask[..., None]
    return out if last else rc.policy.cast_compute(out)


def _message_dtype(rc: RGCNConfig):
    """Messages run in the NARROWER of `message_dtype` and the policy's
    compute dtype (f32 policy + f32 messages stays f32, bit-neutral)."""
    mdt = jnp.dtype(rc.message_dtype)
    return rc.policy.compute if rc.policy.compute.itemsize < mdt.itemsize else mdt


def _rgcn_layer(lp, rc: RGCNConfig, h, batch, *, last, rng=None, train=False):
    B, N, _ = h.shape
    E = batch["edge_src"].shape[1]
    R = rc.num_relations
    src, dst, etype = batch["edge_src"], batch["edge_dst"], batch["edge_type"]
    emask = batch["edge_mask"]
    if tuple(rc.relations_used) != (0, 1, 2, 3):
        keep = jnp.isin(etype, jnp.asarray(rc.relations_used))
        emask = emask * keep

    # per-(dst, relation) in-degree for normalization 1/|N_r(v)|
    key = dst * R + etype
    deg = jax.vmap(lambda k, m: jax.ops.segment_sum(m, k, num_segments=N * R))(
        key, emask
    )
    norm = 1.0 / jnp.maximum(jnp.take_along_axis(deg, key, axis=1), 1.0)

    if rc.use_pallas:
        from repro.kernels import default_interpret
        from repro.kernels.rgcn_spmm.ops import rgcn_message_agg

        coef = jnp.take(lp["comb"], etype, axis=0)  # (B,E,nb)
        w = coef * (emask * norm)[..., None]
        agg = rgcn_message_agg(
            h, lp["basis"], src, dst, w, N, default_interpret(),
        )
    else:
        # gather-first + aggregate-then-transform: the basis contraction is
        # applied ONCE per (node, basis) after aggregation, so the expensive
        # (D x O) matmul runs on (B,N,nb,D) instead of per-edge payloads and
        # the gather/scatter payload is D, not nb*O.
        mdt = _message_dtype(rc)
        h_m = h.astype(mdt)
        h_src = jnp.take_along_axis(h_m, src[:, :, None], axis=1)  # (B,E,D)
        coef = jnp.take(lp["comb"], etype, axis=0)  # (B,E,nb)
        w = (coef * (emask * norm)[..., None]).astype(mdt)  # (B,E,nb)
        weighted = h_src[:, :, None, :] * w[..., None]  # (B,E,nb,D)
        s = jax.vmap(
            lambda m, d: jax.ops.segment_sum(m, d, num_segments=N)
        )(weighted, dst)                            # (B,N,nb,D)
        agg = jnp.einsum("bnkd,kdo->bno", s, lp["basis"].astype(mdt),
                         preferred_element_type=jnp.float32)

    return _layer_epilogue(lp, rc, agg, h, batch["node_mask"], last=last,
                           rng=rng, train=train)


def encode(p, rc: RGCNConfig, batch, max_warps: int, *, rng=None, train=False,
           noise_gate=None):
    """Graphs -> kernel embeddings z_k (B, dims[-1]).  noise_gate: optional
    (B,) per-graph gate for the feature-noise augmentation."""
    if rng is not None:
        rngs = jax.random.split(rng, len(rc.dims))
    else:
        rngs = [None] * len(rc.dims)
    h = rc.policy.cast_compute(node_features(p, rc, batch))
    if noise_gate is not None and rngs[-1] is not None:
        from repro.core.augment import apply_feature_noise

        h = apply_feature_noise(rngs[-1], h, noise_gate, rc.feat_noise_sigma)
        h = h * batch["node_mask"].astype(h.dtype)[..., None]
    for li, lp in enumerate(p["layers"]):
        h = _rgcn_layer(
            lp, rc, h, batch, last=(li == len(p["layers"]) - 1),
            rng=rngs[li], train=train,
        )
    # warp mean-pool readout, then mean over warps
    wid = batch["warp_id"]
    nmask = batch["node_mask"]
    sums = jax.vmap(
        lambda hh, w, m: jax.ops.segment_sum(hh * m[:, None], w, num_segments=max_warps)
    )(h, wid, nmask)
    cnts = jax.vmap(
        lambda w, m: jax.ops.segment_sum(m, w, num_segments=max_warps)
    )(wid, nmask)
    warp_mean = sums / jnp.maximum(cnts, 1.0)[..., None]
    valid = (cnts > 0).astype(h.dtype)
    zk = jnp.sum(warp_mean * valid[..., None], axis=1) / jnp.maximum(
        jnp.sum(valid, axis=1, keepdims=True), 1.0
    )
    return zk


# ---------------------------------------------------------------------------
# Packed (flat segment-batched) path — see core/batching.py for the layout.
# Node features reuse `node_features` (it is rank-agnostic); message passing
# replaces the per-graph vmap + segment_sum pairs with single global
# segment-sums over the flat axes, and the readout is a two-level
# warp-segment -> graph-segment mean.
# ---------------------------------------------------------------------------


def edge_norm_packed(dst, etype, emask, num_nodes: int, num_relations: int):
    """Per-edge degree normalizer 1/|N_r(dst_e)| for a packed batch.

    h-independent (pure graph structure: dst/etype/edge_mask), so it is
    hoisted out of the layer loop entirely: core/batching.pack_graphs
    precomputes it once per packed batch (numpy, bit-identical — integer-
    valued mask sums and the same 1/max(deg,1) IEEE division), and
    core/augment.py recomputes it per augmented view whose edge_mask
    changed.  This function is the single definition both use and the
    in-trace fallback for batches that predate the ``edge_norm`` field."""
    key = dst * num_relations + etype
    deg = jax.ops.segment_sum(emask, key,
                              num_segments=num_nodes * num_relations)
    return 1.0 / jnp.maximum(jnp.take(deg, key), 1.0)


def _rgcn_layer_packed(lp, rc: RGCNConfig, h, batch, *, last, rng=None,
                       train=False, unfused_ref=False):
    P, _ = h.shape
    R = rc.num_relations
    src, dst, etype = batch["edge_src"], batch["edge_dst"], batch["edge_type"]
    emask = batch["edge_mask"]
    if tuple(rc.relations_used) != (0, 1, 2, 3):
        # the relation filter edits emask, so any precomputed normalizer
        # (derived from the FULL mask) is stale — re-derive per layer
        keep = jnp.isin(etype, jnp.asarray(rc.relations_used))
        emask = emask * keep
        norm = edge_norm_packed(dst, etype, emask, P, R)
    elif unfused_ref or "edge_norm" not in batch:
        norm = edge_norm_packed(dst, etype, emask, P, R)
    else:
        norm = batch["edge_norm"]                       # hoisted (pack_graphs)
    wnorm = emask * norm                                # (Q,)

    coef = jnp.take(lp["comb"], etype, axis=0)          # (Q,nb)
    if rc.use_pallas and not unfused_ref:
        from repro.kernels import default_interpret
        from repro.kernels.rgcn_fused.ops import rgcn_fused_agg_flat

        agg = rgcn_fused_agg_flat(
            h, lp["basis"], src, dst, coef, wnorm, P, default_interpret(),
        )
    elif rc.use_pallas:
        from repro.kernels import default_interpret
        from repro.kernels.rgcn_spmm.ops import rgcn_message_agg_flat

        w = coef * wnorm[:, None]                       # (Q,nb)
        agg = rgcn_message_agg_flat(
            h, lp["basis"], src, dst, w, P, default_interpret(),
        )
    else:
        mdt = _message_dtype(rc)
        w = coef * wnorm[:, None]                       # (Q,nb)
        h_src = jnp.take(h.astype(mdt), src, axis=0)    # (Q,D)
        weighted = h_src[:, None, :] * w[..., None].astype(mdt)  # (Q,nb,D)
        s = jax.ops.segment_sum(weighted, dst, num_segments=P)   # (P,nb,D)
        agg = jnp.einsum("nkd,kdo->no", s, lp["basis"].astype(mdt),
                         preferred_element_type=jnp.float32)

    out = _layer_epilogue(lp, rc, agg, h, batch["node_mask"], last=last,
                          rng=rng, train=train)
    # data-parallel sharding over the packed node axis (bucket sizes are
    # powers of two, so the axis divides evenly); no-op without mesh rules
    return constrain(out, "batch", "embed")


def encode_packed(p, rc: RGCNConfig, batch, *, rng=None, train=False,
                  noise_gate=None, unfused_ref=False):
    """Packed batch -> kernel embeddings z_k (G, dims[-1]).  Static sizes
    come from the batch arrays; noise_gate is a per-graph (G,) gate.
    Padding graphs (graph_mask == 0) produce zero rows.

    ``unfused_ref=True`` reconstructs the pre-fusion path exactly —
    per-layer normalizer recomputation, rgcn_spmm under use_pallas, and
    the four-segment-sum readout — and is the parity/bench baseline for
    the fused default (bit-exact on the jnp path)."""
    if rng is not None:
        rngs = jax.random.split(rng, len(rc.dims))
    else:
        rngs = [None] * len(rc.dims)
    h = rc.policy.cast_compute(node_features(p, rc, batch))  # (P, 64)
    if noise_gate is not None and rngs[-1] is not None:
        from repro.core.augment import apply_feature_noise_packed

        h = apply_feature_noise_packed(
            rngs[-1], h, noise_gate, batch["graph_id"], rc.feat_noise_sigma
        )
        h = h * batch["node_mask"].astype(h.dtype)[:, None]
    for li, lp in enumerate(p["layers"]):
        h = _rgcn_layer_packed(
            lp, rc, h, batch, last=(li == len(p["layers"]) - 1),
            rng=rngs[li], train=train, unfused_ref=unfused_ref,
        )
    # two-level readout: node -> warp segment mean, warp -> graph mean
    wseg, nmask = batch["warp_seg"], batch["node_mask"]
    G = batch["graph_mask"].shape[0]
    if unfused_ref:
        W = batch["warp_graph"].shape[0]
        wsum = jax.ops.segment_sum(h * nmask[:, None], wseg, num_segments=W)
        wcnt = jax.ops.segment_sum(nmask, wseg, num_segments=W)
        warp_mean = wsum / jnp.maximum(wcnt, 1.0)[:, None]
        valid = (wcnt > 0).astype(h.dtype)              # (W,)
        gsum = jax.ops.segment_sum(
            warp_mean * valid[:, None], batch["warp_graph"], num_segments=G
        )
        gcnt = jax.ops.segment_sum(valid, batch["warp_graph"], num_segments=G)
        return gsum / jnp.maximum(gcnt, 1.0)[:, None]
    from repro.kernels.rgcn_fused.ops import fused_two_level_readout

    return fused_two_level_readout(h, nmask, wseg, batch["warp_graph"], G)


def project(p, rc: RGCNConfig, zk, *, rng=None, train=False):
    h = jax.nn.relu(zk @ p["proj"]["w1"] + p["proj"]["b1"])
    if train and rng is not None and rc.dropout > 0:
        keep = jax.random.bernoulli(rng, 1 - rc.dropout, h.shape)
        h = h * keep / (1 - rc.dropout)
    return h @ p["proj"]["w2"] + p["proj"]["b2"]
