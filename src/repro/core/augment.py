"""Graph augmentation pool (paper §3.3.1): node dropping (15%), edge dropping
(15%), feature noise (sigma=0.01).  For each graph one or two strategies are
applied stochastically per view.  All jit-friendly: augmentation = masks +
a noise flag, applied on top of the padded batch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NODE_DROP_RATE = 0.15
EDGE_DROP_RATE = 0.15

# the 6 subsets of {node_drop, edge_drop, noise} of size 1 or 2
_COMBOS = jnp.array(
    [
        [1, 0, 0], [0, 1, 0], [0, 0, 1],
        [1, 1, 0], [1, 0, 1], [0, 1, 1],
    ],
    jnp.float32,
)


def augment_view(rng, batch):
    """Returns (aug_batch, use_noise (B,) float mask)."""
    B, N = batch["node_mask"].shape
    E = batch["edge_mask"].shape[1]
    r_combo, r_node, r_edge = jax.random.split(rng, 3)
    combo = jax.random.randint(r_combo, (B,), 0, _COMBOS.shape[0])
    flags = _COMBOS[combo]  # (B,3) node/edge/noise

    node_keep = jax.random.bernoulli(r_node, 1 - NODE_DROP_RATE, (B, N))
    node_keep = jnp.where(flags[:, 0:1] > 0, node_keep, True)
    edge_keep = jax.random.bernoulli(r_edge, 1 - EDGE_DROP_RATE, (B, E))
    edge_keep = jnp.where(flags[:, 1:2] > 0, edge_keep, True)

    node_mask = batch["node_mask"] * node_keep
    src_keep = jnp.take_along_axis(node_mask, batch["edge_src"], axis=1)
    dst_keep = jnp.take_along_axis(node_mask, batch["edge_dst"], axis=1)
    edge_mask = batch["edge_mask"] * edge_keep * src_keep * dst_keep

    out = dict(batch)
    out["node_mask"] = node_mask
    out["edge_mask"] = edge_mask
    return out, flags[:, 2]


def apply_feature_noise(rng, h, use_noise, sigma):
    """Per-graph gated Gaussian feature noise (B,) gate.  Noise is drawn in
    h's dtype so a bf16 compute policy stays bf16 through augmentation."""
    noise = sigma * jax.random.normal(rng, h.shape, h.dtype)
    return h + noise * use_noise.astype(h.dtype)[:, None, None]


# ---------------------------------------------------------------------------
# Packed-batch variants (core/batching.py layout): per-graph strategy flags
# are gathered onto the flat node/edge axes via graph_id / edge_graph.
# ---------------------------------------------------------------------------


def augment_view_packed(rng, batch):
    """Returns (aug_batch, use_noise (G,) float mask) for a packed batch."""
    P = batch["node_mask"].shape[0]
    Q = batch["edge_mask"].shape[0]
    G = batch["graph_mask"].shape[0]
    r_combo, r_node, r_edge = jax.random.split(rng, 3)
    combo = jax.random.randint(r_combo, (G,), 0, _COMBOS.shape[0])
    flags = _COMBOS[combo]  # (G,3) node/edge/noise

    node_keep = jax.random.bernoulli(r_node, 1 - NODE_DROP_RATE, (P,))
    node_keep = jnp.where(flags[batch["graph_id"], 0] > 0, node_keep, True)
    edge_keep = jax.random.bernoulli(r_edge, 1 - EDGE_DROP_RATE, (Q,))
    edge_keep = jnp.where(flags[batch["edge_graph"], 1] > 0, edge_keep, True)

    node_mask = batch["node_mask"] * node_keep
    src_keep = jnp.take(node_mask, batch["edge_src"])
    dst_keep = jnp.take(node_mask, batch["edge_dst"])
    edge_mask = batch["edge_mask"] * edge_keep * src_keep * dst_keep

    out = dict(batch)
    out["node_mask"] = node_mask
    out["edge_mask"] = edge_mask
    if "edge_norm" in batch:
        # the view's edge_mask changed, so the hoisted degree normalizer
        # (pack_graphs, schema v2) is stale for this view — re-derive it
        # once here (still hoisted OUT of the per-layer loop)
        from repro.core.graphs import NUM_RELATIONS
        from repro.core.rgcn import edge_norm_packed

        out["edge_norm"] = edge_norm_packed(
            batch["edge_dst"], batch["edge_type"], edge_mask, P, NUM_RELATIONS
        )
    return out, flags[:, 2]


def apply_feature_noise_packed(rng, h, use_noise, graph_id, sigma):
    """Per-graph gated Gaussian feature noise on flat (P, D) features.
    Drawn in h's dtype (see `apply_feature_noise`)."""
    noise = sigma * jax.random.normal(rng, h.shape, h.dtype)
    return h + noise * jnp.take(use_noise, graph_id).astype(h.dtype)[:, None]
