"""Symmetric InfoNCE / NT-Xent (paper §3.3.3, eqs. 2-4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_normalize(z, eps=1e-8):
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), eps)


def info_nce(z1, z2, tau: float):
    """z1, z2: (B, d) projected views.  Returns (loss, metrics).

    Always computed in f32: under a bf16 compute policy (core/precision.py)
    the logits/softmax are the numerically sensitive part, so the views are
    upcast here rather than in every caller.
    """
    z1 = l2_normalize(z1.astype(jnp.float32))
    z2 = l2_normalize(z2.astype(jnp.float32))
    S = (z1 @ z2.T) / tau  # eq. 2

    def ce(S):  # eq. 3
        return -jnp.mean(jnp.diag(jax.nn.log_softmax(S, axis=-1)))

    loss = 0.5 * (ce(S) + ce(S.T))  # eq. 4
    B = S.shape[0]
    acc = jnp.mean(jnp.argmax(S, axis=-1) == jnp.arange(B))
    pos = jnp.mean(jnp.diag(S)) * tau
    neg = (jnp.sum(S) - jnp.trace(S)) / jnp.maximum(B * (B - 1), 1) * tau
    return loss, {"nce_acc": acc, "pos_sim": pos, "neg_sim": neg}
