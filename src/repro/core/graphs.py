"""HRG construction from SASS traces (paper §3.2), fully vectorized.

Node categories: instruction (token = opcode), pseudo (MemRef), variable
(register versions via SSA discipline — a new node per write, reads attach to
the most recent version; memory variables keyed by address).

Edge relations (4, matching the paper's model-config):
  0 control-flow   (instr_i -> instr_{i+1} in warp temporal order)
  1 data-src       (variable -> instruction reading it)
  2 data-dst       (instruction -> variable it writes)
  3 mem-ref        (memory variable <-> MemRef pseudo <-> instruction)

Each warp's trace becomes its own subgraph; the kernel graph is their union,
with warp_id labels so the readout can mean-pool per warp then across warps.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.tracing.isa import PSEUDO_IDS, VAR_IDS
from repro.tracing.tracer import WarpTrace

NUM_RELATIONS = 4
NODE_INSTR, NODE_PSEUDO, NODE_VAR = 0, 1, 2


@dataclass
class KernelGraph:
    node_type: np.ndarray   # (N,) int8
    token: np.ndarray       # (N,) int16  opcode / pseudo kind / var kind
    pc_norm: np.ndarray     # (N,) float32
    vstats: np.ndarray      # (N,8) float32
    warp_id: np.ndarray     # (N,) int16
    edge_src: np.ndarray    # (E,) int32
    edge_dst: np.ndarray    # (E,) int32
    edge_type: np.ndarray   # (E,) int8
    n_warps: int

    @property
    def n_nodes(self):
        return len(self.token)

    @property
    def n_edges(self):
        return len(self.edge_src)


def _warp_graph(tr: WarpTrace):
    """Build one warp subgraph; returns node/edge arrays."""
    n = len(tr.opcode)
    max_pc = max(float(tr.pc.max()), 1.0)

    # ---- node bookkeeping ------------------------------------------------
    nt = [np.full(n, NODE_INSTR, np.int8)]
    tok = [tr.opcode.astype(np.int16)]
    pcn = [tr.pc.astype(np.float32) / max_pc]
    vst = [np.zeros((n, 8), np.float32)]
    next_id = n

    es, ed, et = [], [], []

    # ---- control flow ----------------------------------------------------
    if n > 1:
        es.append(np.arange(n - 1, dtype=np.int32))
        ed.append(np.arange(1, n, dtype=np.int32))
        et.append(np.zeros(n - 1, np.int8))

    # ---- register SSA ----------------------------------------------------
    # events: writes from dest slots, reads from src slots
    wi, wreg = [], []
    for c in range(tr.dest.shape[1]):
        m = tr.dest[:, c] >= 0
        wi.append(np.nonzero(m)[0])
        wreg.append(tr.dest[m, c])
    wi = np.concatenate(wi) if wi else np.zeros(0, np.int64)
    wreg = np.concatenate(wreg).astype(np.int64) if len(wi) else np.zeros(0, np.int64)

    ri, rreg = [], []
    for c in range(tr.src.shape[1]):
        m = tr.src[:, c] >= 0
        ri.append(np.nonzero(m)[0])
        rreg.append(tr.src[m, c])
    ri = np.concatenate(ri) if ri else np.zeros(0, np.int64)
    rreg = np.concatenate(rreg).astype(np.int64) if len(ri) else np.zeros(0, np.int64)

    # merge events sorted by (reg, instr, is_write) — reads see writes < i
    ev_reg = np.concatenate([rreg, wreg])
    ev_i = np.concatenate([ri, wi])
    ev_w = np.concatenate([np.zeros(len(ri), np.int8), np.ones(len(wi), np.int8)])
    order = np.lexsort((ev_w, ev_i, ev_reg))
    sreg, si, sw = ev_reg[order], ev_i[order], ev_w[order]
    # version = inclusive cumsum of writes within each reg group
    grp_start = np.concatenate([[True], sreg[1:] != sreg[:-1]])
    wcum = np.cumsum(sw)
    base = np.zeros(len(sw), np.int64)
    starts = np.nonzero(grp_start)[0]
    if len(starts):
        base_vals = wcum[starts] - sw[starts]
        base = np.repeat(base_vals, np.diff(np.concatenate([starts, [len(sw)]])))
    ver = wcum - base  # for writes: its version (>=1); for reads: versions seen

    # write nodes: one per write event (version >= 1)
    w_sel = sw == 1
    n_writes = int(w_sel.sum())
    write_node = next_id + np.arange(n_writes, dtype=np.int64)
    next_id += n_writes
    # map (reg, version) -> write node id for reads
    wkey = sreg[w_sel] * (n + 1) + ver[w_sel]
    worder = np.argsort(wkey, kind="stable")
    wkey_sorted = wkey[worder]
    wnode_sorted = write_node[worder]
    w_instr = si[w_sel]

    nt.append(np.full(n_writes, NODE_VAR, np.int8))
    tok.append(np.full(n_writes, VAR_IDS["reg"], np.int16))
    pcn.append(np.zeros(n_writes, np.float32))
    vst.append(tr.vstats[w_instr.astype(np.int64)])

    # init nodes: regs read at version 0
    r_sel = sw == 0
    r_reg, r_ver, r_i = sreg[r_sel], ver[r_sel], si[r_sel]
    init_mask = r_ver == 0
    init_regs = np.unique(r_reg[init_mask])
    init_ids = next_id + np.arange(len(init_regs), dtype=np.int64)
    next_id += len(init_regs)
    nt.append(np.full(len(init_regs), NODE_VAR, np.int8))
    tok.append(np.full(len(init_regs), VAR_IDS["init"], np.int16))
    pcn.append(np.zeros(len(init_regs), np.float32))
    # init value = stats of first reading instruction (recorded trace value)
    first_read_idx = np.searchsorted(init_regs, r_reg[init_mask])
    init_vst = np.zeros((len(init_regs), 8), np.float32)
    # last assignment wins; order within reg ascending i, so reverse to keep first
    rv = r_i[init_mask][::-1]
    init_vst[first_read_idx[::-1]] = tr.vstats[rv.astype(np.int64)]
    vst.append(init_vst)

    # data-dst edges: write instr -> write var node
    es.append(w_instr.astype(np.int32))
    ed.append(write_node.astype(np.int32))
    et.append(np.full(n_writes, 2, np.int8))

    # data-src edges: var node -> reading instr
    src_nodes = np.empty(len(r_reg), np.int64)
    # versioned reads
    vmask = ~init_mask
    if vmask.any():
        rkey = r_reg[vmask] * (n + 1) + r_ver[vmask]
        pos = np.searchsorted(wkey_sorted, rkey)
        src_nodes[vmask] = wnode_sorted[pos]
    if init_mask.any():
        pos = np.searchsorted(init_regs, r_reg[init_mask])
        src_nodes[init_mask] = init_ids[pos]
    es.append(src_nodes.astype(np.int32))
    ed.append(r_i.astype(np.int32))
    et.append(np.full(len(r_reg), 1, np.int8))

    # ---- memory: MemRef pseudo + memory variable nodes --------------------
    mem_mask = tr.mem_width > 0
    mem_i = np.nonzero(mem_mask)[0]
    if len(mem_i):
        n_mem = len(mem_i)
        pseudo_ids = next_id + np.arange(n_mem, dtype=np.int64)
        next_id += n_mem
        nt.append(np.full(n_mem, NODE_PSEUDO, np.int8))
        tok.append(np.full(n_mem, PSEUDO_IDS["MemRef"], np.int16))
        pcn.append(np.zeros(n_mem, np.float32))
        vst.append(np.zeros((n_mem, 8), np.float32))

        # memory variables live at 128-byte cache-line granularity: loads
        # hitting the same line share one node, so spatial reuse is visible
        # as graph STRUCTURE (what hand-crafted features cannot see).
        addrs = tr.mem_addr[mem_i] >> 7
        uniq, inv = np.unique(addrs, return_inverse=True)
        mem_var_ids = next_id + np.arange(len(uniq), dtype=np.int64)
        next_id += len(uniq)
        nt.append(np.full(len(uniq), NODE_VAR, np.int8))
        tok.append(np.full(len(uniq), VAR_IDS["mem"], np.int16))
        pcn.append(np.zeros(len(uniq), np.float32))
        first_pos = np.full(len(uniq), -1, np.int64)
        first_pos[inv[::-1]] = mem_i[::-1]
        vst.append(tr.vstats[first_pos])

        mvar = mem_var_ids[inv]
        # loads: mem_var -> pseudo -> instr ; stores: instr -> pseudo -> mem_var
        from repro.tracing.isa import OPCODE_IDS

        store_ops = {OPCODE_IDS[o] for o in ("STG", "STS", "RED")}
        is_store = np.isin(tr.opcode[mem_i], list(store_ops))
        ld, st = ~is_store, is_store
        es += [mvar[ld].astype(np.int32), pseudo_ids[ld].astype(np.int32)]
        ed += [pseudo_ids[ld].astype(np.int32), mem_i[ld].astype(np.int32)]
        et += [np.full(ld.sum(), 3, np.int8)] * 2
        es += [mem_i[st].astype(np.int32), pseudo_ids[st].astype(np.int32)]
        ed += [pseudo_ids[st].astype(np.int32), mvar[st].astype(np.int32)]
        et += [np.full(st.sum(), 3, np.int8)] * 2

    node_type = np.concatenate(nt)
    token = np.concatenate(tok)
    pc_norm = np.concatenate(pcn)
    vstats = np.concatenate(vst, axis=0)
    edge_src = np.concatenate(es) if es else np.zeros(0, np.int32)
    edge_dst = np.concatenate(ed) if ed else np.zeros(0, np.int32)
    edge_type = np.concatenate(et) if et else np.zeros(0, np.int8)
    return node_type, token, pc_norm, vstats, edge_src, edge_dst, edge_type


def build_kernel_graph(traces: list[WarpTrace]) -> KernelGraph:
    """Union of per-warp subgraphs with warp ids (paper: kernel graph =
    union of warp graphs; readout averages warp embeddings)."""
    parts = [_warp_graph(t) for t in traces]
    offs = np.cumsum([0] + [len(p[0]) for p in parts])
    node_type = np.concatenate([p[0] for p in parts])
    token = np.concatenate([p[1] for p in parts])
    pc_norm = np.concatenate([p[2] for p in parts])
    vstats = np.concatenate([p[3] for p in parts], axis=0)
    warp_id = np.concatenate(
        [np.full(len(p[0]), w, np.int16) for w, p in enumerate(parts)]
    )
    edge_src = np.concatenate([p[4] + offs[w] for w, p in enumerate(parts)])
    edge_dst = np.concatenate([p[5] + offs[w] for w, p in enumerate(parts)])
    edge_type = np.concatenate([p[6] for p in parts])
    return KernelGraph(
        node_type, token, pc_norm, vstats, warp_id,
        edge_src.astype(np.int32), edge_dst.astype(np.int32), edge_type,
        n_warps=len(parts),
    )


def iter_kernel_graphs(program, cap_warps: int | None = None,
                       cap_instr: int | None = None):
    """Lazily trace + build one HRG per invocation of a
    `tracing.programs.Program` (duck-typed: anything with `.kernels` whose
    items have `.trace`); nothing is retained between yields — the
    streaming-ingestion primitive (see repro.workloads.streaming).

    Omitted caps resolve through ``repro.config.resolve_trace_caps`` — the
    program's own ``trace_caps`` (model-zoo programs) or the repo defaults —
    so this path can never trace at a different window than ``trace()``."""
    from repro.config import resolve_trace_caps

    cap_warps, cap_instr = resolve_trace_caps(cap_warps, cap_instr, program)
    for k in program.kernels:
        yield build_kernel_graph(k.trace(cap_warps, cap_instr))


def pad_batch(graphs: list[KernelGraph], max_nodes=None, max_edges=None,
              max_warps=None):
    """Pad a list of KernelGraphs into dense batch arrays (jit-ready).

    Compatibility shim — new code should use the packed representation in
    core/batching.py, which avoids padding every graph to the batch-wide max.
    When `max_nodes`/`max_edges` caps drop nodes or edges, the per-graph
    counts are surfaced in `trunc_nodes`/`trunc_edges` (B,) and a warning is
    emitted, so sampler fidelity loss is observable instead of silent.
    """
    B = len(graphs)
    N = max_nodes or max(g.n_nodes for g in graphs)
    E = max_edges or max(max(g.n_edges for g in graphs), 1)
    W = max_warps or max(g.n_warps for g in graphs)
    out = {
        "node_type": np.zeros((B, N), np.int32),
        "token": np.zeros((B, N), np.int32),
        "pc_norm": np.zeros((B, N), np.float32),
        "vstats": np.zeros((B, N, 8), np.float32),
        "warp_id": np.zeros((B, N), np.int32),
        "node_mask": np.zeros((B, N), np.float32),
        "edge_src": np.zeros((B, E), np.int32),
        "edge_dst": np.zeros((B, E), np.int32),
        "edge_type": np.zeros((B, E), np.int32),
        "edge_mask": np.zeros((B, E), np.float32),
        "n_warps": np.zeros((B,), np.int32),
        "trunc_nodes": np.zeros((B,), np.int32),
        "trunc_edges": np.zeros((B,), np.int32),
    }
    for b, g in enumerate(graphs):
        n = min(g.n_nodes, N)
        e = min(g.n_edges, E)
        out["node_type"][b, :n] = g.node_type[:n]
        out["token"][b, :n] = g.token[:n]
        out["pc_norm"][b, :n] = g.pc_norm[:n]
        out["vstats"][b, :n] = g.vstats[:n]
        out["warp_id"][b, :n] = g.warp_id[:n]
        out["node_mask"][b, :n] = 1.0
        keep = (g.edge_src[:e] < n) & (g.edge_dst[:e] < n)
        out["edge_src"][b, :e] = np.where(keep, g.edge_src[:e], 0)
        out["edge_dst"][b, :e] = np.where(keep, g.edge_dst[:e], 0)
        out["edge_type"][b, :e] = np.where(keep, g.edge_type[:e], 0)
        out["edge_mask"][b, :e] = keep.astype(np.float32)
        out["n_warps"][b] = g.n_warps
        out["trunc_nodes"][b] = g.n_nodes - n
        out["trunc_edges"][b] = g.n_edges - e + int(e - keep.sum())
    if out["trunc_nodes"].any() or out["trunc_edges"].any():
        import warnings

        warnings.warn(
            f"pad_batch truncated {int(out['trunc_nodes'].sum())} nodes / "
            f"{int(out['trunc_edges'].sum())} edges across "
            f"{int(((out['trunc_nodes'] > 0) | (out['trunc_edges'] > 0)).sum())}"
            f" graph(s); counts are in batch['trunc_nodes'/'trunc_edges']",
            stacklevel=2,
        )
    return out, W
