"""K-Means + silhouette K-selection (paper §3.4).

TPU-native formulation: distances are dense matmuls (|x|^2 - 2xc^T + |c|^2);
Lloyd iterations are jit'd.  K selection maximizes the silhouette
coefficient, preferring the smaller K on near-ties; degenerate structure
(all kernels essentially identical -> max silhouette below threshold)
collapses to K=1, and tiny programs (n <= 4) fall back to distance-threshold
agglomeration (silhouette is uninformative over singletons).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _pairwise_sq(x, c):
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    return jnp.maximum(x2 - 2 * x @ c.T + c2[None], 0.0)


@functools.partial(jax.jit, static_argnames=("k", "iters", "use_pallas"))
def _kmeans_run(x, init_idx, k: int, iters: int = 50, use_pallas: bool = False):
    cent = x[init_idx]

    def assign(cent):
        if use_pallas:  # blocked MXU kernel (interpret=True on CPU)
            from repro.kernels.kmeans_assign.ops import kmeans_assign

            return kmeans_assign(x, cent, interpret=True)
        d = _pairwise_sq(x, cent)
        return jnp.argmin(d, axis=1), jnp.min(d, axis=1)

    def body(cent, _):
        lab, _ = assign(cent)
        onehot = jax.nn.one_hot(lab, k, dtype=x.dtype)
        sums = onehot.T @ x
        cnts = onehot.sum(0)[:, None]
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(body, cent, None, length=iters)
    lab, mind = assign(cent)
    inertia = jnp.sum(mind)
    return lab, cent, inertia


def _kmeanspp_init(x, k, seed):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = [int(rng.integers(n))]
    d = np.sum((x - x[idx[0]]) ** 2, axis=1)
    for _ in range(1, k):
        tot = d.sum()
        if not np.isfinite(tot) or tot <= 1e-20:
            nxt = int(rng.integers(n))  # degenerate: all points coincide
        else:
            nxt = int(rng.choice(n, p=d / tot))
        idx.append(nxt)
        d = np.minimum(d, np.sum((x - x[nxt]) ** 2, axis=1))
    return np.array(idx)


def kmeans(x: np.ndarray, k: int, seed: int = 0, iters: int = 50,
           use_pallas: bool = False):
    """Returns (labels (n,), centroids (k,d), inertia)."""
    x = np.asarray(x, np.float32)
    if k >= len(x):
        return np.arange(len(x)), x.copy(), 0.0
    init = _kmeanspp_init(x, k, seed)
    lab, cent, inertia = _kmeans_run(jnp.asarray(x), jnp.asarray(init), k,
                                     iters, use_pallas)
    return np.asarray(lab), np.asarray(cent), float(inertia)


@jax.jit
def _silhouette_jit(x, lab_onehot):
    """Mean silhouette; clusters of size 1 contribute s=0."""
    d = jnp.sqrt(_pairwise_sq(x, x))
    cnt = lab_onehot.sum(0)  # (k,)
    sums = d @ lab_onehot    # (n,k) total distance to each cluster
    own_cnt = lab_onehot @ cnt  # (n,)
    own_sum = jnp.sum(sums * lab_onehot, axis=1)
    a = own_sum / jnp.maximum(own_cnt - 1, 1)
    mean_other = sums / jnp.maximum(cnt[None, :], 1)
    mean_other = jnp.where(lab_onehot > 0, jnp.inf, mean_other)
    mean_other = jnp.where(cnt[None, :] > 0, mean_other, jnp.inf)
    b = jnp.min(mean_other, axis=1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_cnt > 1, s, 0.0)  # singleton convention
    return jnp.mean(s)


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    k = int(labels.max()) + 1
    onehot = jax.nn.one_hot(jnp.asarray(labels), k, dtype=jnp.float32)
    return float(_silhouette_jit(jnp.asarray(x, jnp.float32), onehot))


def _agglomerate_threshold(x, thresh=0.25):
    """Tiny-n fallback: single-link merge on relative euclidean distance."""
    n = len(x)
    labels = np.arange(n)
    scale = np.mean(np.linalg.norm(x, axis=1)) + 1e-9
    for i in range(n):
        for j in range(i + 1, n):
            if np.linalg.norm(x[i] - x[j]) / scale < thresh:
                labels[labels == labels[j]] = labels[i]
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def select_k_and_cluster(
    x: np.ndarray,
    k_max: int = 48,
    seed: int = 0,
    sil_floor: float = 0.20,
    tie_tol: float = 0.02,
    tiny_n: int = 4,
    sil_cap: int = 1200,
):
    """Paper's K-selection: maximize silhouette, prefer smaller K on ties;
    returns (labels, info).  Silhouette is scored on a deterministic
    subsample when n > sil_cap (standard O(n^2) mitigation)."""
    x = np.asarray(x, np.float32)
    n = len(x)
    if n <= 1:
        return np.zeros(n, int), {"k": max(n, 0), "sil": 1.0, "mode": "trivial"}
    if n <= tiny_n:
        labels = _agglomerate_threshold(x)
        return labels, {"k": int(labels.max()) + 1, "sil": 1.0, "mode": "tiny"}

    sil_idx = None
    if n > sil_cap:
        sil_idx = np.random.default_rng(seed).choice(n, sil_cap, replace=False)

    ks = [k for k in range(2, min(k_max, n - 1) + 1)]
    results = {}
    scores = {}
    for k in ks:
        lab, cent, _ = kmeans(x, k, seed=seed)
        # re-label compactly (empty clusters possible)
        _, lab = np.unique(lab, return_inverse=True)
        if lab.max() == 0:
            continue
        results[k] = lab
        if sil_idx is not None:
            sl = lab[sil_idx]
            if sl.max() == sl.min():
                continue
            _, sl = np.unique(sl, return_inverse=True)
            scores[k] = silhouette(x[sil_idx], sl)
        else:
            scores[k] = silhouette(x, lab)
    if not scores:
        return np.zeros(n, int), {"k": 1, "sil": 0.0, "mode": "degenerate"}
    best = max(scores.values())
    if best < sil_floor:
        return np.zeros(n, int), {"k": 1, "sil": best, "mode": "weak->K=1"}
    chosen = min(k for k, s in scores.items() if s >= best - tie_tol)
    return results[chosen], {
        "k": int(results[chosen].max()) + 1, "sil": scores[chosen],
        "mode": "silhouette", "scores": scores,
    }
