"""K-Means + silhouette K-selection (paper §3.4).

TPU-native formulation: distances are dense matmuls (|x|^2 - 2xc^T + |c|^2);
Lloyd iterations are jit'd.  K selection maximizes the silhouette
coefficient, preferring the smaller K on near-ties; degenerate structure
(all kernels essentially identical -> max silhouette below threshold)
collapses to K=1, and tiny programs (n <= 4) fall back to distance-threshold
agglomeration (silhouette is uninformative over singletons).

Two implementations share the selection rule (DESIGN.md §8):

- the SEQUENTIAL reference (`select_k_and_cluster`): one jitted K-Means fit
  per candidate K plus an O(n^2) silhouette per candidate — up to ~2(k_max-1)
  dispatches and as many executables per embedding shape;
- the SWEPT engine (`select_k_and_cluster_swept` / `sweep_cluster_stack`):
  centroids padded to `k_max` with mask-aware Lloyd updates, every candidate
  K evaluated via `vmap`/`lax.scan` inside ONE executable, on-device
  kmeans++ init (fold-in RNG), and a blocked silhouette that never
  materializes the n x n distance matrix.  Executables are cached
  process-wide per (batch, bucket, d, k_max, ...) key — the second program
  in a bucket never recompiles (`ENGINE_STATS`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

#: process-wide swept-engine instrumentation: `builds` counts compiled
#: executables (cache misses), `dispatches` counts engine invocations
ENGINE_STATS = {"builds": 0, "dispatches": 0}
_ENGINE_CACHE: dict[tuple, object] = {}

#: points-axis power-of-two bucket floor for the swept engine (embeddings
#: are padded per bucket so nearby program sizes share one executable)
POINT_FLOOR = 32


def _pairwise_sq(x, c):
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)
    return jnp.maximum(x2 - 2 * x @ c.T + c2[None], 0.0)


# ---------------------------------------------------------------------------
# sequential reference path (one fit per candidate K)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "iters", "use_pallas"))
def _kmeans_run(x, init_idx, k: int, iters: int = 50, use_pallas: bool = False):
    cent = x[init_idx]

    def assign(cent):
        if use_pallas:  # blocked MXU kernel (interpret resolves per backend)
            from repro.kernels.kmeans_assign.ops import kmeans_assign

            return kmeans_assign(x, cent)
        d = _pairwise_sq(x, cent)
        return jnp.argmin(d, axis=1), jnp.min(d, axis=1)

    def body(cent, _):
        lab, _ = assign(cent)
        onehot = jax.nn.one_hot(lab, k, dtype=x.dtype)
        sums = onehot.T @ x
        cnts = onehot.sum(0)[:, None]
        new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cent)
        return new, None

    cent, _ = jax.lax.scan(body, cent, None, length=iters)
    lab, mind = assign(cent)
    inertia = jnp.sum(mind)
    return lab, cent, inertia


def _kmeanspp_init(x, k, seed):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = [int(rng.integers(n))]
    d = np.sum((x - x[idx[0]]) ** 2, axis=1)
    for _ in range(1, k):
        tot = d.sum()
        if not np.isfinite(tot) or tot <= 1e-20:
            nxt = int(rng.integers(n))  # degenerate: all points coincide
        else:
            nxt = int(rng.choice(n, p=d / tot))
        idx.append(nxt)
        d = np.minimum(d, np.sum((x - x[nxt]) ** 2, axis=1))
    return np.array(idx)


def kmeans(x: np.ndarray, k: int, seed: int = 0, iters: int = 50,
           use_pallas: bool = False, init_idx=None):
    """Returns (labels (n,), centroids (k,d), inertia).  `init_idx`
    overrides the kmeans++ seeding (the device-init parity path)."""
    x = np.asarray(x, np.float32)
    if k >= len(x):
        return np.arange(len(x)), x.copy(), 0.0
    init = _kmeanspp_init(x, k, seed) if init_idx is None else init_idx[:k]
    lab, cent, inertia = _kmeans_run(jnp.asarray(x), jnp.asarray(init), k,
                                     iters, use_pallas)
    return np.asarray(lab), np.asarray(cent), float(inertia)


@jax.jit
def _silhouette_jit(x, lab_onehot):
    """Mean silhouette; clusters of size 1 contribute s=0."""
    d = jnp.sqrt(_pairwise_sq(x, x))
    cnt = lab_onehot.sum(0)  # (k,)
    sums = d @ lab_onehot    # (n,k) total distance to each cluster
    own_cnt = lab_onehot @ cnt  # (n,)
    own_sum = jnp.sum(sums * lab_onehot, axis=1)
    a = own_sum / jnp.maximum(own_cnt - 1, 1)
    mean_other = sums / jnp.maximum(cnt[None, :], 1)
    mean_other = jnp.where(lab_onehot > 0, jnp.inf, mean_other)
    mean_other = jnp.where(cnt[None, :] > 0, mean_other, jnp.inf)
    b = jnp.min(mean_other, axis=1)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_cnt > 1, s, 0.0)  # singleton convention
    return jnp.mean(s)


def silhouette(x: np.ndarray, labels: np.ndarray) -> float:
    k = int(labels.max()) + 1
    onehot = jax.nn.one_hot(jnp.asarray(labels), k, dtype=jnp.float32)
    return float(_silhouette_jit(jnp.asarray(x, jnp.float32), onehot))


def _agglomerate_threshold(x, thresh=0.25):
    """Tiny-n fallback: single-link merge on relative euclidean distance."""
    n = len(x)
    labels = np.arange(n)
    scale = np.mean(np.linalg.norm(x, axis=1)) + 1e-9
    for i in range(n):
        for j in range(i + 1, n):
            if np.linalg.norm(x[i] - x[j]) / scale < thresh:
                labels[labels == labels[j]] = labels[i]
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def _choose_k(scores: dict[int, float], sil_floor: float, tie_tol: float):
    """Shared K-selection rule: maximize silhouette, prefer the smaller K
    on near-ties, collapse to K=1 below the floor.  Returns (chosen_k,
    best_score); chosen_k is None on the K=1 collapse."""
    best = max(scores.values())
    if best < sil_floor:
        return None, best
    return min(k for k, s in scores.items() if s >= best - tie_tol), best


def _host_preamble(x, seed, tiny_n, sil_cap):
    """Degenerate/tiny handling + the deterministic silhouette subsample,
    shared verbatim by the sequential and swept paths.  Returns either
    (labels, info) for an early exit or (None, sil_idx)."""
    n = len(x)
    if n <= 1:
        return (np.zeros(n, int),
                {"k": max(n, 0), "sil": 1.0, "mode": "trivial"}), None
    if n <= tiny_n:
        labels = _agglomerate_threshold(x)
        return (labels,
                {"k": int(labels.max()) + 1, "sil": 1.0, "mode": "tiny"}), None
    sil_idx = None
    if n > sil_cap:
        sil_idx = np.random.default_rng(seed).choice(n, sil_cap, replace=False)
    return None, sil_idx


def select_k_and_cluster(
    x: np.ndarray,
    k_max: int = 48,
    seed: int = 0,
    sil_floor: float = 0.20,
    tie_tol: float = 0.02,
    tiny_n: int = 4,
    sil_cap: int = 1200,
    iters: int = 50,
    use_pallas: bool = False,
    init: str = "host",
):
    """Paper's K-selection: maximize silhouette, prefer smaller K on ties;
    returns (labels, info).  Silhouette is scored on a deterministic
    subsample when n > sil_cap (standard O(n^2) mitigation).

    This is the sequential REFERENCE: one jitted fit + silhouette per
    candidate K.  The compiled engine (`select_k_and_cluster_swept`) returns
    identical labels/K and is the production path (repro.sampling.PlanEngine).
    `init="device"` seeds kmeans++ on-device with fold-in RNG (the engine's
    fully device-resident mode); the default `"host"` numpy seeding is
    bit-stable with the historical behavior.
    """
    x = np.asarray(x, np.float32)
    n = len(x)
    done, sil_idx = _host_preamble(x, seed, tiny_n, sil_cap)
    if done is not None:
        return done

    ks = [k for k in range(2, min(k_max, n - 1) + 1)]
    dev_init = None
    if init == "device":
        dev_init = device_init_indices(x, seed, min(k_max, n - 1))
    results = {}
    scores = {}
    for k in ks:
        lab, cent, _ = kmeans(x, k, seed=seed, iters=iters,
                              use_pallas=use_pallas, init_idx=dev_init)
        # re-label compactly (empty clusters possible)
        # lint: allow[R1] sequential reference syncs per candidate K by design
        _, lab = np.unique(lab, return_inverse=True)
        if lab.max() == 0:
            continue
        results[k] = lab
        if sil_idx is not None:
            sl = lab[sil_idx]
            if sl.max() == sl.min():
                continue
            _, sl = np.unique(sl, return_inverse=True)
            scores[k] = silhouette(x[sil_idx], sl)
        else:
            scores[k] = silhouette(x, lab)
    if not scores:
        return np.zeros(n, int), {"k": 1, "sil": 0.0, "mode": "degenerate"}
    chosen, best = _choose_k(scores, sil_floor, tie_tol)
    if chosen is None:
        return np.zeros(n, int), {"k": 1, "sil": best, "mode": "weak->K=1"}
    return results[chosen], {
        "k": int(results[chosen].max()) + 1, "sil": scores[chosen],
        "mode": "silhouette", "scores": scores,
    }


# ---------------------------------------------------------------------------
# compiled K-sweep engine: every candidate K in one executable
# ---------------------------------------------------------------------------

def bucket_points(n: int) -> int:
    """Next power-of-two points bucket >= POINT_FLOOR (the swept engine's
    padding unit; PlanEngine groups requests by this same key)."""
    b = POINT_FLOOR
    while b < n:
        b <<= 1
    return b


def bucket_batch(n: int) -> int:
    """Power-of-two batch-axis padding for a chunk of n programs (all-zero
    pmask rows are inert), so odd chunk/tail sizes share an executable."""
    b = 1
    while b < n:
        b <<= 1
    return b


def _round_sil_block(n_pad: int, sil_block: int) -> int:
    """Largest power-of-two block <= sil_block that divides the pow2 points
    bucket (a non-divisor block would silently drop distance columns).
    Shared by the sweep and the warm-pool pre-build so both resolve the
    SAME executable cache key."""
    blk = min(sil_block, n_pad)
    while n_pad % blk:
        blk &= blk - 1  # largest power of two <= blk
    return blk


def _device_kmeanspp(x, pmask, key, k_up: int):
    """On-device kmeans++ (D^2 sampling) over the masked points, fold-in
    RNG per draw.  Returns (k_up,) int32 indices; the first k entries are a
    valid kmeans++ seeding for any candidate K <= k_up (prefix property)."""
    base_logits = jnp.where(pmask > 0, 0.0, -jnp.inf)
    i0 = jax.random.categorical(jax.random.fold_in(key, 0), base_logits)
    d0 = jnp.sum((x - x[i0]) ** 2, axis=1) * pmask
    idx0 = jnp.zeros(k_up, jnp.int32).at[0].set(i0.astype(jnp.int32))

    def body(t, carry):
        idx, d = carry
        tot = jnp.sum(d)
        dlog = jnp.where(d > 0, jnp.log(jnp.maximum(d, 1e-30)), -jnp.inf)
        logits = jnp.where(tot > 1e-20, dlog, base_logits)
        nxt = jax.random.categorical(jax.random.fold_in(key, t), logits)
        d = jnp.minimum(d, jnp.sum((x - x[nxt]) ** 2, axis=1) * pmask)
        return idx.at[t].set(nxt.astype(jnp.int32)), d

    idx, _ = jax.lax.fori_loop(1, k_up, body, (idx0, d0))
    return idx


@functools.partial(jax.jit, static_argnames=("k_up",))
def _device_init_padded(xp, pmask, seed, k_up: int):
    key = jax.random.PRNGKey(seed)
    return _device_kmeanspp(xp, pmask, key, k_up)


def device_init_indices(x: np.ndarray, seed: int, k_up: int) -> np.ndarray:
    """Host entry point for the on-device kmeans++ seeding, evaluated at the
    padded bucket shape so the sequential reference and the swept engine
    draw IDENTICAL indices (categorical sampling is shape-dependent).
    Padding happens on the HOST so the executable is keyed on the bucket
    shape, not the raw n — any program of a bucket (with k_up = k_max)
    reuses one compiled init, and the warm pool can pre-build it."""
    x = np.asarray(x, np.float32)
    n = len(x)
    n_pad = bucket_points(n)
    xp = np.zeros((n_pad, x.shape[1]), np.float32)
    xp[:n] = x
    pmask = (np.arange(n_pad) < n).astype(np.float32)
    idx = _device_init_padded(jnp.asarray(xp), jnp.asarray(pmask), seed, k_up)
    return np.asarray(idx)


def _sil_sums_all(x, onehot_all, sil_block: int):
    """Blocked silhouette accumulator for EVERY candidate at once: the
    (n_pad, block) distance tile is computed once per block and contracted
    against each candidate's masked one-hot — the n x n matrix never
    materializes and the distance work is shared across candidates."""
    n_pad = x.shape[0]
    assert n_pad % sil_block == 0, (n_pad, sil_block)  # no dropped columns
    x2 = jnp.sum(x * x, axis=1)
    nb = n_pad // sil_block

    def body(acc, jb):
        xb = jax.lax.dynamic_slice_in_dim(x, jb * sil_block, sil_block)
        ohb = jax.lax.dynamic_slice_in_dim(
            onehot_all, jb * sil_block, sil_block, axis=1)
        xb2 = jnp.sum(xb * xb, axis=1)
        d2 = jnp.maximum(x2[:, None] - 2.0 * (x @ xb.T) + xb2[None, :], 0.0)
        dist = jnp.sqrt(d2)                           # (n_pad, blk)
        return acc + jnp.einsum("nb,kbc->knc", dist, ohb), None

    acc0 = jnp.zeros((onehot_all.shape[0], n_pad, onehot_all.shape[2]),
                     x.dtype)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(nb))
    return acc                                        # (num_k, n_pad, k_max)


def _sweep_core(x, pmask, init_idx, sil_mask, *, k_max: int, iters: int,
                use_pallas: bool, sil_block: int):
    """One program, every candidate K (2..k_max), one trace.

    Masking rules (DESIGN.md §8): `pmask` marks real points — padding is
    excluded from centroid sums/counts, inertia, and silhouette means;
    per-candidate `cmask` marks live centroid slots — dead slots never win
    an assignment and empty clusters keep their previous centroid.
    `init_idx` carries the kmeans++ seeding (host numpy draw or the
    on-device `device_init_indices` draw — always taken at the program's
    OWN points bucket, so results never depend on batch composition).
    """
    n_pad, d = x.shape
    ks = jnp.arange(2, k_max + 1)                     # (num_k,)
    n_real = jnp.sum(pmask)
    # same candidate set as the sequential `range(2, min(k_max, n-1) + 1)`
    k_valid = ks.astype(x.dtype) <= jnp.minimum(
        # lint: allow[R1] k_max is a static arg — trace-time constant
        jnp.asarray(float(k_max), x.dtype), n_real - 1.0)

    cent0 = x[init_idx]                               # (k_max, d) shared
    cmask_all = (jnp.arange(k_max)[None, :] < ks[:, None]).astype(x.dtype)

    if use_pallas:
        from repro.kernels.kmeans_assign.ops import (
            kmeans_assign_fused, silhouette_sums,
        )

        def lloyd_one(cmask):
            def body(cent, _):
                lab, _, sums, cnts = kmeans_assign_fused(x, cent, cmask,
                                                         pmask)
                new = jnp.where((cnts > 0)[:, None],
                                sums / jnp.maximum(cnts, 1)[:, None], cent)
                return new, None

            cent, _ = jax.lax.scan(body, cent0, None, length=iters)
            lab, _, _, _ = kmeans_assign_fused(x, cent, cmask, pmask)
            return lab

        labels_all = jax.lax.map(lloyd_one, cmask_all)  # (num_k, n_pad)
        onehot_all = (jax.nn.one_hot(labels_all, k_max, dtype=x.dtype)
                      * sil_mask[None, :, None])
        sums_all = jax.lax.map(lambda oh: silhouette_sums(x, oh), onehot_all)
    else:
        def lloyd_one(cmask):
            def assign(cent):
                d2 = _pairwise_sq(x, cent)
                d2 = jnp.where(cmask[None, :] > 0, d2, jnp.inf)
                return jnp.argmin(d2, axis=1)

            def body(cent, _):
                lab = assign(cent)
                onehot = (jax.nn.one_hot(lab, k_max, dtype=x.dtype)
                          * pmask[:, None])
                sums = onehot.T @ x
                cnts = onehot.sum(0)[:, None]
                new = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1), cent)
                return new, None

            cent, _ = jax.lax.scan(body, cent0, None, length=iters)
            return assign(cent)

        labels_all = jax.vmap(lloyd_one)(cmask_all)   # (num_k, n_pad)
        onehot_all = (jax.nn.one_hot(labels_all, k_max, dtype=x.dtype)
                      * sil_mask[None, :, None])
        sums_all = _sil_sums_all(x, onehot_all, sil_block)

    # vectorized masked silhouette (same math as _silhouette_jit, restricted
    # to the sil_mask subset; empty clusters are excluded via cnt > 0)
    cnt = onehot_all.sum(1)                           # (num_k, k_max)
    own_cnt = jnp.einsum("knc,kc->kn", onehot_all, cnt)
    own_sum = jnp.sum(sums_all * onehot_all, axis=2)
    a = own_sum / jnp.maximum(own_cnt - 1, 1)
    mean_other = sums_all / jnp.maximum(cnt[:, None, :], 1)
    mean_other = jnp.where(onehot_all > 0, jnp.inf, mean_other)
    mean_other = jnp.where(cnt[:, None, :] > 0, mean_other, jnp.inf)
    b = jnp.min(mean_other, axis=2)
    s = (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12)
    s = jnp.where(own_cnt > 1, s, 0.0) * sil_mask[None, :]
    sil = jnp.sum(s, axis=1) / jnp.maximum(jnp.sum(sil_mask), 1.0)
    n_live = jnp.sum(cnt > 0, axis=1)                 # clusters in subset
    ok = (k_valid > 0) & (n_live >= 2)
    return labels_all.astype(jnp.int32), sil, ok


def _sweep_fn(batch: int, n_pad: int, d: int, k_max: int, iters: int,
              use_pallas: bool, sil_block: int, shards: int = 1):
    """Process-wide executable cache: one jitted sweep per static key.
    Shapes are fixed per key, so each entry compiles exactly once —
    `ENGINE_STATS['builds']` therefore counts executable builds.

    ``shards`` is the program-axis device count the dispatch will commit
    its arguments to.  It is part of the key — jit silently re-lowers per
    input sharding, so an entry serving BOTH replicated and sharded
    arguments would hide a compile from the builds counter and break the
    warmup/zero-recompile guarantee (DESIGN.md §11)."""
    key = (batch, n_pad, d, k_max, iters, use_pallas, sil_block, shards)
    fn = _ENGINE_CACHE.get(key)
    if fn is None:
        ENGINE_STATS["builds"] += 1
        core = functools.partial(
            _sweep_core, k_max=k_max, iters=iters, use_pallas=use_pallas,
            sil_block=sil_block)
        fn = jax.jit(jax.vmap(core) if batch > 1 else core)
        _ENGINE_CACHE[key] = fn
    return fn


def _effective_shards(batch: int, data_shards: int) -> int:
    """Program-axis shard count for a dispatch: the largest power of two
    <= ``data_shards`` that divides the (pow2) batch bucket, capped by the
    devices actually present.  Shared by warm_sweep and the dispatch path
    so warmed cache keys are exactly the served keys."""
    if data_shards <= 1 or batch <= 1:
        return 1
    s = 1
    while (s << 1) <= min(batch, data_shards, jax.device_count()):
        s <<= 1
    return s


def _shard_args(args: tuple, shards: int) -> tuple:
    """Commit stacked sweep args to a 1-D data mesh over the leading
    program axis (each device holds batch/shards programs)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.array(jax.devices()[:shards]), ("data",))
    return tuple(
        jax.device_put(a, NamedSharding(
            mesh, PartitionSpec(*(("data",) + (None,) * (a.ndim - 1)))))
        for a in args)


def warm_sweep(batch: int, n_pad: int, d: int, k_max: int = 48,
               iters: int = 50, use_pallas: bool = False, init: str = "host",
               sil_block: int = 512, data_shards: int = 1) -> int:
    """Executable PRE-BUILD entry point for the warm pool: compile the swept
    executable for one ``(batch, points-bucket, dim)`` cache key off the
    serving path, so the first real request of a bucket never pays the
    compile.  The jitted sweep is driven once on inert inputs (all-zero
    ``pmask`` — every candidate is masked invalid and the junk outputs are
    discarded), which populates the same process-wide cache the serving
    dispatches hit.  Dispatch counters are NOT bumped — ``builds`` counts
    the compile as usual.  Returns the number of NEW executables built
    (0 when the key was already warm)."""
    B = bucket_batch(max(batch, 1))
    n_pad = bucket_points(n_pad)
    blk = _round_sil_block(n_pad, sil_block)
    shards = _effective_shards(B, data_shards)
    before = ENGINE_STATS["builds"]
    fn = _sweep_fn(B, n_pad, d, k_max, iters, use_pallas, blk, shards)
    shape = ((B, n_pad, d), (B, n_pad), (B, k_max), (B, n_pad))
    if B == 1:
        shape = tuple(s[1:] for s in shape)
    args = (jnp.zeros(shape[0], jnp.float32), jnp.zeros(shape[1], jnp.float32),
            jnp.zeros(shape[2], jnp.int32), jnp.zeros(shape[3], jnp.float32))
    if shards > 1:
        args = _shard_args(args, shards)
    jax.block_until_ready(fn(*args))
    if init == "device":
        # the dominant serving case (n > k_max) resolves k_up == k_max
        k_up = min(k_max, n_pad - 1)
        pm = np.zeros(n_pad, np.float32)
        pm[0] = 1.0  # one live point keeps the categorical logits finite
        jax.block_until_ready(
            _device_init_padded(jnp.zeros((n_pad, d), jnp.float32),
                                jnp.asarray(pm), 0, k_up))
    return ENGINE_STATS["builds"] - before


def engine_stats() -> dict:
    """Snapshot of the swept-engine counters (builds = compiles)."""
    return dict(ENGINE_STATS, cache_entries=len(_ENGINE_CACHE))


def reset_engine_stats() -> None:
    ENGINE_STATS["builds"] = 0
    ENGINE_STATS["dispatches"] = 0


def _finish_one(labels_all, sil, ok, n, ks, sil_floor, tie_tol):
    """Host-side selection over the swept scores — mirrors the sequential
    path's rule exactly (shared `_choose_k`)."""
    scores = {int(ks[i]): float(sil[i]) for i in range(len(ks)) if ok[i]}
    if not scores:
        return np.zeros(n, int), {"k": 1, "sil": 0.0, "mode": "degenerate",
                                  "engine": "sweep"}
    chosen, best = _choose_k(scores, sil_floor, tie_tol)
    if chosen is None:
        return np.zeros(n, int), {"k": 1, "sil": best, "mode": "weak->K=1",
                                  "engine": "sweep"}
    _, lab = np.unique(labels_all[chosen - 2][:n], return_inverse=True)
    return lab, {
        "k": int(lab.max()) + 1, "sil": scores[chosen], "mode": "silhouette",
        "scores": scores, "engine": "sweep",
    }


def sweep_cluster_stack(
    xs: list,
    k_max: int = 48,
    seed: int = 0,
    sil_floor: float = 0.20,
    tie_tol: float = 0.02,
    tiny_n: int = 4,
    sil_cap: int = 1200,
    iters: int = 50,
    use_pallas: bool = False,
    init: str = "host",
    sil_block: int = 512,
    data_shards: int = 1,
):
    """Plan MANY programs per dispatch: embeddings are padded to a shared
    power-of-two points bucket, stacked on a leading program axis, and every
    candidate K of every program is evaluated in ONE vmapped executable.
    Tiny/trivial programs take the host fallback (same as sequential).

    Returns a list of (labels, info) aligned with `xs`.  `seed` may be an
    int (shared) or a per-program sequence.  kmeans++ seeds (host numpy or
    `init="device"` fold-in draws) are always taken at each program's OWN
    points bucket, so a program's result is independent of which batch it
    rides in.

    ``data_shards > 1`` commits the stacked program axis to a 1-D device
    mesh (`_effective_shards` resolves the width that divides the pow2
    batch bucket), so ONE dispatch serves N_devices x the programs of a
    single-device dispatch.  Programs are row-independent — the sharded
    sweep is collective-free and its labels are bit-identical to the
    replicated dispatch.
    """
    xs = [np.asarray(x, np.float32) for x in xs]
    seeds = ([int(seed)] * len(xs) if np.isscalar(seed)
             else [int(s) for s in seed])
    out: list = [None] * len(xs)
    todo: list[int] = []
    sil_idxs: dict[int, np.ndarray] = {}
    for i, x in enumerate(xs):
        done, sil_idx = _host_preamble(x, seeds[i], tiny_n, sil_cap)
        if done is not None:
            out[i] = done
        elif x.ndim != 2 or x.shape[1] == 0:
            # featureless embeddings (d == 0): every point is identical, so
            # this is the degenerate K=1 collapse the sequential path also
            # reaches — decided on the HOST, a zero-width matrix is never
            # worth a device trace
            out[i] = (np.zeros(len(x), int),
                      {"k": 1, "sil": 0.0, "mode": "degenerate",
                       "engine": "sweep"})
        else:
            todo.append(i)
            sil_idxs[i] = sil_idx
    if not todo:
        return out

    n_pad = bucket_points(max(len(xs[i]) for i in todo))
    d = xs[todo[0]].shape[1]
    blk = _round_sil_block(n_pad, sil_block)
    # the batch axis is pow2-padded too (all-zero pmask rows are inert and
    # host-discarded), so odd chunk/tail sizes share an executable instead
    # of compiling one per distinct B
    B = bucket_batch(len(todo))
    xb = np.zeros((B, n_pad, d), np.float32)
    pmask = np.zeros((B, n_pad), np.float32)
    silm = np.zeros((B, n_pad), np.float32)
    init_idx = np.zeros((B, k_max), np.int32)
    for row, i in enumerate(todo):
        x = xs[i]
        n = len(x)
        xb[row, :n] = x
        pmask[row, :n] = 1.0
        sil_idx = sil_idxs[i]
        if sil_idx is None:
            silm[row, :n] = 1.0
        else:
            silm[row, sil_idx] = 1.0
        k_up = min(k_max, n - 1)
        if init == "device":
            init_idx[row, :k_up] = device_init_indices(x, seeds[i], k_up)
        else:
            init_idx[row, :k_up] = _kmeanspp_init(x, k_up, seeds[i])

    shards = _effective_shards(B, data_shards)
    fn = _sweep_fn(B, n_pad, d, k_max, iters, use_pallas, blk, shards)
    ENGINE_STATS["dispatches"] += 1
    if shards > 1:
        args = _shard_args((xb, pmask, init_idx, silm), shards)
    else:
        args = (jnp.asarray(xb), jnp.asarray(pmask), jnp.asarray(init_idx),
                jnp.asarray(silm))
    if B > 1:
        labels_all, sil, ok = fn(*args)
    else:
        labels_all, sil, ok = (jnp.expand_dims(r, 0) for r in
                               fn(*(a[0] for a in args)))
    labels_all = np.asarray(labels_all)
    sil = np.asarray(sil)
    ok = np.asarray(ok)
    ks = list(range(2, k_max + 1))
    for row, i in enumerate(todo):
        out[i] = _finish_one(labels_all[row], sil[row], ok[row], len(xs[i]),
                             ks, sil_floor, tie_tol)
    return out


def select_k_and_cluster_swept(x: np.ndarray, **kw):
    """Single-program front door for the compiled K-sweep; identical
    signature/semantics to :func:`select_k_and_cluster` (plus `init` and
    `sil_block`), identical labels/K on the parity suite."""
    return sweep_cluster_stack([np.asarray(x, np.float32)], **kw)[0]
