"""Packed, bucketed graph batching (jraph-style) for the RGCN stack.

Dense `pad_batch` pads every graph in a batch to the batch-wide max
nodes/edges/warps, so one large kernel inflates the cost of every small one
and every new (N, E, W) combination triggers a fresh jit compile.  The packed
representation concatenates all graphs of a batch into ONE flat node array
and ONE flat edge array:

  node axis (P,): node_type / token / pc_norm / vstats / node_mask
                  graph_id  — segment id of the owning graph
                  warp_seg  — GLOBAL warp segment id (graph-offset warp ids)
  edge axis (Q,): edge_src / edge_dst (node-offset-shifted into the flat
                  node axis, sorted by edge_dst for the blocked SpMM kernel),
                  edge_type / edge_mask / edge_graph
                  edge_norm — hoisted per-edge degree normalizer
                  1/|N_r(dst_e)| (schema v2; h-independent, so computed once
                  here instead of per RGCN layer per step — DESIGN.md §12)
  warp axis (W,): warp_graph — graph id per warp segment (warp validity
                  is derived in the readout from per-warp node counts)
  graph axis (G,): graph_mask, trunc_nodes / trunc_edges accounting

Each axis is padded up to a small set of size BUCKETS (powers of two above a
floor), so the number of distinct jit-compiled shapes is bounded by the
bucket count instead of the dataset's shape diversity.  Padding rows carry
mask 0 and index 0; every consumer is masked, so segment-sums over padding
contribute nothing.

`unpack` is index bookkeeping only: graph g owns rows [node_off[g],
node_off[g] + n_nodes[g]) of the flat node axis, and row g of any per-graph
output (e.g. the (G, 256) kernel embeddings).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.core.graphs import NUM_RELATIONS, KernelGraph

#: packed-batch dict schema version.  v2 added the precomputed ``edge_norm``
#: field; every consumer falls back to in-trace recomputation when the key
#: is absent (core/rgcn._rgcn_layer_packed), so v1 batches stay valid.
PACK_SCHEMA = 2

# Bucket floors: the smallest padded size per axis.  Everything above the
# floor rounds up to the next power of two, so #buckets per axis is
# log2(max/floor) + 1.
NODE_FLOOR = 256
EDGE_FLOOR = 512
WARP_FLOOR = 4

# Default micro-batch budgets for the streaming embed path.  MAX_NODES also
# bounds the flat Pallas kernel's VMEM residency: h (P, 128) f32 + the
# (P, nb*128) accumulator must fit on-chip (P = 4096 -> ~6 MB).
MAX_NODES_PER_MICROBATCH = 4096
MAX_EDGES_PER_MICROBATCH = 8192
MAX_GRAPHS_PER_MICROBATCH = 64


def bucket_size(n: int, floor: int) -> int:
    """Round n up to the next power-of-two bucket >= floor."""
    b = int(floor)
    while b < n:
        b *= 2
    return b


def bucket_key(batch) -> tuple[int, int, int, int]:
    """(P, Q, W, G) static shape key — jit retraces are bounded by the
    number of distinct keys."""
    return (
        batch["node_mask"].shape[0],
        batch["edge_mask"].shape[0],
        batch["warp_graph"].shape[0],
        batch["graph_mask"].shape[0],
    )


@dataclass(frozen=True)
class PackMeta:
    """Host-side bookkeeping to unpack per-graph results.

    node_off/warp_off slice the flat node/warp axes per graph.  The flat
    EDGE arrays are dst-sorted across the whole batch, so graph g's edges
    are NOT contiguous — select them with `batch['edge_graph'] == g`;
    `edge_off` only gives per-graph edge COUNTS (edge_off[g+1]-edge_off[g]).
    """
    n_graphs: int
    node_off: np.ndarray   # (G+1,) node offsets into the flat axis
    edge_off: np.ndarray   # (G+1,) cumulative per-graph edge counts (pre-sort)
    warp_off: np.ndarray   # (G+1,)
    trunc_nodes: np.ndarray  # (G,) nodes dropped by per-graph caps
    trunc_edges: np.ndarray  # (G,)


def pack_graphs(
    graphs: list[KernelGraph],
    *,
    bucket: bool = True,
    pad_graphs_to: int | None = None,
    max_nodes_per_graph: int | None = None,
    max_edges_per_graph: int | None = None,
):
    """Pack a list of KernelGraphs into one flat (numpy) batch.

    Returns (batch dict, PackMeta).  With `bucket`, the node/edge/warp axes
    are padded to power-of-two buckets; the graph axis is left exact unless
    `pad_graphs_to` is given (training keeps G == batch_size so the InfoNCE
    logits never see padding graphs; embed pads G per micro-batch bucket).
    """
    G = len(graphs)
    assert G > 0, "pack_graphs needs at least one graph"

    n_nodes = np.empty(G, np.int64)
    n_edges = np.empty(G, np.int64)
    n_warps = np.empty(G, np.int64)
    trunc_n = np.zeros(G, np.int64)
    trunc_e = np.zeros(G, np.int64)
    parts = []
    for gi, g in enumerate(graphs):
        n, e = g.n_nodes, g.n_edges
        if max_nodes_per_graph is not None and n > max_nodes_per_graph:
            trunc_n[gi] = n - max_nodes_per_graph
            n = max_nodes_per_graph
        src, dst, et = g.edge_src, g.edge_dst, g.edge_type
        if n < g.n_nodes:  # drop edges touching truncated nodes
            keep = (src < n) & (dst < n)
            src, dst, et = src[keep], dst[keep], et[keep]
            trunc_e[gi] += g.n_edges - len(src)
            e = len(src)
        if max_edges_per_graph is not None and e > max_edges_per_graph:
            trunc_e[gi] += e - max_edges_per_graph
            src = src[:max_edges_per_graph]
            dst = dst[:max_edges_per_graph]
            et = et[:max_edges_per_graph]
            e = max_edges_per_graph
        n_nodes[gi], n_edges[gi], n_warps[gi] = n, e, g.n_warps
        parts.append((n, src, dst, et))

    node_off = np.concatenate([[0], np.cumsum(n_nodes)])
    edge_off = np.concatenate([[0], np.cumsum(n_edges)])
    warp_off = np.concatenate([[0], np.cumsum(n_warps)])
    P_used, Q_used, W_used = int(node_off[-1]), int(edge_off[-1]), int(warp_off[-1])

    if bucket:
        P = bucket_size(P_used, NODE_FLOOR)
        Q = bucket_size(max(Q_used, 1), EDGE_FLOOR)
        W = bucket_size(max(W_used, 1), WARP_FLOOR)
    else:
        P, Q, W = P_used, max(Q_used, 1), max(W_used, 1)
    Gp = pad_graphs_to or G
    assert Gp >= G, (Gp, G)

    batch = {
        "node_type": np.zeros(P, np.int32),
        "token": np.zeros(P, np.int32),
        "pc_norm": np.zeros(P, np.float32),
        "vstats": np.zeros((P, 8), np.float32),
        "graph_id": np.zeros(P, np.int32),
        "warp_seg": np.zeros(P, np.int32),
        "node_mask": np.zeros(P, np.float32),
        "edge_src": np.zeros(Q, np.int32),
        "edge_dst": np.zeros(Q, np.int32),
        "edge_type": np.zeros(Q, np.int32),
        "edge_graph": np.zeros(Q, np.int32),
        "edge_mask": np.zeros(Q, np.float32),
        "warp_graph": np.zeros(W, np.int32),
        "graph_mask": np.zeros(Gp, np.float32),
        "trunc_nodes": np.zeros(Gp, np.int32),
        "trunc_edges": np.zeros(Gp, np.int32),
    }

    for gi, g in enumerate(graphs):
        n, src, dst, et = parts[gi]
        no, eo, wo = int(node_off[gi]), int(edge_off[gi]), int(warp_off[gi])
        sl = slice(no, no + n)
        batch["node_type"][sl] = g.node_type[:n]
        batch["token"][sl] = g.token[:n]
        batch["pc_norm"][sl] = g.pc_norm[:n]
        batch["vstats"][sl] = g.vstats[:n]
        batch["graph_id"][sl] = gi
        batch["warp_seg"][sl] = g.warp_id[:n].astype(np.int32) + wo
        batch["node_mask"][sl] = 1.0
        e = len(src)
        el = slice(eo, eo + e)
        batch["edge_src"][el] = src.astype(np.int32) + no
        batch["edge_dst"][el] = dst.astype(np.int32) + no
        batch["edge_type"][el] = et
        batch["edge_graph"][el] = gi
        batch["edge_mask"][el] = 1.0
        wl = slice(wo, wo + g.n_warps)
        batch["warp_graph"][wl] = gi
    batch["graph_mask"][:G] = 1.0
    batch["trunc_nodes"][:G] = trunc_n
    batch["trunc_edges"][:G] = trunc_e

    # sort the used prefix of the edge list by destination: the blocked SpMM
    # kernel streams edge blocks whose dst indices are then near-contiguous,
    # and the accumulation order becomes deterministic
    order = np.argsort(batch["edge_dst"][:Q_used], kind="stable")
    for k in ("edge_src", "edge_dst", "edge_type", "edge_graph", "edge_mask"):
        batch[k][:Q_used] = batch[k][:Q_used][order]

    # hoisted degree normalizer 1/|N_r(v)| (schema v2, DESIGN.md §12):
    # structure-only, so it is derived ONCE here instead of per layer per
    # step in-trace.  Bit-identical to core/rgcn.edge_norm_packed (integer-
    # valued mask sums + the same 1/max IEEE division); padding rows (mask 0)
    # get the same formula so the jnp twin matches on every element.
    key = batch["edge_dst"].astype(np.int64) * NUM_RELATIONS + batch["edge_type"]
    deg = np.zeros(P * NUM_RELATIONS, np.float32)
    np.add.at(deg, key, batch["edge_mask"])
    batch["edge_norm"] = np.float32(1.0) / np.maximum(deg[key], np.float32(1.0))

    meta = PackMeta(
        n_graphs=G, node_off=node_off, edge_off=edge_off, warp_off=warp_off,
        trunc_nodes=trunc_n, trunc_edges=trunc_e,
    )
    return batch, meta


@dataclass(frozen=True)
class EpochSegment:
    """A maximal run of CONSECUTIVE training steps whose packed batches share
    one bucket key, with the per-step batches stacked along a new leading
    step axis — ready to become `jax.lax.scan` xs after one device upload.
    Step order is preserved exactly (the optimizer state evolves
    sequentially), so segments never reorder steps across bucket flips."""
    start: int                     # first step (absolute index in the epoch)
    stop: int                      # one past the last step
    key: tuple                     # bucket_key shared by every step
    batches: dict                  # field -> np.ndarray of shape (stop-start, ...)

    def __len__(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class EpochPlan:
    """Host-side epoch schedule built by `plan_epoch` (DESIGN.md §4): every
    step's batch packed up front, grouped into same-bucket segments.  The
    trainer stages each segment to the device ONCE and drives it with a
    compiled multi-step scan instead of a per-step pack -> upload -> sync
    round-trip."""
    n_steps: int
    selections: np.ndarray         # (n_steps, batch_size) graph indices
    segments: tuple                # EpochSegments covering [0, n_steps)
    bucket_keys: tuple             # sorted distinct bucket keys
    trunc_nodes: int               # total nodes truncated by per-graph caps


def plan_epoch(
    graphs: list[KernelGraph],
    selections: np.ndarray,
    *,
    max_nodes_per_graph: int | None = None,
    max_edges_per_graph: int | None = None,
) -> EpochPlan:
    """Pack every step of an epoch and group consecutive same-bucket steps.

    `selections` is the (steps, batch_size) matrix of graph indices (one row
    per training step, drawn ahead of time so the schedule is deterministic
    given the seed — the resume protocol replays it exactly).  Bucketing
    keeps the number of distinct stacked shapes — and hence scan compiles —
    bounded by the bucket count, not the step count.
    """
    selections = np.asarray(selections)
    steps = []
    trunc_total = 0
    for sel in selections:
        packed, meta = pack_graphs(
            [graphs[i] for i in sel],
            max_nodes_per_graph=max_nodes_per_graph,
            max_edges_per_graph=max_edges_per_graph,
        )
        trunc_total += int(meta.trunc_nodes.sum())
        steps.append((bucket_key(packed), packed))

    segments: list[EpochSegment] = []
    start = 0
    while start < len(steps):
        key = steps[start][0]
        stop = start + 1
        while stop < len(steps) and steps[stop][0] == key:
            stop += 1
        stacked = {
            f: np.stack([steps[t][1][f] for t in range(start, stop)])
            for f in steps[start][1]
        }
        segments.append(EpochSegment(start=start, stop=stop, key=key,
                                     batches=stacked))
        start = stop
    return EpochPlan(
        n_steps=len(steps), selections=selections, segments=tuple(segments),
        bucket_keys=tuple(sorted({k for k, _ in steps})),
        trunc_nodes=trunc_total,
    )


def plan_microbatches(
    graphs: list[KernelGraph],
    *,
    max_nodes: int = MAX_NODES_PER_MICROBATCH,
    max_edges: int = MAX_EDGES_PER_MICROBATCH,
    max_graphs: int = MAX_GRAPHS_PER_MICROBATCH,
) -> list[list[int]]:
    """Greedy size-sorted binning of graph indices into micro-batches whose
    packed totals respect the node/edge/graph budgets.  Sorting by size keeps
    same-bucket graphs together, minimizing distinct bucket keys."""
    order = sorted(
        range(len(graphs)), key=lambda i: (graphs[i].n_nodes, graphs[i].n_edges)
    )
    bins: list[list[int]] = []
    cur: list[int] = []
    cn = ce = 0
    for i in order:
        g = graphs[i]
        gn = min(g.n_nodes, max_nodes)
        ge = min(g.n_edges, max_edges)
        if cur and (cn + gn > max_nodes or ce + ge > max_edges
                    or len(cur) >= max_graphs):
            bins.append(cur)
            cur, cn, ce = [], 0, 0
        cur.append(i)
        cn += gn
        ce += ge
    if cur:
        bins.append(cur)
    return bins


def stream_bins(
    items,
    size_fn,
    *,
    max_nodes: int = MAX_NODES_PER_MICROBATCH,
    max_edges: int = MAX_EDGES_PER_MICROBATCH,
    max_graphs: int = MAX_GRAPHS_PER_MICROBATCH,
    stats: dict | None = None,
):
    """Greedy micro-batch binning over an ITERATOR of items.

    The streaming counterpart of `plan_microbatches`: items arrive one at a
    time (no global size-sort is possible), are buffered until the packed
    budgets would overflow, and each full bin is yielded before the next item
    is buffered — so at most ONE bin of items is ever resident.  `size_fn`
    maps an item to (n_nodes, n_edges); per-item sizes are clamped to the
    budgets (oversized graphs are truncated downstream by `pack_graphs`).

    When `stats` is given it accumulates: peak_resident_graphs /
    peak_resident_nodes / peak_resident_edges — the TRUE (unclamped) sizes
    of what is buffered, so a single oversized graph shows up honestly even
    though the budget decision clamps it (truncation to the budget happens
    downstream in `pack_graphs`) — and bins.
    """
    buf: list = []
    bn = be = 0          # budget-clamped running sums (flush decision)
    rn = re_ = 0         # true resident sums (stats)
    peak_g = peak_n = peak_e = bins = 0
    for item in items:
        n, e = size_fn(item)
        gn, ge = min(int(n), max_nodes), min(int(e), max_edges)
        if buf and (bn + gn > max_nodes or be + ge > max_edges
                    or len(buf) >= max_graphs):
            bins += 1
            yield buf
            buf, bn, be, rn, re_ = [], 0, 0, 0, 0
        buf.append(item)
        bn += gn
        be += ge
        rn += int(n)
        re_ += int(e)
        peak_g = max(peak_g, len(buf))
        peak_n = max(peak_n, rn)
        peak_e = max(peak_e, re_)
    if buf:
        bins += 1
        yield buf
    if stats is not None:
        stats.update(
            peak_resident_graphs=peak_g, peak_resident_nodes=peak_n,
            peak_resident_edges=peak_e, bins=bins,
        )


def graph_content_hash(g: KernelGraph) -> str:
    """Content hash of a kernel graph — identical repeated invocations hash
    equal, so the embedding cache encodes each distinct kernel once."""
    h = hashlib.blake2b(digest_size=16)
    for a in (g.node_type, g.token, g.pc_norm, g.vstats, g.warp_id,
              g.edge_src, g.edge_dst, g.edge_type):
        h.update(np.ascontiguousarray(a).tobytes())
        h.update(str(a.shape).encode())
    h.update(str(g.n_warps).encode())
    return h.hexdigest()
