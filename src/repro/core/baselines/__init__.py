from repro.core.baselines.pka import pka_plan
from repro.core.baselines.sieve import sieve_plan
from repro.core.baselines.stem_root import stem_root_plan
