"""STEM+ROOT (2025) baseline.

Name-keyed grouping like Sieve, but the per-name signature is the *profiled
execution-time distribution*: fine-grained hierarchical clustering (1-d
single-link with a relative gap threshold), then ROOT's statistical error
model picks MULTIPLE representatives per cluster:

    n_c = ceil((z * cov_c / eps)^2),  z = 1.96, eps = 0.25 (paper setup)

spread evenly over the cluster.  Consistently low error, at the cost of a
much larger representative set (the paper's 56.57x vs 258.94x speedup gap).
"""

from __future__ import annotations

import numpy as np

from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program
from repro.sim.hardware import PLATFORMS
from repro.sim.timing import simulate_kernel

Z_SCORE = 1.96
GAP_REL = 0.15  # relative gap threshold for splitting time clusters


def stem_root_plan(program: Program, platform="P1", eps=0.25) -> SamplingPlan:
    hw = PLATFORMS[platform]
    times = np.array(
        [simulate_kernel(k.stats(platform), hw).time_s for k in program.kernels]
    )
    names = [k.name for k in program.kernels]
    seqs = np.array([k.seq for k in program.kernels])

    labels = np.full(len(names), -1, int)
    reps: dict[int, list[int]] = {}
    next_label = 0
    for name in sorted(set(names)):
        idx = np.array([i for i, n in enumerate(names) if n == name])
        order = idx[np.argsort(times[idx])]
        t = times[order]
        # STEM: hierarchical 1-d split at large relative gaps
        clusters = [[order[0]]]
        for j in range(1, len(order)):
            prev_t = times[clusters[-1][-1]]
            if prev_t > 0 and (t[j] - prev_t) / max(prev_t, 1e-12) > GAP_REL:
                clusters.append([])
            clusters[-1].append(order[j])
        for members in clusters:
            members = np.asarray(members)
            labels[members] = next_label
            mt = times[members]
            cov = mt.std() / max(mt.mean(), 1e-12)
            # ROOT: sample size from the statistical error model
            n_rep = int(np.ceil((Z_SCORE * cov / eps) ** 2))
            n_rep = int(np.clip(n_rep, 1, len(members)))
            # spread representatives evenly across the sorted cluster
            pos = np.linspace(0, len(members) - 1, n_rep).round().astype(int)
            chosen = members[np.argsort(times[members])][pos]
            reps[next_label] = sorted(int(c) for c in set(chosen.tolist()))
            next_label += 1
    return SamplingPlan(labels=labels, reps=reps, method="STEM+ROOT")
