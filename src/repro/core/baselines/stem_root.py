"""STEM+ROOT (2025) baseline.

Name-keyed grouping like Sieve, but the per-name signature is the *profiled
execution-time distribution*: fine-grained hierarchical clustering (1-d
single-link with a relative gap threshold), then ROOT's statistical error
model picks MULTIPLE representatives per cluster:

    n_c = ceil((z * cov_c / eps)^2),  z = 1.96, eps = 0.25 (paper setup)

spread evenly over the cluster.  Consistently low error, at the cost of a
much larger representative set (the paper's 56.57x vs 258.94x speedup gap).

``stem_root_times``/``stem_root_partition`` produce the profile and the
(labels, multi-rep selector) pair; representative selection goes through
the shared ``repro.sampling.plan_from_labels``.  ``stem_root_plan`` is the
legacy free-function entry point — prefer
``repro.sampling.get_method("stem_root")``.
"""

from __future__ import annotations

import numpy as np

from repro.sampling.base import plan_from_labels
from repro.sim.hardware import PLATFORMS
from repro.sim.simulate import SamplingPlan
from repro.sim.timing import simulate_batch, stack_stats
from repro.tracing.programs import Program

Z_SCORE = 1.96
GAP_REL = 0.15  # relative gap threshold for splitting time clusters


def stem_root_times(program: Program, platform: str = "P1") -> np.ndarray:
    """Profiled per-invocation execution times (the STEM signature),
    timed in one vectorized `simulate_batch` pass."""
    hw = PLATFORMS[platform]
    stats = [k.stats(platform) for k in program.kernels]
    return np.asarray(simulate_batch(stack_stats(stats), hw).time_s)


def stem_root_partition(times: np.ndarray, names: list, eps: float = 0.25):
    """STEM clustering + ROOT's representative policy.

    Returns ``(labels, rep_selector)`` where ``rep_selector(cluster,
    members)`` implements ROOT's error-model sample size, spread evenly over
    the cluster's sorted times — plugged into ``plan_from_labels``.
    """
    times = np.asarray(times)
    labels = np.full(len(names), -1, int)
    next_label = 0
    for name in sorted(set(names)):
        idx = np.array([i for i, n in enumerate(names) if n == name])
        order = idx[np.argsort(times[idx])]
        t = times[order]
        # STEM: hierarchical 1-d split at large relative gaps
        clusters = [[order[0]]]
        for j in range(1, len(order)):
            prev_t = times[clusters[-1][-1]]
            if prev_t > 0 and (t[j] - prev_t) / max(prev_t, 1e-12) > GAP_REL:
                clusters.append([])
            clusters[-1].append(order[j])
        for members in clusters:
            labels[np.asarray(members)] = next_label
            next_label += 1

    def rep_selector(cluster: int, members: np.ndarray) -> list[int]:
        mt = times[members]
        cov = mt.std() / max(mt.mean(), 1e-12)
        # ROOT: sample size from the statistical error model
        n_rep = int(np.clip(np.ceil((Z_SCORE * cov / eps) ** 2), 1, len(members)))
        # spread representatives evenly across the sorted cluster
        pos = np.linspace(0, len(members) - 1, n_rep).round().astype(int)
        return members[np.argsort(mt)][pos].tolist()

    return labels, rep_selector


def stem_root_plan(program: Program, platform: str = "P1",
                   eps: float = 0.25) -> SamplingPlan:
    """Deprecated shim — use ``repro.sampling.get_method("stem_root")``."""
    times = stem_root_times(program, platform)
    names = [k.name for k in program.kernels]
    seqs = np.array([k.seq for k in program.kernels])
    labels, rep_selector = stem_root_partition(times, names, eps)
    return plan_from_labels(labels, seqs, "STEM+ROOT",
                            rep_selector=rep_selector)
