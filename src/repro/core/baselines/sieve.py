"""Sieve (ISPASS'23) baseline.

Strict kernel-name partitioning, then per-name stratification on dynamic
instruction count when its coefficient of variation (CoV) is high; the
representative is the first kernel with the maximum CTA count in each
stratum, weighted by stratum size.

Name-keyed grouping is Sieve's crippling constraint on workloads whose
invocations carry distinct names (nw / lu / 3mm): every kernel becomes its
own cluster and no reduction is possible.
"""

from __future__ import annotations

import numpy as np

from repro.core.sampler import SamplingPlan
from repro.tracing.programs import Program

COV_THRESHOLD = 0.10


def sieve_plan(program: Program, platform="P1") -> SamplingPlan:
    names = [k.name for k in program.kernels]
    instrs = np.array([k.stats(platform).warp_instructions for k in program.kernels])
    ctas = np.array([k.stats(platform).ctas for k in program.kernels])
    seqs = np.array([k.seq for k in program.kernels])

    labels = np.full(len(names), -1, int)
    next_label = 0
    reps: dict[int, list[int]] = {}
    for name in sorted(set(names)):
        idx = np.array([i for i, n in enumerate(names) if n == name])
        vals = instrs[idx]

        # recursive CoV stratification: split at the largest relative gap
        # until every stratum's instruction-count CoV is below threshold
        # (keeps near-identical counts together regardless of group size).
        def stratify(members):
            v = instrs[members]
            if len(members) < 2 or v.std() / max(v.mean(), 1e-9) <= COV_THRESHOLD:
                return [members]
            order = members[np.argsort(instrs[members])]
            sv = instrs[order]
            rel_gap = (sv[1:] - sv[:-1]) / np.maximum(sv[:-1], 1e-9)
            cut = int(np.argmax(rel_gap)) + 1
            return stratify(order[:cut]) + stratify(order[cut:])

        strata = stratify(idx)
        for stratum in strata:
            labels[stratum] = next_label
            # first kernel with the maximum CTA count (original Sieve rule)
            c = ctas[stratum]
            cand = stratum[c == c.max()]
            rep = cand[np.argmin(seqs[cand])]
            reps[next_label] = [int(rep)]
            next_label += 1
    return SamplingPlan(labels=labels, reps=reps, method="Sieve")
