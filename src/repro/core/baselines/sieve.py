"""Sieve (ISPASS'23) baseline.

Strict kernel-name partitioning, then per-name stratification on dynamic
instruction count when its coefficient of variation (CoV) is high; the
representative is the first kernel with the maximum CTA count in each
stratum, weighted by stratum size.

Name-keyed grouping is Sieve's crippling constraint on workloads whose
invocations carry distinct names (nw / lu / 3mm): every kernel becomes its
own cluster and no reduction is possible.

``sieve_partition`` produces the (labels, CTA-priority) pair; representative
selection goes through the shared ``repro.sampling.plan_from_labels``.
``sieve_plan`` is the legacy free-function entry point — prefer
``repro.sampling.get_method("sieve")``.
"""

from __future__ import annotations

import numpy as np

from repro.sampling.base import plan_from_labels
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program

COV_THRESHOLD = 0.10


def sieve_partition(program: Program, platform: str = "P1"):
    """Name partition + recursive CoV stratification.

    Returns ``(labels, ctas)``: cluster labels per invocation and the CTA
    counts used as the representative priority (Sieve's "first kernel with
    the max CTA count" rule).
    """
    names = [k.name for k in program.kernels]
    instrs = np.array([k.stats(platform).warp_instructions for k in program.kernels])
    ctas = np.array([k.stats(platform).ctas for k in program.kernels])

    labels = np.full(len(names), -1, int)
    next_label = 0
    for name in sorted(set(names)):
        idx = np.array([i for i, n in enumerate(names) if n == name])

        # recursive CoV stratification: split at the largest relative gap
        # until every stratum's instruction-count CoV is below threshold
        # (keeps near-identical counts together regardless of group size).
        def stratify(members):
            v = instrs[members]
            if len(members) < 2 or v.std() / max(v.mean(), 1e-9) <= COV_THRESHOLD:
                return [members]
            order = members[np.argsort(instrs[members])]
            sv = instrs[order]
            rel_gap = (sv[1:] - sv[:-1]) / np.maximum(sv[:-1], 1e-9)
            cut = int(np.argmax(rel_gap)) + 1
            return stratify(order[:cut]) + stratify(order[cut:])

        for stratum in stratify(idx):
            labels[stratum] = next_label
            next_label += 1
    return labels, ctas


def sieve_plan(program: Program, platform: str = "P1") -> SamplingPlan:
    """Deprecated shim — use ``repro.sampling.get_method("sieve")``."""
    labels, ctas = sieve_partition(program, platform)
    seqs = np.array([k.seq for k in program.kernels])
    return plan_from_labels(labels, seqs, "Sieve", priority=ctas)
