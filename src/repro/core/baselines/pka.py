"""PKA (Principal Kernel Analysis, MICRO'21) baseline.

Twelve microarchitecture-independent profiling features per kernel
(instruction mix over 10 classes + log dynamic instruction count + log CTA
count), z-scored, K-Means with the same silhouette K-selection as
GCL-Sampler, representative = first invocation per cluster.

The feature set deliberately excludes working-set / access-pattern /
dependence structure — exactly the limited expressiveness the paper blames
for PKA's 20.9% average error: kernels with matching mixes but different
cache behavior or loop trip counts collapse into one cluster.

``pka_plan`` is the legacy free-function entry point — prefer
``repro.sampling.get_method("pka")``.
"""

from __future__ import annotations

import numpy as np

from repro.core.clustering import select_k_and_cluster
from repro.sampling.base import plan_from_labels
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import Program


def pka_features(program: Program, platform="P1") -> np.ndarray:
    feats = []
    for k in program.kernels:
        st = k.stats(platform)
        mix = st.instr_mix  # (10,)
        feats.append(
            np.concatenate([
                mix,
                [np.log1p(st.warp_instructions)],
                [st.divergence],
            ])
        )
    x = np.asarray(feats, np.float32)
    mu, sd = x.mean(0), x.std(0)
    return (x - mu) / np.maximum(sd, 1e-6)


def pka_plan(program: Program, k_max=48, seed=0) -> SamplingPlan:
    """Deprecated shim — use ``repro.sampling.get_method("pka")``."""
    x = pka_features(program)
    labels, info = select_k_and_cluster(x, k_max=k_max, seed=seed)
    seqs = np.array([k.seq for k in program.kernels])
    return plan_from_labels(labels, seqs, "PKA", extra=info)
