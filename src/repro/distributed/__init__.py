from repro.distributed.sharding import (
    MeshRules,
    constrain,
    constrain_batch,
    set_mesh_rules,
    current_rules,
    spec_for,
    param_shardings,
)
