"""Logical-axis sharding policy engine (t5x-flavoured, divisibility-aware).

Every parameter and activation carries a tuple of *logical* dim names
(e.g. ``('embed', 'heads', 'head_dim')``).  A single policy maps logical
names to mesh axes:

- ``batch``       -> the batch axes (``('data',)`` or ``('pod','data')``)
- tensor-model parallelism: the FIRST name of the preference list present in
  the tuple whose dim can be sharded over the ``model`` axis gets it
  (uneven sharding allowed when dim >= axis size — GSPMD pads; dims smaller
  than the axis are skipped)
- FSDP (params only): the first *remaining* name whose dim is shardable gets
  the batch axes (ZeRO-3: params + optimizer moments sharded over DP)

The same engine drives parameter `in_shardings` and in-model
``with_sharding_constraint`` calls, so the whole policy lives in one place
and per-arch divisibility quirks (24 heads, 8 experts, vocab 49155, MQA)
resolve automatically with documented fallbacks.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Preference order for placing the tensor-parallel 'model' axis.
PARAM_MODEL_PREF = (
    "vocab", "ffn", "heads", "d_inner", "ssm_heads", "attn_hidden", "embed",
)
ACT_MODEL_PREF = (
    "vocab", "ffn", "heads", "d_inner", "ssm_heads", "cache_seq",
)
# Preference order for placing the FSDP axes on parameters.
FSDP_PREF = (
    "embed", "ffn", "vocab", "d_inner", "heads", "attn_hidden",
    "kv_hidden", "experts", "blocks",
)


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    batch_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp: bool = True
    # perf-iteration knobs (see EXPERIMENTS.md §Perf)
    act_model_pref: tuple[str, ...] = ACT_MODEL_PREF
    param_model_pref: tuple[str, ...] = PARAM_MODEL_PREF
    fsdp_pref: tuple[str, ...] = FSDP_PREF
    seq_shard: bool = False  # sequence parallelism on residual activations

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def fsdp_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


_local = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def set_mesh_rules(rules: Optional[MeshRules]):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def _shardable(dim: int, n: int, *, exact: bool) -> bool:
    """Can a dim of size `dim` be sharded n-ways?  pjit ARGUMENT shardings
    must divide exactly; with_sharding_constraint on activations tolerates
    uneven dims (GSPMD pads)."""
    if exact:
        return dim % n == 0
    return dim >= n


def spec_for(
    names: Sequence[Optional[str]],
    shape: Sequence[int],
    *,
    rules: MeshRules,
    is_param: bool,
) -> P:
    assert len(names) == len(shape), (names, shape)
    assign: list = [None] * len(names)

    # 1) batch axes on 'batch' (skip when the batch is too small to shard,
    # e.g. long_500k's global_batch=1 — it stays replicated over data)
    bsize = rules.fsdp_size
    for i, n in enumerate(names):
        if n == "batch" and shape[i] % bsize == 0:
            assign[i] = rules.batch_axes

    # 2) tensor-parallel 'model' placement
    pref = rules.param_model_pref if is_param else rules.act_model_pref
    msize = rules.model_size
    for cand in pref:
        placed = False
        for i, n in enumerate(names):
            if n == cand and assign[i] is None and _shardable(
                shape[i], msize, exact=is_param
            ):
                assign[i] = (rules.model_axis,)
                placed = True
                break
        if placed:
            break

    # 2b) optional sequence parallelism on activations
    if not is_param and rules.seq_shard:
        if not any(a == (rules.model_axis,) for a in assign):
            for i, n in enumerate(names):
                if n == "seq" and assign[i] is None and _shardable(
                    shape[i], msize, exact=False
                ):
                    assign[i] = (rules.model_axis,)
                    break

    # 3) FSDP placement on params — only when the batch axes are still free:
    # a dim already carrying them via rule 1 (e.g. a param with a literal
    # 'batch' dim) must not be duplicated onto a second dim, since a
    # PartitionSpec may use each mesh axis at most once
    if is_param and rules.fsdp and not any(
        a is not None and set(a) & set(rules.batch_axes) for a in assign
    ):
        fsize = rules.fsdp_size
        for cand in rules.fsdp_pref:
            placed = False
            for i, n in enumerate(names):
                if (
                    n == cand
                    and assign[i] is None
                    and shape[i] % fsize == 0  # keep FSDP even (gather layout)
                ):
                    assign[i] = rules.batch_axes
                    placed = True
                    break
            if placed:
                break

    return P(*[a if a is None else (a[0] if len(a) == 1 else a) for a in assign])


def sharding_for(names, shape, *, rules: MeshRules, is_param: bool) -> NamedSharding:
    return NamedSharding(rules.mesh, spec_for(names, shape, rules=rules, is_param=is_param))


def constrain(x, *names, rules: Optional[MeshRules] = None):
    """with_sharding_constraint using the active MeshRules (no-op otherwise).
    `rules` overrides the thread-local context (used by the compiled training
    engine, whose traces are cached per MeshRules — see core/train.py)."""
    rules = rules or current_rules()
    if rules is None:
        return x
    spec = spec_for(names, x.shape, rules=rules, is_param=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


#: logical axis names for every field of a packed graph batch
#: (core/batching.py layout).  The flat node/edge/warp/graph axes all carry
#: the 'batch' logical name: packed graphs are data-parallel — bucket sizes
#: are powers of two, so the axes divide evenly over the batch mesh axes.
PACKED_BATCH_AXES: dict[str, tuple] = {
    "node_type": ("batch",),
    "token": ("batch",),
    "pc_norm": ("batch",),
    "vstats": ("batch", None),
    "graph_id": ("batch",),
    "warp_seg": ("batch",),
    "node_mask": ("batch",),
    "edge_src": ("batch",),
    "edge_dst": ("batch",),
    "edge_type": ("batch",),
    "edge_graph": ("batch",),
    "edge_mask": ("batch",),
    "edge_norm": ("batch",),
    "warp_graph": ("batch",),
    "graph_mask": ("batch",),
    "trunc_nodes": ("batch",),
    "trunc_edges": ("batch",),
}


def constrain_batch(batch: dict, rules: Optional[MeshRules] = None) -> dict:
    """Constrain every packed-batch field to its PACKED_BATCH_AXES spec so
    the node/edge/graph axes stay data-parallel INSIDE a compiled scan step
    (GSPMD would otherwise be free to gather the whole epoch slice onto one
    shard).  No-op without active/explicit MeshRules."""
    rules = rules or current_rules()
    if rules is None:
        return batch
    return {
        k: constrain(v, *PACKED_BATCH_AXES[k], rules=rules)
        if k in PACKED_BATCH_AXES else v
        for k, v in batch.items()
    }


def batch_put_spec(field: str, shape: Sequence[int], rules: MeshRules,
                   *, leading: int = 0) -> P:
    """PartitionSpec for host->device staging of one packed-batch field.

    The first ``leading`` dims (e.g. the scan-steps axis of a stacked
    segment) stay replicated; the remaining dims follow PACKED_BATCH_AXES.
    Pad-or-skip fallback: a 'batch' dim that does not divide the data-axis
    size (non-pow2 graph counts, tiny buckets) stays REPLICATED instead of
    producing an invalid argument sharding — pjit argument shardings must
    divide exactly (see `_shardable`), unlike in-trace constraints."""
    axes = PACKED_BATCH_AXES.get(field, ())
    bsize = rules.fsdp_size
    spec: list = [None] * leading
    for i, ax in enumerate(axes):
        dim = shape[leading + i] if leading + i < len(shape) else 0
        if ax == "batch" and bsize > 1 and dim % bsize == 0:
            spec.append(rules.batch_axes if len(rules.batch_axes) > 1
                        else rules.batch_axes[0])
        else:
            spec.append(None)
    return P(*spec)


def shard_batch_put(batch: dict, rules: Optional[MeshRules] = None,
                    *, leading: int = 0) -> dict:
    """Stage a packed batch (host numpy arrays) onto the mesh with its
    PACKED_BATCH_AXES shardings — the multi-device counterpart of the
    single-device ``jnp.asarray`` upload.  Each device receives only its
    own batch shard instead of a full replica, so host->device bytes stay
    constant as the mesh grows.  No-op (plain upload) without rules or on
    a 1-device data axis."""
    import jax.numpy as jnp

    if rules is None:
        rules = current_rules()
    if rules is None or rules.fsdp_size <= 1:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = batch_put_spec(k, tuple(v.shape), rules, leading=leading)
        out[k] = jax.device_put(v, NamedSharding(rules.mesh, spec))
    return out


def param_shardings(param_axes, abstract_params, rules: MeshRules):
    """Pytree of NamedShardings from an axes-metadata tree (same structure)."""

    def _one(axes, leaf):
        return sharding_for(axes, leaf.shape, rules=rules, is_param=True)

    return jax.tree_util.tree_map(
        _one, param_axes, abstract_params,
        is_leaf=lambda a: isinstance(a, tuple) and all(isinstance(x, (str, type(None))) for x in a),
    )
