"""Fault-tolerance utilities for long-running distributed training.

- Watchdog: straggler / hang detection.  Each step arms a timer sized to an
  SLO multiple of the trailing median step time; if a step exceeds it, the
  callback fires (log -> alert -> abort-and-restart-from-checkpoint, which at
  cluster scale evicts the straggling host).
- Heartbeat: periodic liveness file (what a cluster supervisor scrapes).
- retry: bounded-backoff wrapper for transient infrastructure failures
  (checkpoint I/O, data source hiccups).
"""

from __future__ import annotations

import json
import os
import statistics
import threading
import time
from typing import Callable, Optional


class DeviceLost(RuntimeError):
    """A mesh participant is gone or straggling past the SLO.

    Raised at a checkpoint boundary (never mid-step) by the scale-out
    drivers — the training engine's watchdog/fault hooks and the
    PlanEngine's sharded dispatch — so the caller can DEGRADE (shrink the
    mesh, replay from the last checkpoint) instead of aborting.  Test
    harnesses raise it from injection hooks to exercise the same path.
    """


class Watchdog:
    def __init__(self, slo_factor: float = 5.0, min_timeout_s: float = 30.0,
                 on_straggler: Optional[Callable[[float], None]] = None,
                 window: int = 32):
        self.slo_factor = slo_factor
        self.min_timeout_s = min_timeout_s
        self.on_straggler = on_straggler or (lambda t: None)
        self._times: list[float] = []
        self._window = window
        self._timer: Optional[threading.Timer] = None
        self.fired = 0

    def timeout_s(self) -> float:
        if not self._times:
            return self.min_timeout_s
        med = statistics.median(self._times)
        return max(self.min_timeout_s, self.slo_factor * med)

    def step_start(self):
        self._arm(self.timeout_s())
        self._t0 = time.time()

    def step_end(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        dt = time.time() - self._t0
        self._times.append(dt)
        if len(self._times) > self._window:
            self._times.pop(0)
        return dt

    def _arm(self, timeout):
        def fire():
            self.fired += 1
            self.on_straggler(timeout)

        self._timer = threading.Timer(timeout, fire)
        self._timer.daemon = True
        self._timer.start()


class Heartbeat:
    """Periodic liveness marker: {host, step, time} json, atomically swapped."""

    def __init__(self, path: str, interval_s: float = 15.0, host_id: int = 0):
        self.path = path
        self.interval_s = interval_s
        self.host_id = host_id
        self._step = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def update(self, step: int):
        self._step = step

    def start(self):
        def loop():
            while not self._stop.wait(self.interval_s):
                self.beat()

        self.beat()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def beat(self):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "step": self._step,
                       "time": time.time()}, f)
        os.replace(tmp, self.path)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)


def retry(fn, *, attempts: int = 3, backoff_s: float = 1.0,
          exceptions=(OSError, IOError)):
    """Bounded-backoff retry for transient infrastructure failures."""
    last = None
    for i in range(attempts):
        try:
            return fn()
        except exceptions as e:  # noqa: PERF203
            last = e
            time.sleep(backoff_s * (2 ** i))
    raise last
