from repro.optim.adamw import adamw_init, adamw_update, TrainState, apply_gradients
from repro.optim.schedules import cosine_schedule
