"""LR schedules.  The paper trains with AdamW + cosine annealing (SGDR-style,
no restarts) from lr0=7e-4; we add linear warmup for large-batch stability."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr, total_steps, warmup_steps=0, min_ratio=0.01):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.where(
        warmup_steps > 0, jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0), 1.0
    )
    t = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * cos


def constant_schedule(step, *, base_lr, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), base_lr)


SCHEDULES = {"cosine": cosine_schedule, "constant": constant_schedule}
