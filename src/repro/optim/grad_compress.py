"""Error-feedback int8 gradient compression (1-bit-Adam-family technique).

Beyond-paper distributed-optimization feature: before the DP all-reduce,
gradients are quantized to int8 with a per-tensor scale; the quantization
error is carried to the next step (error feedback), which keeps SGD/Adam
convergence (Karimireddy et al., arXiv:1901.09847).

Under GSPMD the all-reduce itself is compiler-inserted; quantizing the
*gradient values* shrinks the reduce payload when XLA reduces in the narrow
dtype.  We expose the numerics here (value-level quantization + EF) so the
training loop is faithful to what a bandwidth-constrained deployment runs;
the collective-bytes win is reported in the roofline iteration log.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x32):
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, err):
    """Quantize (grad + carried error) to int8, return dequantized grads and
    the new error residual.  Pure value-level transform; shape-preserving."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree_util.tree_map(one, grads, err)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def psum_mean(grads, axis_name: str):
    """Exact cross-device gradient mean (the uncompressed reference the
    compressed collective is benchmarked against)."""
    n = jax.lax.psum(1, axis_name)
    return jax.tree_util.tree_map(
        lambda g: (jax.lax.psum(g.astype(jnp.float32), axis_name) / n
                   ).astype(g.dtype), grads)


def compressed_psum_mean(grads, err, axis_name: str):
    """Error-feedback int8 cross-device gradient mean — the collective
    counterpart of :func:`compress_decompress`, for shard_map-traced
    data-parallel steps.

    Per leaf: add the carried error, share ONE scale across the mesh
    (pmax of the local amax — every shard must quantize on the same grid
    or the integer sum is meaningless), quantize to int8, and all-reduce
    the int8 codes widened to int16 (the sum of N<=256 int8 values needs
    16 bits; the reduce payload is 2 bytes/element vs 4 for f32 — the
    bytes-on-the-wire win measured in BENCH_scaleout.json).  The new
    error residual is LOCAL: what this shard failed to communicate,
    carried to its next step (Karimireddy et al. error feedback).

    Returns ``(mean_grads, new_err)`` with the input tree structures.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int16), axis_name)
        mean = total.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), g32 - q.astype(jnp.float32) * scale

    out = jax.tree_util.tree_map(one, grads, err)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e
