"""Error-feedback int8 gradient compression (1-bit-Adam-family technique).

Beyond-paper distributed-optimization feature: before the DP all-reduce,
gradients are quantized to int8 with a per-tensor scale; the quantization
error is carried to the next step (error feedback), which keeps SGD/Adam
convergence (Karimireddy et al., arXiv:1901.09847).

Under GSPMD the all-reduce itself is compiler-inserted; quantizing the
*gradient values* shrinks the reduce payload when XLA reduces in the narrow
dtype.  We expose the numerics here (value-level quantization + EF) so the
training loop is faithful to what a bandwidth-constrained deployment runs;
the collective-bytes win is reported in the roofline iteration log.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize(x32):
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(grads, err):
    """Quantize (grad + carried error) to int8, return dequantized grads and
    the new error residual.  Pure value-level transform; shape-preserving."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree_util.tree_map(one, grads, err)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e
