"""AdamW (decoupled weight decay) with:

- configurable moment dtype (bf16 moments for >30B archs — halves optimizer
  HBM; error is absorbed by Adam's normalization),
- global-norm gradient clipping,
- static loss-scale support (``TrainConfig.loss_scale``): when the loss was
  scaled before differentiation (mixed-precision policy, DESIGN.md §7) the
  update divides the gradients back out in f32 before the moment update —
  clipping and ``grad_norm`` are reported in UNSCALED units,
- optional error-feedback int8 gradient compression on the DP all-reduce
  (beyond-paper distributed-optimization feature; see optim/grad_compress.py).

Moments are stored with the SAME sharding as params (ZeRO-style: the sharding
engine shards both over the FSDP axes).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.optim.schedules import SCHEDULES


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: dict
    mu: dict
    nu: dict
    compress_err: Optional[dict] = None  # error-feedback residual (optional)


def adamw_init(params, tcfg: TrainConfig) -> TrainState:
    dt = jnp.dtype(tcfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    mu = jax.tree_util.tree_map(zeros, params)
    nu = jax.tree_util.tree_map(zeros, params)
    err = (
        jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if tcfg.grad_compress
        else None
    )
    return TrainState(jnp.zeros((), jnp.int32), params, mu, nu, err)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, state: TrainState, tcfg: TrainConfig):
    """Returns (new_state, metrics)."""
    step = state.step + 1
    lr = SCHEDULES[tcfg.schedule](
        step, base_lr=tcfg.learning_rate,
        total_steps=tcfg.total_steps, warmup_steps=tcfg.warmup_steps,
    )

    inv_scale = 1.0 / tcfg.loss_scale
    gnorm = global_norm(grads) * inv_scale
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * (inv_scale * clip)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * jnp.square(g)
        update = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        p_new = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, state.params, grads, state.mu, state.nu)
    params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = TrainState(step, params, mu, nu, state.compress_err)
    return new_state, {"lr": lr, "grad_norm": gnorm}


def apply_gradients(state: TrainState, grads, tcfg: TrainConfig):
    if tcfg.grad_compress and state.compress_err is not None:
        from repro.optim.grad_compress import compress_decompress

        grads, new_err = compress_decompress(grads, state.compress_err)
        state = state._replace(compress_err=new_err)
    return adamw_update(grads, state, tcfg)
