"""End-to-end driver (paper's kind): train the RGCN contrastive sampler for a
few hundred steps on a real workload's kernel graphs, with validation
InfoNCE, then cluster and report the achieved sampling quality through the
unified evaluation harness.

This example drives the STAGE-level surface (build_graphs / train / embed /
cluster on ``GCLSampler``) that the registered ``gcl`` method wraps; for the
one-call path see ``examples/quickstart.py`` or ``repro.launch.sample``.

    PYTHONPATH=src python examples/train_sampler.py --program AlexNet --steps 200
"""

import argparse
import time

import numpy as np

from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.sampling import evaluate
from repro.tracing.programs import PAPER_PROGRAMS, get_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--program", default="AlexNet", choices=PAPER_PROGRAMS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    prog = get_program(args.program)
    cfg = GCLSamplerConfig(
        train=GCLTrainConfig(steps=args.steps, batch_size=args.batch,
                             log_every=20),
    )
    sampler = GCLSampler(cfg)

    print(f"== contrastive training on {args.program} "
          f"({len(prog)} kernels) ==")
    graphs = sampler.build_graphs(prog)
    print(f"graphs: {len(graphs)}, "
          f"~{int(np.mean([g.n_nodes for g in graphs]))} nodes / "
          f"~{int(np.mean([g.n_edges for g in graphs]))} edges each")

    t0 = time.time()
    info = sampler.train(graphs, verbose=True)
    print(f"training done in {time.time() - t0:.0f}s; "
          f"val_loss={info.get('val_loss', float('nan')):.4f} "
          f"val_acc={info.get('val_acc', float('nan')):.3f}")

    emb = sampler.embed(graphs)
    seqs = np.array([k.seq for k in prog.kernels])
    plan = sampler.cluster(emb, seqs)
    res = evaluate(plan, prog, "P1")
    print(f"K={res.num_clusters} (silhouette mode: {plan.extra.get('mode')})"
          f" -> error {res.error_pct['cycles']:.2f}%, "
          f"speedup {res.speedup:.1f}x")


if __name__ == "__main__":
    main()
