"""Batched serving example over the assigned-architecture zoo: prefill +
KV/SSM-cache decode with continuous batches of synthetic requests.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m
"""

import argparse
import sys

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()
    return serve_main([
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", "48",
        "--tokens", str(args.tokens),
        "--requests", "2",
    ])


if __name__ == "__main__":
    sys.exit(main())
