"""LM pretraining driver on the assigned-architecture zoo (substrate e2e
example): trains a reduced config of any ``--arch`` on the deterministic
synthetic stream with checkpointing + watchdog, via the production launcher.

    PYTHONPATH=src python examples/lm_pretrain.py --arch llama3.2-3b \\
        --steps 300 --batch 8 --seq-len 256

(The loss drops markedly within a few hundred steps on the Markov stream;
~10-50M-param smoke configs train at a few steps/s on CPU.)
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_pretrain")
    args = ap.parse_args()
    return train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq-len", str(args.seq_len),
        "--lr", "1e-3",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "100",
        "--resume", "auto",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
