"""Quickstart: the whole GCL-Sampler pipeline on one workload in ~2 minutes.

    PYTHONPATH=src python examples/quickstart.py [--program nw]

Stages (paper Fig. 2): trace -> HRG -> RGCN contrastive training ->
embeddings -> K-Means -> representative selection -> sampled simulation,
with error/speedup against full simulation and the three baselines.
"""

import argparse
import time

import numpy as np

from repro.core.baselines import pka_plan, sieve_plan, stem_root_plan
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.sim.simulate import sampling_error, simulate_program, speedup
from repro.tracing.programs import PAPER_PROGRAMS, get_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--program", default="nw", choices=PAPER_PROGRAMS)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    prog = get_program(args.program)
    print(f"== {args.program}: {len(prog)} kernel invocations ==")

    t0 = time.time()
    sampler = GCLSampler(GCLSamplerConfig(
        cap_instr=64,
        train=GCLTrainConfig(steps=args.steps, batch_size=8),
    ))
    plan = sampler.fit(prog, verbose=True)
    print(f"GCL-Sampler: K={plan.num_clusters} clusters, "
          f"{len(plan.rep_indices())} representative(s) "
          f"({time.time() - t0:.0f}s)")

    metrics = simulate_program(prog, "P1")
    rows = [("GCL-Sampler", plan)]
    rows += [("PKA", pka_plan(prog)), ("Sieve", sieve_plan(prog)),
             ("STEM+ROOT", stem_root_plan(prog))]
    print(f"\n{'method':14s}{'clusters':>9s}{'reps':>6s}"
          f"{'error %':>9s}{'speedup':>9s}")
    for name, p in rows:
        print(f"{name:14s}{p.num_clusters:9d}{len(p.rep_indices()):6d}"
              f"{sampling_error(p, metrics):9.2f}"
              f"{speedup(p, metrics):8.1f}x")


if __name__ == "__main__":
    main()
