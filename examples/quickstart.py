"""Quickstart: every registered sampling method on one workload in ~2 minutes,
through the unified ``repro.sampling`` API.

    PYTHONPATH=src python examples/quickstart.py [--program nw]

Stages (paper Fig. 2, owned by the ``gcl`` method): trace -> HRG -> RGCN
contrastive training -> embeddings -> K-Means -> representative selection,
then one ``evaluate`` call per method for error/speedup against full
simulation.  Artifacts (trained encoder, embeddings, plans) land in
``--out`` and are replayed on re-runs.  For the full method x program x
platform grid, use ``python -m repro.launch.sample``.
"""

import argparse
import time

from repro.sampling import (
    ArtifactStore, available_methods, evaluate_metrics, get_method,
)
from repro.sim.simulate import simulate_program
from repro.tracing.programs import PAPER_PROGRAMS, get_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--program", default="nw", choices=PAPER_PROGRAMS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--out", default="runs/quickstart")
    args = ap.parse_args()

    prog = get_program(args.program)
    store = ArtifactStore(args.out)
    print(f"== {args.program}: {len(prog)} kernel invocations ==")

    metrics = simulate_program(prog, "P1")  # full simulation, once
    results = []
    for method_id in available_methods():
        kwargs = (
            dict(steps=args.steps, batch_size=8, cap_instr=64)
            if method_id == "gcl" else {}
        )
        method = get_method(method_id, **kwargs)
        t0 = time.time()
        plan, _ = method.run(prog, store=store)
        print(f"{plan.method}: K={plan.num_clusters} clusters, "
              f"{len(plan.rep_indices())} representative(s) "
              f"({time.time() - t0:.0f}s)")
        results.append(evaluate_metrics(plan, metrics, program=prog.name,
                                        platform="P1"))

    print(f"\n{'method':14s}{'clusters':>9s}{'reps':>6s}"
          f"{'error %':>9s}{'speedup':>9s}")
    for r in results:
        print(f"{r.method:14s}{r.num_clusters:9d}{r.num_reps:6d}"
              f"{r.error_pct['cycles']:9.2f}{r.speedup:8.1f}x")


if __name__ == "__main__":
    main()
