"""Sampled-simulation workflow on a workload derived from an ASSIGNED
architecture config (the framework-integration path, paper §5.4): the LM zoo
is the simulation subject.

    PYTHONPATH=src python examples/sampled_simulation.py --arch granite-3-2b
"""

import argparse
import time

import numpy as np

from repro.configs import list_archs
from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.sim.simulate import (
    full_metrics, reconstruct, sampling_error, sim_wall_time,
    simulate_program, speedup,
)
from repro.tracing.programs import lm_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=2, help="inference steps")
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    prog = lm_program(args.arch, steps=args.steps, seq_len=256)
    print(f"== lm:{args.arch}: {len(prog)} kernel invocations "
          f"(prefill + {args.steps - 1} decode steps) ==")

    sampler = GCLSampler(GCLSamplerConfig(
        cap_instr=64,
        train=GCLTrainConfig(steps=args.train_steps, batch_size=16),
    ))
    plan = sampler.fit(prog, verbose=True)
    metrics = simulate_program(prog, "P1")

    full = full_metrics(metrics)
    est = reconstruct(plan, metrics)
    t_full = sim_wall_time(metrics)
    t_sampled = sim_wall_time(metrics, plan.rep_indices())
    print(f"\nclusters: {plan.num_clusters}  reps: {len(plan.rep_indices())}")
    print(f"cycles: full {full['cycles']:.3e} vs sampled {est['cycles']:.3e} "
          f"(err {sampling_error(plan, metrics):.2f}%)")
    print(f"kernel-time speedup (eq.6): {speedup(plan, metrics):.1f}x")
    print(f"simulator wall-time: {t_full:.1f}s -> {t_sampled:.1f}s "
          f"({t_full / max(t_sampled, 1e-9):.1f}x)")
    for m in ("ipc", "l1_hit", "l2_hit", "occupancy"):
        print(f"  {m:10s} full {full[m]:.4f} sampled {est[m]:.4f}")


if __name__ == "__main__":
    main()
