"""Sampled-simulation workflow on a workload derived from an ASSIGNED
architecture config (the framework-integration path, paper §5.4): the LM zoo
is the simulation subject, driven through the unified ``repro.sampling``
API — one ``run`` + one ``evaluate`` call owns the whole comparison.

    PYTHONPATH=src python examples/sampled_simulation.py --arch granite-3-2b
"""

import argparse

from repro.configs import list_archs
from repro.sampling import evaluate, get_method
from repro.tracing.programs import lm_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=2, help="inference steps")
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    prog = lm_program(args.arch, steps=args.steps, seq_len=256)
    print(f"== lm:{args.arch}: {len(prog)} kernel invocations "
          f"(prefill + {args.steps - 1} decode steps) ==")

    method = get_method("gcl", steps=args.train_steps, batch_size=16,
                        cap_instr=64)
    plan, artifacts = method.run(prog)
    res = evaluate(plan, prog, "P1")

    print(f"\nclusters: {res.num_clusters}  reps: {res.num_reps}")
    print(f"cycles: full {res.full['cycles']:.3e} vs sampled "
          f"{res.sampled['cycles']:.3e} (err {res.error_pct['cycles']:.2f}%)")
    print(f"kernel-time speedup (eq.6): {res.speedup:.1f}x")
    print(f"simulator wall-time: {res.sim_time_full_s:.1f}s -> "
          f"{res.sim_time_sampled_s:.1f}s ({res.sim_speedup:.1f}x)")
    for m in ("ipc", "l1_hit", "l2_hit", "occupancy"):
        print(f"  {m:10s} full {res.full[m]:.4f} sampled {res.sampled[m]:.4f}")
    print(f"stage timings: "
          + " ".join(f"{k}={v:.1f}s" for k, v in artifacts.timings.items()))


if __name__ == "__main__":
    main()
