"""Per-kernel interpret-mode validation: shape/dtype sweeps, allclose against
the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rgcn_spmm.ops import rgcn_message_agg
from repro.kernels.rgcn_spmm.ref import rgcn_message_agg_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref, ssd_sequential_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, S, K, G, hd, bq, bk)
    (2, 128, 2, 2, 64, 32, 32),
    (1, 256, 1, 4, 128, 64, 128),   # MQA grouping
    (2, 64, 4, 1, 32, 64, 64),      # MHA, single q block
    (1, 128, 2, 3, 16, 32, 64),     # uneven head grouping, rect blocks
]


@pytest.mark.parametrize("B,S,K,G,hd,bq,bk", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, K, G, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention_fwd(q, k, v, scale=hd**-0.5, block_q=bq, block_k=bk,
                              interpret=True)
    ref = attention_ref(q, k, v, hd**-0.5)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_grad_via_oracle():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    g1 = jax.grad(lambda q_: flash_attention(q_, k, v, 0.17, True).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v, 0.17).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


# ---------------------------------------------------------------------------
# rgcn_spmm
# ---------------------------------------------------------------------------

RGCN_SHAPES = [
    # (B, N, D, E, nb, O)
    (2, 64, 32, 100, 2, 48),
    (1, 128, 64, 256, 3, 64),
    (3, 32, 16, 17, 2, 32),  # edge count not divisible by block
]


@pytest.mark.parametrize("B,N,D,E,nb,O", RGCN_SHAPES)
def test_rgcn_spmm_matches_ref(B, N, D, E, nb, O):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    h = jax.random.normal(ks[0], (B, N, D))
    basis = jax.random.normal(ks[1], (nb, D, O))
    src = jax.random.randint(ks[2], (B, E), 0, N)
    dst = jax.random.randint(ks[3], (B, E), 0, N)
    w = jax.random.normal(ks[4], (B, E, nb))
    out = rgcn_message_agg(h, basis, src, dst, w, N, True)
    ref = rgcn_message_agg_ref(h, basis, src, dst, w, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_rgcn_spmm_grad_via_oracle():
    B, N, D, E, nb, O = 1, 32, 16, 40, 2, 24
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    h = jax.random.normal(ks[0], (B, N, D))
    basis = jax.random.normal(ks[1], (nb, D, O))
    src = jax.random.randint(ks[2], (B, E), 0, N)
    dst = jax.random.randint(ks[3], (B, E), 0, N)
    w = jax.random.normal(ks[4], (B, E, nb))
    g1 = jax.grad(lambda h_: rgcn_message_agg(h_, basis, src, dst, w, N, True).sum())(h)
    g2 = jax.grad(lambda h_: rgcn_message_agg_ref(h_, basis, src, dst, w, N).sum())(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (B, S, nh, hp, ds, Q)
    (2, 64, 3, 16, 8, 16),
    (1, 128, 2, 32, 16, 32),
    (2, 32, 1, 8, 4, 32),  # single chunk
]


@pytest.mark.parametrize("B,S,nh,hp,ds,Q", SSD_SHAPES)
def test_ssd_kernel_matches_refs(B, S, nh, hp, ds, Q):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, ds)) * 0.5
    yk, fk = ssd_scan(x, dt, A, Bc, Cc, Q, True)
    yr, fr = ssd_ref(x, dt, A, Bc, Cc, Q)
    ys, fs = ssd_sequential_ref(x, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(ys), atol=1e-3)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(fs), atol=1e-3)


def test_ssd_chunk_size_invariance():
    """The chunked algorithm is exact: any chunk size gives the same y."""
    B, S, nh, hp, ds = 1, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, ds)) * 0.5
    y8, f8 = ssd_ref(x, dt, A, Bc, Cc, 8)
    y32, f32 = ssd_ref(x, dt, A, Bc, Cc, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f8), np.asarray(f32), atol=1e-4)
