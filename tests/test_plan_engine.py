"""Planning-engine parity suite (DESIGN.md §8).

Pins the compiled multi-K sweep to the sequential reference —
labels/K/silhouette identical request-for-request — plus the vectorized
timing model against the scalar shim, the PlanEngine batching layer, and
mask-aware Lloyd properties (empty clusters, k >= n, duplicates)."""

import numpy as np
import pytest

from repro.core import clustering
from repro.core.clustering import (
    select_k_and_cluster, select_k_and_cluster_swept, sweep_cluster_stack,
)
from repro.sampling.engine import PlanEngine, PlanRequest


def _blobs(k, n_per, d, seed, scale=50.0, sigma=0.5):
    r = np.random.default_rng(seed)
    c = r.standard_normal((k, d)) * scale
    return np.concatenate(
        [ci + r.standard_normal((n_per, d)) * sigma for ci in c]
    ).astype(np.float32)


def _assert_same(x, seq_kw=None, **kw):
    """Swept and sequential must agree exactly on labels and K and to 1e-5
    on the silhouette (the blocked accumulation reorders fp sums)."""
    seq_only = {k: v for k, v in dict(kw, **(seq_kw or {})).items()
                if k != "sil_block"}  # sweep-only knob
    l1, i1 = select_k_and_cluster(x, **seq_only)
    l2, i2 = select_k_and_cluster_swept(x, **kw)
    assert i1["k"] == i2["k"], (i1, i2)
    np.testing.assert_array_equal(l1, l2)
    assert abs(i1["sil"] - i2["sil"]) < 1e-5
    assert i1["mode"] == i2["mode"]
    return l2, i2


# -- swept vs sequential clustering parity ----------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k_true,n_per,d", [(3, 20, 16), (5, 30, 8),
                                            (2, 50, 32)])
def test_sweep_matches_sequential_blobs(seed, k_true, n_per, d):
    x = _blobs(k_true, n_per, d, seed)
    _, info = _assert_same(x, k_max=12, seed=seed)
    assert info["k"] == k_true
    assert info["engine"] == "sweep"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sweep_matches_sequential_unstructured(seed):
    """No blob structure -> both paths take the same weak->K=1 collapse."""
    x = np.random.default_rng(seed).standard_normal((80, 12)).astype(np.float32)
    _assert_same(x, k_max=10, seed=seed)


def test_identical_embeddings_collapse_to_one_cluster():
    x = np.ones((50, 8), np.float32)
    labels, info = select_k_and_cluster_swept(x, seed=0)
    assert info["k"] == 1 and info["mode"] == "degenerate"
    assert labels.max() == 0
    _assert_same(x, seed=0)


def test_tiny_n_agglomeration_fallback():
    x = np.array([[0.0, 0.0], [0.01, 0.0], [10.0, 10.0]], np.float32)
    labels, info = select_k_and_cluster_swept(x)
    assert info["k"] == 2 and info["mode"] == "tiny"
    assert labels[0] == labels[1] != labels[2]
    _assert_same(x)


def test_trivial_sizes():
    for n in (0, 1):
        x = np.zeros((n, 4), np.float32)
        labels, info = select_k_and_cluster_swept(x)
        assert info["mode"] == "trivial" and len(labels) == n


def test_sil_cap_subsampling_parity():
    """n > sil_cap: both paths score silhouette on the SAME deterministic
    subsample and still agree exactly."""
    x = _blobs(4, 60, 8, seed=7)
    _, info = _assert_same(x, k_max=8, seed=3, sil_cap=100)
    assert info["k"] == 4


def test_device_init_parity():
    """On-device kmeans++ (fold-in RNG): sequential reference and swept
    engine draw identical seeds and produce identical labels."""
    x = _blobs(4, 30, 8, seed=11)
    _, info = _assert_same(x, k_max=10, seed=2, init="device")
    assert info["k"] == 4


def test_device_init_independent_of_batch_composition():
    """A program's device-init draw happens at its OWN points bucket, so
    riding in a batch next to a much larger program changes nothing."""
    small = _blobs(3, 10, 8, seed=1)        # bucket 32
    big = _blobs(4, 60, 8, seed=2)          # bucket 256
    solo = select_k_and_cluster_swept(small, k_max=8, seed=3, init="device")
    batched = sweep_cluster_stack([small, big], k_max=8, seed=3,
                                  init="device")[0]
    np.testing.assert_array_equal(solo[0], batched[0])
    assert solo[1]["k"] == batched[1]["k"]


def test_non_divisor_sil_block_drops_no_columns():
    """sil_block that doesn't divide the points bucket must be rounded
    down, not silently truncate the silhouette accumulation."""
    x = _blobs(3, 40, 8, seed=13)           # n=120 -> bucket 128
    _, info = _assert_same(x, k_max=8, seed=0, seq_kw={}, sil_block=100)
    assert info["k"] == 3


def test_swept_pallas_matches_sequential():
    """Fused kmeans_assign + blocked silhouette kernels (interpret on CPU)
    inside the sweep reproduce the sequential labels."""
    x = _blobs(3, 12, 8, seed=5)
    l1, i1 = select_k_and_cluster(x, k_max=6, seed=0, iters=8)
    l2, i2 = select_k_and_cluster_swept(x, k_max=6, seed=0, iters=8,
                                        use_pallas=True)
    assert i1["k"] == i2["k"]
    np.testing.assert_array_equal(l1, l2)


# -- mask-aware Lloyd properties --------------------------------------------

def test_batch_stack_equals_single_dispatch():
    """Stacked (padded, masked) programs return exactly the per-program
    results — padding rows never leak into labels or scores."""
    xs = [_blobs(3, n_per, 16, seed) for seed, n_per in
          enumerate([10, 17, 25, 31, 8])]
    outs = sweep_cluster_stack(xs, k_max=10, seed=1)
    for x, (lb, ib) in zip(xs, outs):
        ls, is_ = select_k_and_cluster_swept(x, k_max=10, seed=1)
        np.testing.assert_array_equal(lb, ls)
        assert ib["k"] == is_["k"]


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # dev-only dep (requirements-dev.txt)
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(5, 20), st.integers(2, 4), st.integers(0, 100))
    def test_lloyd_k_near_n_and_empty_clusters(n, distinct, seed):
        """k candidates up to k_max > n with few distinct points: empty
        clusters keep their centroids (no NaNs), invalid candidates
        (k > n-1) are masked, and the result still matches the sequential
        reference."""
        rng = np.random.default_rng(seed)
        base = rng.standard_normal((distinct, 6)).astype(np.float32) * 10
        x = base[rng.integers(0, distinct, n)] + \
            rng.standard_normal((n, 6)).astype(np.float32) * 0.01
        l1, i1 = select_k_and_cluster(x, k_max=24, seed=seed)
        l2, i2 = select_k_and_cluster_swept(x, k_max=24, seed=seed)
        assert i1["k"] == i2["k"]
        np.testing.assert_array_equal(l1, l2)
        assert np.isfinite(i2["sil"])
        # labels compact: every cluster id in [0, k) occupied
        assert set(np.unique(l2)) == set(range(i2["k"]))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(12, 40), st.integers(0, 100))
    def test_swept_scores_match_sequential_scores(n, seed):
        """Per-candidate silhouette scores (not just the argmax) agree."""
        x = _blobs(3, n, 8, seed)
        _, i1 = select_k_and_cluster(x, k_max=8, seed=seed)
        _, i2 = select_k_and_cluster_swept(x, k_max=8, seed=seed)
        s1, s2 = i1.get("scores", {}), i2.get("scores", {})
        assert set(s1) == set(s2)
        for k in s1:
            assert abs(s1[k] - s2[k]) < 1e-4, (k, s1[k], s2[k])


# -- vectorized timing model -------------------------------------------------

def test_simulate_batch_matches_scalar():
    from repro.sim.hardware import PLATFORMS
    from repro.sim.timing import (
        _METRIC_FIELDS, _simulate_kernel_scalar, simulate_batch,
        simulate_kernel, stack_stats,
    )
    from repro.tracing.programs import get_program

    for pname in ("3mm", "bfs", "backprop"):
        prog = get_program(pname)
        for plat, hw in PLATFORMS.items():
            stats = [k.stats(plat) for k in prog.kernels]
            batch = simulate_batch(stack_stats(stats), hw)
            assert len(batch) == len(stats)
            for i, s in enumerate(stats):
                ref = _simulate_kernel_scalar(s, hw)
                shim = simulate_kernel(s, hw)
                for f in _METRIC_FIELDS:
                    a, b = getattr(batch[i], f), getattr(ref, f)
                    assert abs(a - b) <= 1e-6 * max(abs(b), 1e-12), \
                        (pname, plat, i, f, a, b)
                    assert getattr(shim, f) == a


def test_batch_metrics_sequence_protocol():
    from repro.sim.simulate import full_metrics, simulate_program
    from repro.sim.timing import BatchKernelMetrics, KernelMetrics
    from repro.tracing.programs import get_program

    m = simulate_program(get_program("3mm"), "P1")
    assert isinstance(m, BatchKernelMetrics)
    assert isinstance(m[0], KernelMetrics)
    as_list = m.tolist()
    assert len(as_list) == len(m)
    # legacy list-of-KernelMetrics consumers see identical aggregates
    assert full_metrics(as_list) == full_metrics(m)


# -- PlanEngine layer --------------------------------------------------------

def test_plan_engine_many_matches_single():
    xs = [_blobs(3, 15, 16, s) for s in range(4)]
    reqs = [PlanRequest(x, np.arange(len(x)), "t", seed=i)
            for i, x in enumerate(xs)]
    eng = PlanEngine(k_max=8)
    plans = eng.plan_many(reqs)
    for i, (x, plan) in enumerate(zip(xs, plans)):
        solo = PlanEngine(k_max=8).plan(x, np.arange(len(x)), "t", seed=i)
        np.testing.assert_array_equal(plan.labels, solo.labels)
        assert plan.reps == solo.reps
    st_ = eng.engine_stats()
    assert st_["programs"] == 4
    # same sizes -> one bucket -> one compiled dispatch for all four
    assert st_["dispatches"] == 1


def test_plan_engine_respects_max_batch_and_buckets():
    xs = [_blobs(2, n, 8, s) for s, n in enumerate([10, 12, 40, 45, 44])]
    eng = PlanEngine(k_max=6, max_batch=2)
    eng.plan_many([PlanRequest(x, np.arange(len(x)), "t") for x in xs])
    st_ = eng.engine_stats()
    # bucket (32, 8): 2 programs -> 1 dispatch; bucket (128, 8): 3
    # programs at max_batch=2 -> 2 dispatches
    assert st_["dispatches"] == 3
    assert st_["programs"] == 5


def test_plan_engine_sequential_mode_identical():
    x = _blobs(3, 20, 8, seed=9)
    sweep = PlanEngine(k_max=8).plan(x, np.arange(len(x)), "t")
    seq = PlanEngine(k_max=8, engine="sequential").plan(
        x, np.arange(len(x)), "t")
    np.testing.assert_array_equal(sweep.labels, seq.labels)
    assert sweep.reps == seq.reps


def test_second_program_never_recompiles():
    """Same-bucket programs share one executable: the acceptance check."""
    eng = PlanEngine(k_max=8)
    eng.cluster(_blobs(3, 14, 16, 0), seed=0)
    builds = clustering.ENGINE_STATS["builds"]
    eng.cluster(_blobs(4, 10, 16, 1), seed=1)   # same (64, 16) bucket
    assert clustering.ENGINE_STATS["builds"] == builds


def test_gcl_sampler_cluster_routes_through_engine():
    from repro.core.sampler import GCLSampler, GCLSamplerConfig

    x = _blobs(3, 15, 16, seed=4)
    sampler = GCLSampler(GCLSamplerConfig(k_max=8))
    plan = sampler.cluster(x, np.arange(len(x)))
    assert plan.method == "GCL-Sampler"
    assert plan.extra.get("engine") == "sweep"
    assert plan.num_clusters == 3


def test_use_pallas_threads_from_rgcn_config():
    from repro.core.rgcn import RGCNConfig
    from repro.core.sampler import GCLSampler, GCLSamplerConfig

    cfg = GCLSamplerConfig(k_max=6, rgcn=RGCNConfig(use_pallas=True))
    assert GCLSampler(cfg).plan_engine().cfg.use_pallas is True
    assert GCLSampler(GCLSamplerConfig()).plan_engine().cfg.use_pallas is False


# -- serving regressions (DESIGN.md §9) --------------------------------------

def test_plan_many_empty_and_degenerate_inputs():
    eng = PlanEngine(k_max=6, iters=10)
    assert eng.plan_many([]) == []
    assert eng.cluster_many([]) == []
    # zero rows: clean trivial plan, no tracing through an empty group
    p = eng.plan(np.zeros((0, 8), np.float32), np.zeros(0, int), "m")
    assert p.labels.shape == (0,) and p.reps == {}
    assert p.extra["mode"] == "trivial" and p.extra["k"] == 0
    # zero-width features: one degenerate cluster (tiny path keeps parity
    # with the sequential reference below the agglomeration floor)
    labels, info = eng.cluster(np.zeros((7, 0), np.float32))
    assert info["mode"] == "degenerate" and info["k"] == 1
    np.testing.assert_array_equal(labels, np.zeros(7, int))
    labels, info = eng.cluster(np.zeros((3, 0), np.float32))
    assert info["mode"] == "tiny" and info["k"] == 1


def test_one_dimensional_embeddings_normalize():
    """(n,) vectors are a single scalar feature -> same result as (n, 1)."""
    x = np.arange(8.0, dtype=np.float32)
    eng = PlanEngine(k_max=6, iters=10)
    labels, info = eng.cluster(x)
    l2, i2 = select_k_and_cluster(x[:, None], k_max=6, iters=10)
    np.testing.assert_array_equal(labels, l2)
    assert info["k"] == i2["k"]


def test_cluster_many_mixed_seeds_match_sequential():
    """Per-request seed overrides inside ONE chunk: every request must get
    ITS seed's result, identical to the sequential reference."""
    xs = [_blobs(3, 18, 8, s) for s in range(5)]  # one 64-point bucket
    seeds = [7, None, 3, 3, 11]                   # None -> engine seed
    eng = PlanEngine(k_max=8, iters=15, seed=42, max_batch=8)
    out = eng.cluster_many(xs, seeds=seeds)
    assert eng.stats["dispatches"] == 1  # all five in one compiled dispatch
    for x, s, (labels, info) in zip(xs, seeds, out):
        ref_l, ref_i = select_k_and_cluster(x, seed=42 if s is None else s,
                                            k_max=8, iters=15)
        np.testing.assert_array_equal(labels, ref_l)
        assert info["k"] == ref_i["k"]


def test_plan_many_overlap_on_off_identical():
    reqs = [PlanRequest(_blobs(3, 15, 8, s), np.arange(45), "m", seed=s)
            for s in range(4)]
    on = PlanEngine(k_max=6, iters=10, overlap_plan_build=True).plan_many(reqs)
    off = PlanEngine(k_max=6, iters=10,
                     overlap_plan_build=False).plan_many(reqs)
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a.labels, b.labels)
        assert a.reps == b.reps and a.extra["k"] == b.extra["k"]


def test_errors_isolate_aligns_poison_requests():
    good = _blobs(3, 15, 8, 0)
    poison = np.array([[1, 2], [3, "x"]], dtype=object)  # fails float cast
    eng = PlanEngine(k_max=6, iters=10)
    with pytest.raises(ValueError):
        eng.cluster_many([good, poison])
    out = eng.cluster_many([good, poison, good], errors="isolate")
    assert isinstance(out[1], Exception)
    np.testing.assert_array_equal(out[0][0], out[2][0])
    assert eng.stats["errors"] >= 1
    plans = eng.plan_many(
        [PlanRequest(x, np.arange(len(x)), "m")
         for x in (good, poison)], errors="isolate")
    assert plans[0].labels.shape == (45,)
    assert isinstance(plans[1], Exception)
    with pytest.raises(ValueError):
        eng.cluster_many([good], errors="nope")


def test_bucket_hist_structured_and_reset():
    eng = PlanEngine(k_max=6, iters=10)
    eng.plan_many([PlanRequest(_blobs(3, 15, 8, s), np.arange(45), "m")
                   for s in range(2)]
                  + [PlanRequest(_blobs(3, 30, 8, 9), np.arange(90), "m")])
    hist = {(e["points_bucket"], e["dim"]): e["count"]
            for e in eng.stats["bucket_hist"]}
    assert hist == {(64, 8): 2, (128, 8): 1}
    assert eng.stats["programs"] == 3
    eng.reset_stats()
    assert eng.stats["bucket_hist"] == [] and eng.stats["programs"] == 0
    assert eng.engine_stats()["builds"] > 0  # process counters survive


def test_warmup_prebuilds_then_zero_builds():
    clustering._ENGINE_CACHE.clear()
    eng = PlanEngine(k_max=6, iters=10, max_batch=4)
    built = eng.warmup([(64, 8)], batch_sizes=[1, 2])
    assert built > 0
    assert eng.warmup([{"points_bucket": 64, "dim": 8}],
                      batch_sizes=[1, 2]) == 0
    assert eng.stats["warmed_executables"] == built
    before = clustering.ENGINE_STATS["builds"]
    eng.cluster_many([_blobs(3, 15, 8, s) for s in range(2)])
    assert clustering.ENGINE_STATS["builds"] == before
