"""Tracing substrate + baseline-method behaviour tests."""

import numpy as np

from repro.core.baselines import sieve_plan, stem_root_plan
from repro.core.baselines.pka import pka_features
from repro.sim.simulate import sampling_error, simulate_program, speedup
from repro.tracing.isa import OPCODE_IDS
from repro.tracing.programs import PAPER_PROGRAMS, get_program, lm_program
from repro.tracing.templates import make_kernel


def test_total_kernel_count_matches_paper():
    assert sum(len(get_program(p)) for p in PAPER_PROGRAMS) == 7746


def test_trace_deterministic():
    k = make_kernel("k", "gemm", {"M": 256, "N": 256, "K": 256}, 0, 3)
    t1 = k.trace(2, 64)
    t2 = k.trace(2, 64)
    np.testing.assert_array_equal(t1[0].opcode, t2[0].opcode)
    np.testing.assert_array_equal(t1[0].mem_addr, t2[0].mem_addr)
    np.testing.assert_array_equal(t1[0].vstats, t2[0].vstats)


def test_trace_table1_fields():
    """Every Table-1 record field is populated."""
    k = make_kernel("k", "softmax", {"rows": 128, "cols": 512}, 0, 1)
    tr = k.trace(1, 64)[0]
    n = len(tr.opcode)
    assert tr.pc.shape == (n,)
    assert tr.mask.shape == (n,) and (tr.mask > 0).all()
    assert tr.dest.shape == (n, 2) and tr.src.shape == (n, 3)
    assert tr.mem_width.shape == (n,)
    assert (tr.mem_addr[tr.mem_width > 0] > 0).all()
    assert tr.vstats.shape == (n, 8)
    # S2R prologue (ctaid/tid) present
    assert tr.opcode[0] == OPCODE_IDS["S2R"]


def test_warp_prologue_encodes_grid():
    small = make_kernel("a", "gemv", {"n": 16, "m": 4096}, 0, 1)
    big = make_kernel("b", "gemv", {"n": 65536, "m": 4096}, 1, 1)
    vs, vb = small.trace(1, 64)[0].vstats[0], big.trace(1, 64)[0].vstats[0]
    assert vb[0] > vs[0]  # ctaid scale grows with grid


def test_sieve_never_merges_names():
    prog = get_program("AlexNet")
    plan = sieve_plan(prog)
    names = [k.name for k in prog.kernels]
    for c in np.unique(plan.labels):
        members = np.nonzero(plan.labels == c)[0]
        assert len({names[i] for i in members}) == 1


def test_sieve_alexnet_merges_equal_count_convs():
    """conv2 (implicit gemm) and conv3 (winograd) have ~equal instruction
    counts under one name -> Sieve merges them -> error."""
    prog = get_program("AlexNet")
    plan = sieve_plan(prog)
    assert plan.labels[3] == plan.labels[6]
    ms = simulate_program(prog, "P1")
    assert sampling_error(plan, ms) > 3.0


def test_stem_root_multiple_reps():
    prog = get_program("lud")
    plan = stem_root_plan(prog)
    sizes = [len(r) for r in plan.reps.values()]
    assert max(sizes) >= 1
    ms = simulate_program(prog, "P1")
    # STEM+ROOT: consistently low error, modest speedup
    assert sampling_error(plan, ms) < 5.0
    assert speedup(plan, ms) >= 1.0


def test_pka_features_are_12d_and_microarch_independent():
    prog = get_program("3mm")
    x = pka_features(prog, "P1")
    assert x.shape == (9, 12)
    x2 = pka_features(prog, "P3")
    np.testing.assert_allclose(x, x2)  # same on every platform


def test_phi2_platform_sensitivity():
    """phi-2's library kernels select different algorithms per platform
    (Table 3 anomaly): stats differ across P1/P2 for the attention kernels."""
    prog = get_program("phi-2")
    attn = [k for k in prog.kernels if "attention" in k.name][0]
    s1, s2 = attn.stats("P1"), attn.stats("P2")
    assert s1.warp_instructions != s2.warp_instructions or not np.allclose(
        s1.instr_mix, s2.instr_mix
    )


def test_other_programs_platform_stable():
    prog = get_program("cfd")
    k = prog.kernels[0]
    s1, s3 = k.stats("P1"), k.stats("P3")
    assert s1.warp_instructions == s3.warp_instructions
    np.testing.assert_allclose(s1.instr_mix, s3.instr_mix)


def test_lm_program_from_assigned_arch():
    """The framework-integration path: any assigned arch yields a sampled-
    simulation workload."""
    prog = lm_program("granite-3-2b", steps=2, seq_len=128)
    assert len(prog) > 100
    ms = simulate_program(prog, "P1")
    assert all(m.cycles > 0 for m in ms)
    # decode-step kernels exist (gemv) alongside prefill gemms
    templates = {k.template for k in prog.kernels}
    assert {"gemm", "gemv", "softmax"}.issubset(templates)


def test_lm_program_hybrid_has_ssm_kernels():
    prog = lm_program("jamba-v0.1-52b", steps=1, seq_len=64)
    assert any("ssd" in k.name for k in prog.kernels)
    assert any("moe" in k.name for k in prog.kernels)
