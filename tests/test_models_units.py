"""Model-layer unit tests: attention paths, MoE, SSM, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import smoke_arch
from repro.models import init_params, lm_loss
from repro.models.attention import (
    _chunked_causal_attention, _full_causal_attention,
)
from repro.models.layers import chunked_cross_entropy, rmsnorm, init_rmsnorm
from repro.models.moe import capacity, moe_forward
from repro.models.ssm import ssd_chunked


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100), st.sampled_from([32, 64]), st.sampled_from([16, 32]))
def test_chunked_attention_equals_full(seed, S, chunk):
    B, K, G, hd = 1, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    full = _full_causal_attention(q, k, v, 0.25)
    chk = _chunked_causal_attention(q, k, v, 0.25, chunk, chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk), atol=2e-5)


def test_chunked_ce_matches_dense():
    cfg = smoke_arch("llama3.2-3b").replace(loss_chunk=7)
    B, S, D, V = 2, 20, 64, 512
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (D, V)) * 0.1
    y = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    y = y.at[:, :3].set(-1)  # masked positions
    got = chunked_cross_entropy(cfg, h, w, y)
    logits = (h @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.maximum(y, 0)[..., None], -1)[..., 0]
    valid = (y >= 0)
    ref = jnp.sum((lse - gold) * valid) / jnp.sum(valid)
    assert float(got) == pytest.approx(float(ref), rel=1e-5)


def test_moe_capacity_formula():
    cfg = smoke_arch("dbrx-132b")  # E=4, top_k=2, cf=1.25
    c = capacity(cfg, 64)
    assert c >= cfg.top_k
    assert c % 4 == 0 or c <= 4


def test_moe_all_tokens_routed_when_capacity_ample():
    cfg = smoke_arch("dbrx-132b").replace(capacity_factor=8.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    p = params["blocks"]["pos0"]["ffn"]
    p = jax.tree_util.tree_map(lambda x: x[0], p)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_forward(cfg, p, h)
    assert out.shape == h.shape
    # with huge capacity nothing is dropped: output rows are nonzero
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert (norms > 1e-6).all()
    assert np.isfinite(float(aux))


def test_moe_aux_loss_near_one_for_uniform():
    """Perfectly balanced routing gives aux ~= 1 (Switch normalization)."""
    cfg = smoke_arch("dbrx-132b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda x: x[0], params["blocks"]["pos0"]["ffn"])
    # zero router -> uniform gates
    p = dict(p, router=jnp.zeros_like(p["router"]))
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, aux = moe_forward(cfg, p, h)
    assert 0.5 < float(aux) < 1.6


def test_ssd_state_continuity():
    """Feeding initial_state continues the sequence exactly."""
    B, S, nh, hp, ds, Q = 1, 32, 2, 8, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, nh, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    Cc = jax.random.normal(ks[4], (B, S, ds)) * 0.5
    y_full, f_full = ssd_chunked(x, dt, A, Bc, Cc, Q)
    h = S // 2
    y1, f1 = ssd_chunked(x[:, :h], dt[:, :h], A, Bc[:, :h], Cc[:, :h], Q)
    y2, f2 = ssd_chunked(x[:, h:], dt[:, h:], A, Bc[:, h:], Cc[:, h:], Q,
                         initial_state=f1)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_full), np.asarray(f2), atol=1e-4)


def test_rmsnorm_scale_invariant_direction():
    p, _ = init_rmsnorm(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 16))
    y1 = rmsnorm(p, x, 1e-6)
    y2 = rmsnorm(p, 10.0 * x, 1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


@pytest.mark.parametrize("arch", ["grok-1-314b", "jamba-v0.1-52b"])
def test_grad_flows_everywhere(arch):
    """Every parameter receives nonzero gradient (no dead branches)."""
    cfg = smoke_arch(arch)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 512),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, 512),
    }
    g = jax.grad(lambda p: lm_loss(cfg, p, batch)[0])(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(g)
    dead = [
        "/".join(str(p) for p in path)
        for path, leaf in flat
        if float(jnp.max(jnp.abs(leaf))) == 0.0
    ]
    assert not dead, f"dead params: {dead[:5]}"
