"""kmeans_assign kernel sweeps + RGCN ablation-switch behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graphs import build_kernel_graph, pad_batch
from repro.core import rgcn as rgcn_mod
from repro.core.rgcn import RGCNConfig
from repro.kernels.kmeans_assign.ops import kmeans_assign
from repro.kernels.kmeans_assign.ref import kmeans_assign_ref
from repro.tracing.templates import make_kernel


@pytest.mark.parametrize("n,d,k,bn", [
    (100, 16, 4, 32), (256, 64, 8, 128), (513, 32, 5, 256), (7, 8, 3, 64),
])
def test_kmeans_assign_matches_ref(n, d, k, bn):
    kx, kc = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(kx, (n, d))
    c = jax.random.normal(kc, (k, d))
    l1, d1 = kmeans_assign(x, c, block_n=bn, interpret=True)
    l2, d2 = kmeans_assign_ref(x, c)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def _batch():
    ks = [make_kernel(f"k{i}", "gemm",
                      {"M": 128 * (i + 1), "N": 128, "K": 128}, i, seed=i)
          for i in range(3)]
    graphs = [build_kernel_graph(k.trace(2, 48)) for k in ks]
    b, mw = pad_batch(graphs)
    return {k: jnp.asarray(v) for k, v in b.items()}, mw


def test_ablation_no_vstats_changes_embeddings():
    batch, mw = _batch()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), RGCNConfig())
    z_full = rgcn_mod.encode(p, RGCNConfig(), batch, mw)
    z_abl = rgcn_mod.encode(p, RGCNConfig(use_vstats=False), batch, mw)
    assert not np.allclose(np.asarray(z_full), np.asarray(z_abl))


def test_ablation_cf_only_ignores_dataflow():
    """With only control-flow relations, zeroing data-flow edge masks by
    hand must give identical embeddings (the switch is equivalent)."""
    batch, mw = _batch()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), RGCNConfig())
    rc = RGCNConfig(relations_used=(0,))
    z1 = rgcn_mod.encode(p, rc, batch, mw)
    manual = dict(batch)
    manual["edge_mask"] = batch["edge_mask"] * (batch["edge_type"] == 0)
    z2 = rgcn_mod.encode(p, RGCNConfig(), manual, mw)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-5)
