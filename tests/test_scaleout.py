"""Multi-device scale-out of the train + plan engines (DESIGN.md §11).

Every test here runs on a SIMULATED mesh: the `scaleout` marker requires
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the environment
before pytest launches (the scaleout-smoke CI job sets it); on an unforced
interpreter the whole module auto-skips (see conftest).

Covered:
- sharded training matches the single-device trajectory within float
  tolerance, and is BIT-exact across refits at a fixed device count;
- checkpoint interrupt/resume stays bit-exact on a sharded mesh;
- the sharded PlanEngine dispatch returns labels/K identical to the
  sequential reference, with ZERO recompiles on the second dispatch
  (device-count-aware executable-cache keys);
- error-feedback int8 gradient compression: the shard_map collective
  tracks the exact f32 mean, and the value-level path
  (``tc.opt.grad_compress``) still converges under sharding;
- the benchmark artifact gates (>=3x modelled steps/s and plans/s at 8
  devices, 0 warm recompiles) via a slow subprocess smoke.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.rgcn import RGCNConfig
from repro.core.train import ContrastiveTrainer, FitInterrupted, GCLTrainConfig
from repro.launch.mesh import make_data_mesh
from repro.sampling.engine import PlanEngine
from repro.tracing.templates import make_kernel

pytestmark = pytest.mark.scaleout


def _graphs(n=8, cap=48):
    from repro.core.graphs import build_kernel_graph

    ks = [make_kernel(f"k{i}", "gemm",
                      {"M": 128 * (i % 3 + 1), "N": 128, "K": 128}, i, seed=i)
          for i in range(n)]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=cap))
            for k in ks]


GRAPHS = _graphs()


def _tc(**kw):
    base = dict(steps=8, batch_size=4, scan_chunk=4, log_every=50)
    base.update(kw)
    return GCLTrainConfig(**base)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _losses(info):
    return np.array([h["loss"] for h in info["history"]])


# ---------------------------------------------------------------------------
# training: sharded-vs-single parity, fixed-width determinism, resume
# ---------------------------------------------------------------------------


def test_sharded_fit_matches_single_device():
    """The 8-wide data-parallel fit must track the single-device trajectory
    within float tolerance (same math, different reduction order)."""
    p1, i1 = ContrastiveTrainer(RGCNConfig(), _tc()).fit(GRAPHS)
    p8, i8 = ContrastiveTrainer(
        RGCNConfig(), _tc(), mesh_rules=make_data_mesh(8)).fit(GRAPHS)
    assert i8["data_shards"] == 8 and i1["data_shards"] == 1
    np.testing.assert_allclose(_losses(i1), _losses(i8),
                               atol=5e-5, rtol=5e-5)
    for a, b in zip(_leaves(p1), _leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)
    assert np.isclose(i1["val_loss"], i8["val_loss"], atol=5e-5)


def test_fixed_device_count_refit_bit_exact():
    """f32 determinism holds AT a fixed mesh width: two fits on the same
    8-wide mesh produce bit-identical parameters."""
    rules = make_data_mesh(8)
    p_a, _ = ContrastiveTrainer(RGCNConfig(), _tc(),
                                mesh_rules=rules).fit(GRAPHS)
    p_b, _ = ContrastiveTrainer(RGCNConfig(), _tc(),
                                mesh_rules=rules).fit(GRAPHS)
    for a, b in zip(_leaves(p_a), _leaves(p_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_sharded_checkpoint_resume_bit_exact(tmp_path):
    """Interrupt + resume on the 8-wide mesh == the uninterrupted sharded
    fit, bit for bit (checkpoints are device-layout-agnostic host arrays,
    so the resume protocol is untouched by sharding)."""
    rules = make_data_mesh(8)
    tc = _tc(steps=8, checkpoint_every=4)
    ck = str(tmp_path / "ck")
    with pytest.raises(FitInterrupted):
        ContrastiveTrainer(RGCNConfig(), tc, mesh_rules=rules).fit(
            GRAPHS, checkpoint_dir=ck, interrupt_after=4)
    p_res, i_res = ContrastiveTrainer(RGCNConfig(), tc,
                                      mesh_rules=rules).fit(
        GRAPHS, checkpoint_dir=ck)
    assert i_res["resumed_from"] >= 4
    p_full, i_full = ContrastiveTrainer(RGCNConfig(), tc,
                                        mesh_rules=rules).fit(GRAPHS)
    np.testing.assert_array_equal(_losses(i_res), _losses(i_full))
    for a, b in zip(_leaves(p_res), _leaves(p_full)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# gradient compression under sharding
# ---------------------------------------------------------------------------


def test_compressed_psum_mean_tracks_exact():
    """The error-feedback int8 collective must agree with the exact f32
    psum mean within the int8 quantization grid (amax/127 per tensor),
    and its residual must be exactly what went uncommunicated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim.grad_compress import compressed_psum_mean, psum_mean

    mesh = make_data_mesh(8).mesh
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(8, 16, 4)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8, 7)), jnp.float32)}
    err = jax.tree_util.tree_map(jnp.zeros_like, grads)
    spec = jax.tree_util.tree_map(lambda _: P("data"), grads)

    exact = jax.jit(shard_map(
        lambda g: psum_mean(g, "data"), mesh=mesh,
        in_specs=(spec,), out_specs=spec))(grads)
    approx, new_err = jax.jit(shard_map(
        lambda g, e: compressed_psum_mean(g, e, "data"), mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec)))(grads, err)

    for k in grads:
        a, b = np.asarray(exact[k]), np.asarray(approx[k])
        grid = np.abs(np.asarray(grads[k])).max() / 127.0
        assert np.abs(a - b).max() <= grid + 1e-6
        # error feedback: residual == local grad minus what was sent
        assert np.isfinite(np.asarray(new_err[k])).all()
        assert np.abs(np.asarray(new_err[k])).max() <= grid + 1e-6


def test_grad_compress_convergence_sharded():
    """Value-level EF-int8 (tc.opt.grad_compress) under the 8-wide mesh:
    training still converges to the same neighborhood as uncompressed —
    final loss within 15% — and the compression state survives the fit."""
    import dataclasses

    tc_off = _tc(steps=12)
    tc_on = dataclasses.replace(
        tc_off, opt=dataclasses.replace(tc_off.opt, grad_compress=True))
    rules = make_data_mesh(8)
    _, i_off = ContrastiveTrainer(RGCNConfig(), tc_off,
                                  mesh_rules=rules).fit(GRAPHS)
    _, i_on = ContrastiveTrainer(RGCNConfig(), tc_on,
                                 mesh_rules=rules).fit(GRAPHS)
    l_off, l_on = _losses(i_off), _losses(i_on)
    assert l_on[-1] <= l_on[0]  # it trains
    assert abs(l_on[-1] - l_off[-1]) <= 0.15 * abs(l_off[-1])


# ---------------------------------------------------------------------------
# plan engine: sharded dispatch parity + zero-recompile warm path
# ---------------------------------------------------------------------------


def _embs(n=16, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(40 + 3 * i, dim)).astype(np.float32)
            for i in range(n)]


def test_plan_engine_sharded_matches_sequential():
    """Labels and chosen K from the sharded sweep dispatch must equal the
    sequential reference exactly — sharding the program axis cannot change
    any program's math."""
    embs = _embs()
    sharded = PlanEngine(k_max=6, iters=8, max_batch=2,
                         data_devices=8).cluster_many(embs)
    reference = PlanEngine(k_max=6, iters=8,
                           engine="sequential").cluster_many(embs)
    for (lab, info), (lab_r, info_r) in zip(sharded, reference):
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_r))
        assert info["k"] == info_r["k"]


def test_sharded_dispatch_scales_chunk_cap():
    """One sharded dispatch serves data_devices x max_batch programs (16
    same-bucket programs, cap = 2 x 8)."""
    rng = np.random.default_rng(2)
    embs = [rng.normal(size=(40 + i, 8)).astype(np.float32)
            for i in range(16)]  # all in the 64-points bucket
    eng = PlanEngine(k_max=6, iters=8, max_batch=2, data_devices=8)
    eng.cluster_many(embs)
    assert eng.stats["dispatches"] == 1
    assert eng.engine_stats()["data_shards"] == 8


def test_zero_recompiles_on_second_sharded_dispatch():
    """The executable-cache key is device-count-aware, so the warm sharded
    path never re-lowers: the 2nd identical dispatch adds 0 builds."""
    from repro.core.clustering import engine_stats

    embs = _embs(n=8, dim=8, seed=3)
    eng = PlanEngine(k_max=6, iters=8, max_batch=1, data_devices=8)
    eng.cluster_many(embs)
    builds0 = engine_stats()["builds"]
    eng.cluster_many(embs)
    assert engine_stats()["builds"] - builds0 == 0


def test_warmup_covers_sharded_dispatch():
    """warm_sweep warms the SAME (sharded) key cluster_many later serves
    from — a warmed engine compiles nothing at dispatch time."""
    from repro.core.clustering import engine_stats

    embs = _embs(n=8, dim=7, seed=5)
    eng = PlanEngine(k_max=5, iters=8, max_batch=1, data_devices=8)
    eng.warmup([(64, 7)], batch_sizes=[8])
    builds0 = engine_stats()["builds"]
    eng.cluster_many(embs)
    assert engine_stats()["builds"] - builds0 == 0


# ---------------------------------------------------------------------------
# benchmark gates (slow: re-runs the bench in a subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_bench_scaleout_gates(tmp_path):
    """The committed acceptance gates: >=3x modelled steps/s and plans/s at
    8 simulated devices vs 1, 0 recompiles on the warm sharded path, and a
    real collective-bytes win from gradient compression."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)  # the bench pins its own device count
    subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_scaleout", "--smoke",
         "--devices", "1,8"],
        check=True, env=env, cwd=repo, timeout=560)
    with open(os.path.join(repo, "BENCH_scaleout.json")) as f:
        doc = json.load(f)
    h = doc["headline"]
    assert h["train_modelled_speedup"] >= 3.0
    assert h["plan_modelled_speedup"] >= 3.0
    assert h["warm_recompiles"] == 0
    assert h["grad_compress_bytes_reduction"] >= 1.5
    # wall-clock floors: simulated devices share the physical cores, so we
    # only require the sharded path not to collapse (no-regression floor)
    t, p = doc["train"], doc["plan"]
    assert t["8"]["steps_per_s_wall"] >= 0.2 * t["1"]["steps_per_s_wall"]
    assert p["8"]["plans_per_s_wall"] >= 0.2 * p["1"]["plans_per_s_wall"]
