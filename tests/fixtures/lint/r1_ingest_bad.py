"""Known-bad R1: an ingest-style worker pool where the consumer converts
every future's compiled-engine output to numpy INSIDE the dispatch loop —
one device round trip per submitted item, hidden behind the executor hop
(``pool.submit`` is a call edge, so the linter sees the worker dispatch)."""
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


def make_engine():
    return jax.jit(lambda b: b * 2.0)  # lint: allow[R2] fixture factory


def encode(item):
    step = make_engine()
    return step(item)


def ingest_loop(items):
    out = []
    with ThreadPoolExecutor(max_workers=2) as pool:
        for item in items:
            fut = pool.submit(encode, item)
            out.append(np.asarray(fut.result()))  # R1b: sync per future
    return out
