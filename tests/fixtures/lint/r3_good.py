"""Known-good R3: seed-derived keys, split before every draw."""
import jax


def draws(seed):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.uniform(k2, (3,))
    return a + b
