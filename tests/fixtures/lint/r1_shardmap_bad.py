"""Known-bad R1: host syncs inside shard_map-traced bodies (both the
``jax.experimental.shard_map`` import and the graduated ``jax.shard_map``
alias must mark the body as traced)."""
import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def psum_mean(mesh):
    def body(g):
        total = jax.lax.psum(g, "data")
        scale = float(total[0])            # R1a: float() in a traced body
        return np.asarray(total) * scale   # R1a: numpy on a traced value

    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))


def scaled(mesh):
    def body2(x):
        return x * float(x.mean())         # R1a via the jax.shard_map alias

    return jax.shard_map(body2, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))
