"""Known-good R2: the three cached-executable patterns the repo uses."""
import functools

import jax

_CACHE = {}

top_level = jax.jit(lambda x: x + 1)        # compiled once per process


@functools.lru_cache(maxsize=8)
def cached_engine(k):
    return jax.jit(lambda x: x * k)         # cached by the lru decorator


def dict_cached(k):
    if k not in _CACHE:
        _CACHE[k] = jax.jit(lambda x: x + k)  # cache-dict store
    return _CACHE[k]


class Holder:
    def setup(self):
        self._fn = jax.jit(lambda x: x - 1)   # instance-attr store
