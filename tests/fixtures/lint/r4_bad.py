"""Known-bad R4: a buffer read after being donated."""
import jax


def update(state, batch):
    return state


def bad_fit(state, batches):
    step = jax.jit(update, donate_argnums=(0,))  # lint: allow[R2] fixture
    out = step(state, batches[0])
    print(state)                # R4: `state` was donated to step above
    return out
