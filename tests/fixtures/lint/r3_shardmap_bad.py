"""Known-bad R3: hard-coded + reused PRNG key inside a shard_map body
(every shard would draw IDENTICAL noise — data-parallel augmentation
silently degenerates to one effective sample)."""
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def noisy_mean(mesh):
    def body(g):
        key = jax.random.PRNGKey(0)          # R3: hard-coded literal key
        noise = jax.random.normal(key, g.shape)
        mask = jax.random.bernoulli(key, 0.5, g.shape)  # R3: reused, no split
        return jax.lax.psum(g + noise * mask, "data")

    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))
