"""Known-bad R1: host syncs inside a traced region and a dispatch loop."""
import jax
import numpy as np


@jax.jit
def traced_sync(x):
    s = float(x.sum())          # R1a: float() inside a jitted function
    return np.asarray(x) + s    # R1a: np.asarray inside a jitted function


def make_step():
    return jax.jit(lambda s: s * 2.0)


def dispatch_loop(xs):
    step = make_step()
    out = []
    for x in xs:
        y = step(x)
        out.append(np.unique(y))   # R1b: numpy on engine output per iter
    return out
