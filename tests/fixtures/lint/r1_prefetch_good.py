"""Known-good R1: one-ahead prefetch staging (the core/train.py `_OneAhead`
shape).  numpy touches only host-side INPUTS on the staging thread; device
outputs accumulate asynchronously and cross to the host ONCE after the
loop, so staging genuinely overlaps the in-flight dispatch."""
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


def make_engine():
    return jax.jit(lambda b: b * 2.0)  # lint: allow[R2] fixture factory


def stage(item):
    # host-side staging: numpy on the input (not an engine output) is legal
    return jax.device_put(np.ascontiguousarray(item))


def prefetch_loop(items):
    step = make_engine()
    out = []
    with ThreadPoolExecutor(max_workers=1) as pool:
        fut = pool.submit(stage, items[0])
        for nxt in items[1:]:
            batch = fut.result()
            fut = pool.submit(stage, nxt)   # staging rides the dispatch
            out.append(step(batch))
        out.append(step(fut.result()))
    return [np.asarray(z) for z in out]     # single post-loop host pull
