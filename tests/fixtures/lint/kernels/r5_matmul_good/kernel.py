"""Known-good R5d: accumulator dtype pinned to f32."""
import jax
import jax.numpy as jnp


def matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
