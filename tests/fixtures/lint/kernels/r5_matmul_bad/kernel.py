"""Known-bad R5d: kernel matmul without an explicit f32 accumulator."""
import jax


def matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())))
