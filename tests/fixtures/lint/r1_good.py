"""Known-good R1: trace-time shape math, host-side syncs, and a waiver."""
import jax
import numpy as np


@jax.jit
def traced_shape_math(x):
    scale = np.sqrt(3.0)        # constant-arg numpy: trace-time, legal
    return x * scale


def host_loop(xs):
    # plain python over host data — float() here never touches a device
    return [float(v) for v in xs]


def make_step():
    return jax.jit(lambda s: s * 2.0)  # lint: allow[R2] fixture factory


def waived_dispatch_loop(xs):
    step = make_step()
    out = []
    for x in xs:
        y = step(x)
        # lint: allow[R1] parity reference syncs per iteration by design
        out.append(np.unique(y))
    return out
