"""Known-bad R5: hard-coded interpret, true-division grid, raw bf16 cast."""
import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp


def kernel_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def call_site(op, x, n):
    y = op(x, interpret=True)                 # R5a: bypasses default_interpret
    z = pl.pallas_call(
        kernel_body,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(n / 128,),                      # R5b: float grid on odd n
    )(y)
    return z.astype(jnp.bfloat16)             # R5c: bypasses precision policy
