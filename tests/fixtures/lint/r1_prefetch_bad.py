"""Known-bad R1: a one-ahead prefetch loop that syncs the engine output
every iteration — the host round trip serializes exactly the dispatch the
staging thread was supposed to hide behind."""
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


def make_engine():
    return jax.jit(lambda b: b * 2.0)  # lint: allow[R2] fixture factory


def stage(item):
    return jax.device_put(np.ascontiguousarray(item))


def prefetch_loop(items):
    step = make_engine()
    out = []
    with ThreadPoolExecutor(max_workers=1) as pool:
        fut = pool.submit(stage, items[0])
        for nxt in items[1:]:
            batch = fut.result()
            fut = pool.submit(stage, nxt)
            z = step(batch)
            out.append(np.asarray(z))   # R1b: sync rides every dispatch
    return out
