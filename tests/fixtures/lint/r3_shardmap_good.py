"""Known-good R3: the key enters as an argument, is folded per shard
(axis_index keeps shards decorrelated), and split once per consumer."""
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def noisy_mean(mesh):
    def body(g, key):
        key = jax.random.fold_in(key, jax.lax.axis_index("data"))
        k1, k2 = jax.random.split(key)
        noise = jax.random.normal(k1, g.shape)
        mask = jax.random.bernoulli(k2, 0.5, g.shape)
        return jax.lax.psum(g + noise * mask, "data")

    return shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                     out_specs=P("data"))
