"""Known-bad R2: per-call and in-loop jax.jit with no process-wide cache."""
import jax


def per_call(f, x):
    g = jax.jit(f)              # R2: fresh trace on every call
    return g(x)


def in_loop(f, xs):
    out = []
    for x in xs:
        g = jax.jit(f)          # R2: fresh trace on every ITERATION
        out.append(g(x))
    return out
