"""Known-good R4: the donated name is rebound by the same statement."""
import jax


def update(state, batch):
    return state


def good_fit(state, batches):
    step = jax.jit(update, donate_argnums=(0,))  # lint: allow[R2] fixture
    for batch in batches:
        state = step(state, batch)   # rebind: old buffer never read again
    return state
