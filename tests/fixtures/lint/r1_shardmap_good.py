"""Known-good R1: shard_map bodies stay on-device (pure lax/jnp ops —
cross-shard reductions via collectives, never host round-trips)."""
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def psum_mean(mesh):
    def body(g):
        n = jax.lax.psum(jnp.ones(()), "data")
        return jax.lax.psum(g, "data") / n

    return shard_map(body, mesh=mesh, in_specs=(P("data"),),
                     out_specs=P("data"))


def scaled(mesh):
    def body2(x):
        return x * jnp.mean(x)

    return jax.shard_map(body2, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))
