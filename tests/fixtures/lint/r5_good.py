"""Known-good R5: interpret passthrough, cdiv grid, policy-routed dtype."""
import jax
import jax.experimental.pallas as pl


def kernel_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def call_site(op, x, n, policy, interpret=None):
    y = op(x, interpret=interpret)            # resolved by default_interpret
    z = pl.pallas_call(
        kernel_body,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(pl.cdiv(n, 128),),
    )(y)
    return policy.cast_compute(z)
