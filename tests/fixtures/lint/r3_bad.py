"""Known-bad R3: literal key + reuse across samplers without split."""
import jax


def draws():
    key = jax.random.PRNGKey(0)             # R3: hard-coded literal key
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))       # R3: key reused, no split
    return a + b
