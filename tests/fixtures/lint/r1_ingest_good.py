"""Known-good R1: the same ingest-style worker pool, but futures of
compiled work accumulate asynchronously and cross to the host ONCE after
the loop — the executor genuinely overlaps the in-flight dispatches."""
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np


def make_engine():
    return jax.jit(lambda b: b * 2.0)  # lint: allow[R2] fixture factory


def encode(item):
    step = make_engine()
    return step(item)


def ingest_loop(items):
    with ThreadPoolExecutor(max_workers=2) as pool:
        futs = [pool.submit(encode, item) for item in items]
        out = [fut.result() for fut in futs]
    return [np.asarray(z) for z in out]  # single post-loop host pull
