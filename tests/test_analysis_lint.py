"""The linter linted: fixture-driven unit tests for rules R1–R5, the
baseline workflow, and the runtime sanitizers (recompile guard + NaN
tripwire).  See DESIGN.md §10."""

import dataclasses
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.lint import (
    BASELINE_PATH, diff_baseline, lint_paths, load_baseline,
)
from repro.analysis.sanitize import (
    NonFiniteError, RecompileError, check_finite, nan_tripwire,
    recompile_guard,
)
from repro.core import clustering
from repro.core.clustering import sweep_cluster_stack, warm_sweep

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"


def rules_of(path) -> set:
    return {f.rule for f in lint_paths([path])}


# ---------------------------------------------------------------------------
# static rules: every rule catches its known-bad and passes its known-good
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule,bad,good", [
    ("R1", "r1_bad.py", "r1_good.py"),
    ("R2", "r2_bad.py", "r2_good.py"),
    ("R3", "r3_bad.py", "r3_good.py"),
    ("R1", "r1_shardmap_bad.py", "r1_shardmap_good.py"),
    ("R1", "r1_prefetch_bad.py", "r1_prefetch_good.py"),
    ("R1", "r1_ingest_bad.py", "r1_ingest_good.py"),
    ("R3", "r3_shardmap_bad.py", "r3_shardmap_good.py"),
    ("R4", "r4_bad.py", "r4_good.py"),
    ("R5", "r5_bad.py", "r5_good.py"),
])
def test_rule_fixture_pair(rule, bad, good):
    assert rule in rules_of(FIXTURES / bad), f"{rule} missed {bad}"
    assert not lint_paths([FIXTURES / good]), f"false positive in {good}"


def test_r5_kernel_matmul_accumulator():
    bad = FIXTURES / "kernels" / "r5_matmul_bad" / "kernel.py"
    good = FIXTURES / "kernels" / "r5_matmul_good" / "kernel.py"
    findings = lint_paths([bad])
    assert any(f.rule == "R5" and "preferred_element_type" in f.message
               for f in findings)
    assert not lint_paths([good])


def test_fused_kernel_entries_registered_in_callgraph():
    """The rgcn_fused entry points are pinned trace entries through the
    explicit KERNEL_ENTRIES registry, independent of decorator detection —
    R1/R5 must keep looking inside the fused encode front-end."""
    import ast

    from repro.analysis.callgraph import (
        KERNEL_ENTRIES, ModuleIndex, build_graph,
    )
    from repro.analysis.lint import module_name_for

    fused = {fid for fid in KERNEL_ENTRIES if ".rgcn_fused." in fid}
    assert len(fused) == 3
    indexes = []
    for rel in ("src/repro/kernels/rgcn_fused/kernel.py",
                "src/repro/kernels/rgcn_fused/ops.py"):
        path = REPO / rel
        tree = ast.parse(path.read_text(), filename=str(path))
        indexes.append(ModuleIndex(str(path), module_name_for(path), tree))
    funcs = build_graph(indexes)
    for fid in fused:
        assert fid in funcs, f"registered kernel entry {fid} not found"
        assert funcs[fid].traced_entry and funcs[fid].traced


def test_ingest_entries_registered_in_callgraph():
    """The trace->graph ingestion roots are pinned HOST entries through the
    INGEST_ENTRIES registry: they exist in the graph, the pool.submit hop
    links the worker body as a real call edge, and none of them is
    reachable from a jit/scan/vmap trace (R1 would flag that)."""
    import ast

    from repro.analysis.callgraph import (
        INGEST_ENTRIES, ModuleIndex, build_graph,
    )
    from repro.analysis.lint import module_name_for

    assert len(INGEST_ENTRIES) >= 4
    indexes = []
    for rel in ("src/repro/ingest/engine.py",
                "src/repro/tracing/tracer.py"):
        path = REPO / rel
        tree = ast.parse(path.read_text(), filename=str(path))
        indexes.append(ModuleIndex(str(path), module_name_for(path), tree))
    funcs = build_graph(indexes)
    for fid in INGEST_ENTRIES:
        assert fid in funcs, f"registered ingest entry {fid} not found"
        assert funcs[fid].host_entry
        assert not funcs[fid].traced, f"{fid} must stay host-side"
    # the executor hop is a call edge: iter_graphs -> _build_one via submit
    it = funcs["repro.ingest.engine:IngestEngine.iter_graphs"]
    assert "repro.ingest.engine:IngestEngine._build_one" in it.calls


def test_r1_flags_both_traced_and_dispatch_loop_sites():
    findings = [f for f in lint_paths([FIXTURES / "r1_bad.py"])
                if f.rule == "R1"]
    symbols = {f.symbol for f in findings}
    assert "traced_sync" in symbols          # R1a inside the jitted fn
    assert "dispatch_loop" in symbols        # R1b on the engine output
    assert len(findings) >= 3


def test_r1_shard_map_bodies_are_traced():
    """Both spellings mark the wrapped body traced: the
    jax.experimental.shard_map import AND the graduated jax.shard_map
    alias (each fixture body syncs, so each must be flagged)."""
    findings = [f for f in lint_paths([FIXTURES / "r1_shardmap_bad.py"])
                if f.rule == "R1"]
    symbols = {f.symbol for f in findings}
    assert "psum_mean.body" in symbols      # from-import spelling
    assert "scaled.body2" in symbols        # jax.shard_map alias
    assert len(findings) == 3


def test_r2_distinguishes_loop_from_per_call():
    messages = [f.message for f in lint_paths([FIXTURES / "r2_bad.py"])
                if f.rule == "R2"]
    assert any("inside a loop" in m for m in messages)
    assert any("per call" in m for m in messages)


def test_r3_flags_literal_and_reuse():
    messages = [f.message for f in lint_paths([FIXTURES / "r3_bad.py"])
                if f.rule == "R3"]
    assert any("hard-coded" in m for m in messages)
    assert any("reused" in m for m in messages)


def test_waiver_comment_suppresses_rule():
    # r1_good's dispatch loop is the SAME shape as r1_bad's — only the
    # inline waiver separates them
    assert not [f for f in lint_paths([FIXTURES / "r1_good.py"])
                if f.symbol == "waived_dispatch_loop"]


# ---------------------------------------------------------------------------
# repo-wide run vs the checked-in baseline
# ---------------------------------------------------------------------------


def test_repo_run_matches_baseline_exactly(monkeypatch):
    monkeypatch.chdir(REPO)   # baseline keys are repo-relative paths
    findings = lint_paths(["src/repro"])
    baseline = load_baseline(BASELINE_PATH)
    new, accepted, stale = diff_baseline(findings, baseline)
    assert not new, "new lint findings:\n" + "\n".join(
        f.render() for f in new)
    assert not stale, "stale baseline entries (fixed? remove them):\n" \
        + "\n".join(stale)
    assert len(accepted) == sum(baseline.values())


def test_baseline_diff_detects_new_and_stale():
    findings = lint_paths([FIXTURES / "r3_bad.py"])
    assert findings
    baseline = load_baseline(BASELINE_PATH)  # src/repro keys: all stale here
    new, accepted, stale = diff_baseline(findings, baseline)
    assert len(new) == len(findings) and not accepted
    assert set(stale) == set(baseline)


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------

# a (points, dim, k_max, iters) combo no other test warms — the executable
# cache and build counters are process-wide
_COLD = dict(d=7, k_max=5, iters=11)


def test_recompile_guard_passes_on_warm_path():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(20, 8)).astype(np.float32)
    warm_sweep(1, x.shape[0], x.shape[1], k_max=6, iters=9)
    with recompile_guard(label="warm sweep") as guard:
        sweep_cluster_stack([x], k_max=6, iters=9)
    assert guard.builds == 0


def test_recompile_guard_trips_when_warmup_skipped():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(20, _COLD["d"])).astype(np.float32)
    with pytest.raises(RecompileError, match="exceed the budget"):
        with recompile_guard(label="cold sweep"):
            sweep_cluster_stack([x], k_max=_COLD["k_max"],
                                iters=_COLD["iters"])
    # and the stats the guard reported match the engine counters' story
    with recompile_guard(label="now warm") as guard:
        sweep_cluster_stack([x], k_max=_COLD["k_max"], iters=_COLD["iters"])
    assert guard.builds == 0
    assert clustering.ENGINE_STATS["builds"] > 0


def test_check_finite_walks_nested_containers_and_dataclasses():
    @dataclasses.dataclass
    class Box:
        w: np.ndarray
        meta: dict

    ok = Box(w=np.ones(3, np.float32), meta={"loss": 0.5, "n": 7})
    check_finite(ok)   # no raise
    bad = Box(w=np.array([1.0, np.nan], np.float32), meta={})
    with pytest.raises(NonFiniteError, match=r"\.w"):
        check_finite(bad)
    with pytest.raises(NonFiniteError, match="loss"):
        check_finite({"loss": float("inf")})
    # integer arrays are never "non-finite"
    check_finite({"labels": np.array([1, 2, 3])})


def test_nan_tripwire_wraps_callables():
    @nan_tripwire
    def good():
        return {"w": np.zeros(2, np.float32)}

    assert good()["w"].shape == (2,)

    bad = nan_tripwire(lambda: np.array([np.inf], np.float32), name="plan")
    with pytest.raises(NonFiniteError, match="plan"):
        bad()


def test_plan_service_sanitize_isolates_nonfinite_plans():
    from repro.serving.service import PlanService

    with PlanService(max_batch=2, sanitize=True) as svc:
        poisoned = svc._sanitize_plan({"weights": np.array([np.nan])})
        assert isinstance(poisoned, NonFiniteError)
        clean = {"weights": np.array([0.5, 0.5])}
        assert svc._sanitize_plan(clean) is clean
        err = RuntimeError("upstream")   # existing failures pass through
        assert svc._sanitize_plan(err) is err
    assert svc.stats()["sanitize_trips"] == 1
