"""Property-based tests (hypothesis) for HRG construction invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.graphs import NODE_INSTR, NODE_VAR, build_kernel_graph
from repro.tracing.isa import OPCODE_IDS
from repro.tracing.templates import make_kernel
from repro.tracing.tracer import WarpTrace


def _random_trace(rng, n):
    """Random but well-formed warp trace."""
    opcode = rng.integers(0, len(OPCODE_IDS), n).astype(np.int16)
    pc = (np.arange(n) * 16).astype(np.int32)
    mask = np.full(n, 0xFFFFFFFF, np.uint32)
    dest = rng.integers(-1, 8, (n, 2)).astype(np.int16)
    src = rng.integers(-1, 8, (n, 3)).astype(np.int16)
    mem_width = np.where(rng.random(n) < 0.2, 4, 0).astype(np.int16)
    mem_addr = np.where(mem_width > 0, rng.integers(0, 1 << 20, n) * 64, 0)
    vstats = rng.standard_normal((n, 8)).astype(np.float32)
    return WarpTrace(opcode, pc, mask, dest, src, mem_width,
                     mem_addr.astype(np.int64), vstats)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 60), st.integers(0, 10_000))
def test_hrg_invariants(n, seed):
    rng = np.random.default_rng(seed)
    g = build_kernel_graph([_random_trace(rng, n)])

    # edges reference valid nodes, types in [0,4)
    assert g.edge_src.min(initial=0) >= 0
    assert g.edge_dst.max(initial=0) < g.n_nodes
    assert set(np.unique(g.edge_type)).issubset({0, 1, 2, 3})

    # exactly n instruction nodes; control-flow chain has n-1 edges
    assert int((g.node_type == NODE_INSTR).sum()) == n
    cf = g.edge_type == 0
    assert int(cf.sum()) == n - 1
    # control flow is the temporal chain i -> i+1
    assert np.array_equal(np.sort(g.edge_src[cf]), np.arange(n - 1))
    assert np.array_equal(np.sort(g.edge_dst[cf]), np.arange(1, n))

    # SSA: every variable node has at most one incoming data-dst edge
    dst_w = g.edge_dst[g.edge_type == 2]
    uniq, counts = np.unique(dst_w, return_counts=True)
    assert (counts == 1).all()
    # data-dst edges land on variable nodes only
    assert (g.node_type[dst_w] == NODE_VAR).all()
    # data-src edges originate from variable nodes only
    src_r = g.edge_src[g.edge_type == 1]
    assert (g.node_type[src_r] == NODE_VAR).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_ssa_reads_see_most_recent_write(n, seed):
    """Paper Fig. 3 (node R4): a read connects to the LATEST prior version."""
    rng = np.random.default_rng(seed)
    tr = _random_trace(rng, n)
    g = build_kernel_graph([tr])
    # reconstruct: for each data-src edge var -> instr, the var must be
    # either an init node or a write node whose writing instruction is the
    # most recent write of that register before the reading instruction.
    # Build write-node -> (reg, instr) map from data-dst edges.
    wmap = {}
    for e in np.nonzero(g.edge_type == 2)[0]:
        wi, vn = int(g.edge_src[e]), int(g.edge_dst[e])
        wmap.setdefault(vn, []).append(wi)
    for e in np.nonzero(g.edge_type == 1)[0]:
        vn, ri = int(g.edge_src[e]), int(g.edge_dst[e])
        if vn not in wmap:
            continue  # init node
        wi = wmap[vn][0]
        assert wi < ri or wi == ri  # writes sort before reads only when < i
        regs_written = set(tr.dest[wi][tr.dest[wi] >= 0].tolist())
        regs_read = set(tr.src[ri][tr.src[ri] >= 0].tolist())
        shared = regs_written & regs_read
        assert shared, "read edge from a var whose reg isn't read"
        # no later write to that reg strictly between wi and ri
        for r in shared:
            between = [
                j for j in range(wi + 1, ri)
                if r in tr.dest[j][tr.dest[j] >= 0].tolist()
            ]
            if not between:
                return  # at least one shared reg has no intervening write
        assert False, "stale version used"


def test_line_sharing_structure():
    """Loads hitting the same 128B line share a memory-variable node."""
    rng = np.random.default_rng(0)
    n = 8
    tr = _random_trace(rng, n)
    tr.mem_width[:] = 4
    tr.opcode[:] = OPCODE_IDS["LDG"]
    tr.dest[:] = -1
    tr.dest[:, 0] = np.arange(n)
    tr.mem_addr[:] = [0, 32, 64, 96, 128, 160, 4096, 8192]  # lines 0,0,0,0,1,1,32,64
    g = build_kernel_graph([tr])
    n_mem_vars = int(((g.node_type == NODE_VAR) & (g.token == 1)).sum())
    assert n_mem_vars == 4  # 4 distinct lines


def test_kernel_graph_union_of_warps():
    k = make_kernel("t", "gemm", {"M": 128, "N": 128, "K": 128}, 0, 1)
    g1 = build_kernel_graph(k.trace(cap_warps=1, cap_instr=64))
    g2 = build_kernel_graph(k.trace(cap_warps=2, cap_instr=64))
    assert g2.n_warps == 2
    assert g2.n_nodes > g1.n_nodes
    # warp ids partition nodes
    assert set(np.unique(g2.warp_id)) == {0, 1}
