"""InfoNCE + RGCN model properties: loss semantics, padding invariance,
pallas-path equivalence, augmentation behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import rgcn as rgcn_mod
from repro.core.augment import augment_view
from repro.core.contrastive import info_nce
from repro.core.graphs import build_kernel_graph, pad_batch
from repro.core.rgcn import RGCNConfig
from repro.tracing.templates import make_kernel


# ---------------------------------------------------------------------------
# InfoNCE
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(0, 1000))
def test_infonce_symmetric(b, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    z1 = jax.random.normal(k1, (b, 8))
    z2 = jax.random.normal(k2, (b, 8))
    l12, _ = info_nce(z1, z2, 0.05)
    l21, _ = info_nce(z2, z1, 0.05)
    assert np.isclose(float(l12), float(l21), atol=1e-5)


def test_infonce_perfect_alignment_low_loss():
    b, d = 8, 16
    z = jax.random.normal(jax.random.PRNGKey(0), (b, d)) * 10
    loss_aligned, m = info_nce(z, z, 0.05)
    z_shuf = z[jnp.roll(jnp.arange(b), 1)]
    loss_misaligned, _ = info_nce(z, z_shuf, 0.05)
    assert float(loss_aligned) < 0.1
    assert float(loss_misaligned) > float(loss_aligned) + 1.0
    assert float(m["nce_acc"]) == 1.0


def test_infonce_lower_bound():
    """loss >= 0 (it's a cross-entropy)."""
    for seed in range(5):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        z1 = jax.random.normal(k1, (6, 4))
        z2 = jax.random.normal(k2, (6, 4))
        loss, _ = info_nce(z1, z2, 0.05)
        assert float(loss) >= 0


# ---------------------------------------------------------------------------
# RGCN encoder
# ---------------------------------------------------------------------------


def _graphs(n=4):
    ks = [
        make_kernel(f"k{i}", "gemm",
                    {"M": 128 * (i + 1), "N": 128, "K": 128}, i, seed=i)
        for i in range(n)
    ]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=48)) for k in ks]


def test_padding_invariance():
    """Extra padded nodes/edges must not change kernel embeddings."""
    graphs = _graphs(3)
    rc = RGCNConfig()
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), rc)
    b1, w1 = pad_batch(graphs)
    b2, w2 = pad_batch(graphs, max_nodes=b1["token"].shape[1] + 64,
                       max_edges=b1["edge_src"].shape[1] + 128)
    z1 = rgcn_mod.encode(params, rc, {k: jnp.asarray(v) for k, v in b1.items()}, w1)
    z2 = rgcn_mod.encode(params, rc, {k: jnp.asarray(v) for k, v in b2.items()}, w2)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-4)


def test_pallas_path_matches_jnp_path():
    graphs = _graphs(2)
    batch, mw = pad_batch(graphs)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), RGCNConfig())
    z_jnp = rgcn_mod.encode(p, RGCNConfig(use_pallas=False), batch, mw)
    z_pls = rgcn_mod.encode(p, RGCNConfig(use_pallas=True), batch, mw)
    np.testing.assert_allclose(np.asarray(z_jnp), np.asarray(z_pls),
                               atol=1e-3, rtol=1e-3)


def test_embedding_dims_match_paper():
    """z_k in R^256; projection head 256 -> 128 -> 64 (paper §3.3.2)."""
    graphs = _graphs(2)
    batch, mw = pad_batch(graphs)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    rc = RGCNConfig()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), rc)
    zk = rgcn_mod.encode(p, rc, batch, mw)
    assert zk.shape == (2, 256)
    proj = rgcn_mod.project(p, rc, zk)
    assert proj.shape == (2, 64)
    assert rc.dims == (64, 128, 128, 256)
    assert len(p["layers"]) == 3


def test_augmentation_only_removes():
    graphs = _graphs(4)
    batch, _ = pad_batch(graphs)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    v, noise = augment_view(jax.random.PRNGKey(0), batch)
    assert np.all(np.asarray(v["node_mask"]) <= np.asarray(batch["node_mask"]))
    assert np.all(np.asarray(v["edge_mask"]) <= np.asarray(batch["edge_mask"]))
    # dropped fraction is bounded (<= ~2x the 15% nominal rate)
    kept = np.asarray(v["node_mask"]).sum() / np.asarray(batch["node_mask"]).sum()
    assert kept > 0.6
    assert set(np.unique(np.asarray(noise))).issubset({0.0, 1.0})


def test_augmented_views_stay_close():
    """Augmented views of the same kernel stay closer (cosine of z_k) than
    views of behaviorally different kernels — the property contrastive
    training relies on."""
    k_small = make_kernel("a", "gemm", {"M": 128, "N": 128, "K": 128}, 0, 1)
    k_diff = make_kernel("b", "traversal", {"nodes": 10_000, "degree": 8}, 1, 2)
    graphs = [
        build_kernel_graph(k.trace(2, 48)) for k in (k_small, k_diff)
    ]
    batch, mw = pad_batch(graphs)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    rc = RGCNConfig()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(3), rc)
    v1, n1 = augment_view(jax.random.PRNGKey(10), batch)
    v2, n2 = augment_view(jax.random.PRNGKey(11), batch)
    z1 = np.asarray(rgcn_mod.encode(p, rc, v1, mw))
    z2 = np.asarray(rgcn_mod.encode(p, rc, v2, mw))

    def cos(a, b):
        return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-9))

    same = cos(z1[0], z2[0])
    cross = cos(z1[0], z2[1])
    assert same > cross
