"""End-to-end behaviour tests for the paper's system: trace -> HRG -> RGCN
contrastive training -> clustering -> sampled simulation, against ground
truth, plus the three baselines on the paper's crafted failure modes."""

import numpy as np
import pytest

from repro.core.sampler import GCLSampler, GCLSamplerConfig
from repro.core.train import GCLTrainConfig
from repro.core.baselines import pka_plan, sieve_plan, stem_root_plan
from repro.sim.simulate import (
    full_metrics, reconstruct, sampling_error, simulate_program, speedup,
)
from repro.tracing.programs import get_program


def _fast_sampler():
    return GCLSampler(GCLSamplerConfig(
        cap_instr=64, train=GCLTrainConfig(steps=30, batch_size=8),
    ))


@pytest.fixture(scope="module")
def nw_results():
    prog = get_program("nw")
    metrics = simulate_program(prog, "P1")
    plan = _fast_sampler().fit(prog)
    return prog, metrics, plan


def test_gcl_nw_two_clusters(nw_results):
    """Paper §5.1: nw has 255 distinct names but 2 behavior groups."""
    _, metrics, plan = nw_results
    assert plan.num_clusters == 2
    assert sampling_error(plan, metrics) < 1.0
    assert speedup(plan, metrics) > 100.0


def test_gcl_nw_beats_name_based(nw_results):
    prog, metrics, _ = nw_results
    sv = sieve_plan(prog)
    st = stem_root_plan(prog)
    assert speedup(sv, metrics) < 1.5  # names distinct -> no reduction
    assert speedup(st, metrics) < 1.5


def test_pka_merges_nw_groups(nw_results):
    """PKA's features are identical across the two nw groups."""
    prog, metrics, _ = nw_results
    pk = pka_plan(prog)
    assert sampling_error(pk, metrics) > 5.0


def test_backprop_no_reduction():
    """backprop: 2 behaviorally-different kernels; GCL keeps both (1x
    speedup, ~0 error); PKA merges them (large error)."""
    prog = get_program("backprop")
    metrics = simulate_program(prog, "P1")
    plan = _fast_sampler().fit(prog)
    assert plan.num_clusters == 2
    assert sampling_error(plan, metrics) < 0.5
    pk = pka_plan(prog)
    assert sampling_error(pk, metrics) > 20.0


def test_reconstruction_exact_when_full():
    """A plan with every kernel as its own cluster reconstructs exactly."""
    prog = get_program("3mm")
    metrics = simulate_program(prog, "P1")
    n = len(prog)
    from repro.sim.simulate import SamplingPlan

    plan = SamplingPlan(
        labels=np.arange(n), reps={i: [i] for i in range(n)}, method="id"
    )
    assert sampling_error(plan, metrics) < 1e-9
    assert abs(speedup(plan, metrics) - 1.0) < 1e-9


def test_weighted_metric_reconstruction():
    prog = get_program("3mm")
    metrics = simulate_program(prog, "P1")
    plan = _fast_sampler().fit(prog)
    full = full_metrics(metrics)
    est = reconstruct(plan, metrics)
    for name in ("cycles", "ipc", "l1_hit", "l2_hit", "occupancy"):
        assert est[name] == pytest.approx(full[name], rel=0.2), name
