# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device.
# Only launch/dryrun.py forces 512 host devices (and only in its own process).
# Scale-out tests opt in via the `scaleout` marker: the CI job (and anyone
# running them locally) sets XLA_FLAGS=--xla_force_host_platform_device_count=8
# in the ENVIRONMENT before launching pytest; on an unforced interpreter they
# auto-skip below.
import numpy as np
import pytest

SCALEOUT_MIN_DEVICES = 8


def pytest_collection_modifyitems(config, items):
    if not any(item.get_closest_marker("scaleout") for item in items):
        return  # don't initialize jax when no scale-out test was collected
    import jax

    if jax.device_count() >= SCALEOUT_MIN_DEVICES:
        return
    skip = pytest.mark.skip(
        reason=f"needs >= {SCALEOUT_MIN_DEVICES} jax devices; run under "
               f"XLA_FLAGS=--xla_force_host_platform_device_count="
               f"{SCALEOUT_MIN_DEVICES}")
    for item in items:
        if item.get_closest_marker("scaleout"):
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
