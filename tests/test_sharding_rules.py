"""Sharding policy engine: divisibility-aware fallbacks, FSDP placement."""

import jax
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    MeshRules, PACKED_BATCH_AXES, batch_put_spec, spec_for,
)


@pytest.fixture(scope="module")
def rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # fake a 16x16 policy by overriding sizes via a subclass-free trick:
    class R(MeshRules):
        @property
        def model_size(self):
            return 16

        @property
        def fsdp_size(self):
            return 16

    return R(mesh=mesh, batch_axes=("data",))


def test_ffn_weight_tp_plus_fsdp(rules):
    # w1 (embed, ffn): model on ffn, fsdp on embed
    assert spec_for(("embed", "ffn"), (8192, 29568), rules=rules,
                    is_param=True) == P("data", "model")


def test_vocab_not_divisible_falls_back(rules):
    # granite vocab 49155 % 16 != 0 -> model moves to embed
    spec = spec_for(("vocab", "embed"), (49155, 2048), rules=rules, is_param=True)
    assert spec == P(None, "model")


def test_vocab_divisible_sharded(rules):
    spec = spec_for(("vocab", "embed"), (152064, 8192), rules=rules, is_param=True)
    assert spec == P("model", "data")


def test_kv_heads_too_small_falls_to_embed(rules):
    # wk (embed, kv_heads=8, head_dim): kv_heads (8 < 16) is never sharded;
    # the model axis falls back to the contraction dim (partial-sum
    # all-reduce on a small kv output — preferable to replicated compute).
    spec = spec_for(("embed", "kv_heads", "head_dim"), (8192, 8, 128),
                    rules=rules, is_param=True)
    assert spec == P("model", None, None)


def test_q_heads_sharded(rules):
    spec = spec_for(("embed", "heads", "head_dim"), (8192, 64, 128),
                    rules=rules, is_param=True)
    assert spec == P("data", "model", None)


def test_activation_uneven_heads_allowed(rules):
    # 24 heads over 16: activations tolerate uneven sharding
    spec = spec_for(("batch", "seq", "heads", "head_dim"), (256, 4096, 24, 128),
                    rules=rules, is_param=False)
    assert spec == P("data", None, "model", None)


def test_small_batch_stays_replicated(rules):
    # long_500k: global_batch=1 cannot shard over 16
    spec = spec_for(("batch", "cache_seq", "kv_heads", "head_dim"),
                    (1, 524288, 8, 128), rules=rules, is_param=False)
    assert spec == P(None, "model", None, None)


def test_moe_expert_weights(rules):
    # dbrx w1 (experts=16, embed, ffn): model on ffn (TP-MoE), fsdp on embed
    spec = spec_for(("experts", "embed", "ffn"), (16, 6144, 10752),
                    rules=rules, is_param=True)
    assert spec == P(None, "data", "model")


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.sampled_from(["embed", "ffn", "heads", "vocab", "batch", None, "seq"]),
        min_size=1, max_size=4,
    ),
    st.lists(st.integers(1, 4096), min_size=4, max_size=4),
    st.booleans(),
)
def test_spec_always_valid(rules, rules_names, dims, is_param):
    names = tuple(rules_names)
    shape = tuple(dims[: len(names)])
    spec = spec_for(names, shape, rules=rules, is_param=is_param)
    assert len(spec) == len(names)
    # params: any sharded dim divides exactly
    if is_param:
        for dim, s in zip(shape, spec):
            if s == "model":
                assert dim % 16 == 0
            if s == "data" or s == ("data",):
                assert dim % 16 == 0
    # no axis used twice
    flat = []
    for s in spec:
        if s is None:
            continue
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


def test_param_batch_dim_blocks_fsdp_duplicate(rules):
    """Regression (rule-3 guard): a param whose literal 'batch' dim took
    the data axis must NOT get a second 'data' placement from FSDP — a
    PartitionSpec may use each mesh axis at most once."""
    spec = spec_for(("batch", "embed", "ffn"), (16, 8192, 29568),
                    rules=rules, is_param=True)
    assert spec == P("data", None, "model")
    flat = [a for s in spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))]
    assert len(flat) == len(set(flat))


# ---------------------------------------------------------------------------
# packed-batch staging specs (scale-out host->device path)
# ---------------------------------------------------------------------------


def _put_rules(data: int):
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class R(MeshRules):
        @property
        def fsdp_size(self):
            return data

    return R(mesh=mesh, batch_axes=("data",))


@pytest.mark.parametrize("field", sorted(PACKED_BATCH_AXES))
def test_batch_put_spec_pad_or_skip_non_divisible(field):
    """6 programs on 4 devices (and every other non-divisible size) must
    REPLICATE, never emit an invalid argument sharding: pjit input
    shardings have to divide exactly."""
    rules = _put_rules(4)
    ndim = len(PACKED_BATCH_AXES[field])
    shape = (6,) + (3,) * (ndim - 1)
    spec = batch_put_spec(field, shape, rules)
    assert spec == P(*([None] * ndim))


@settings(max_examples=100, deadline=None)
@given(
    st.sampled_from(sorted(PACKED_BATCH_AXES)),
    st.integers(1, 4096),
    st.sampled_from([1, 2, 4, 8]),
    st.integers(0, 1),
)
def test_batch_put_spec_always_valid(field, dim, data, leading):
    """MeshRules placement + packed-batch staging never produce an invalid
    PartitionSpec: leading (scan) dims replicated, a sharded dim always
    divides the data-axis size, each mesh axis used at most once."""
    rules = _put_rules(data)
    naxes = len(PACKED_BATCH_AXES[field])
    shape = (5,) * leading + (dim,) + (7,) * (naxes - 1)
    spec = batch_put_spec(field, shape, rules, leading=leading)
    assert len(spec) == leading + naxes
    for i in range(leading):
        assert spec[i] is None
    flat = []
    for i, s in enumerate(spec):
        if s is None:
            continue
        assert shape[i] % data == 0  # exact divisibility or replicate
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))
    if data == 1:  # 1-wide data axis: nothing to shard, ever
        assert all(s is None for s in spec)
