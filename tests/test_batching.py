"""Packed, bucketed batching layer (core/batching.py): round-trip parity with
the dense path, bucket-count bounds on recompilation, flat-SpMM kernel parity,
truncation accounting, and packed augmentation invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rgcn as rgcn_mod
from repro.core.augment import augment_view_packed
from repro.core.batching import (
    NODE_FLOOR, bucket_key, bucket_size, graph_content_hash, pack_graphs,
    plan_microbatches,
)
from repro.core.graphs import build_kernel_graph, pad_batch
from repro.core.rgcn import RGCNConfig
from repro.core.train import ContrastiveTrainer, GCLTrainConfig
from repro.kernels.rgcn_spmm.ops import rgcn_message_agg_flat
from repro.kernels.rgcn_spmm.ref import rgcn_message_agg_flat_ref
from repro.tracing.templates import make_kernel


def _graphs(n=4, cap=48):
    ks = [
        make_kernel(f"k{i}", "gemm",
                    {"M": 128 * (i + 1), "N": 128, "K": 128}, i, seed=i)
        for i in range(n)
    ]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=cap)) for k in ks]


def _jnp(batch):
    return {k: jnp.asarray(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_bucket_size_pow2_and_monotone():
    assert bucket_size(1, 256) == 256
    assert bucket_size(256, 256) == 256
    assert bucket_size(257, 256) == 512
    assert bucket_size(5000, 256) == 8192
    prev = 0
    for n in range(1, 3000, 37):
        b = bucket_size(n, 256)
        assert b >= n and b >= prev
        prev = b


def test_bucket_count_bounded_by_log_range():
    """Packing many different graph subsets must produce at most
    log2(max/floor)+1 node buckets — not one shape per subset."""
    graphs = _graphs(8)
    keys = set()
    for lo in range(8):
        for hi in range(lo + 1, 9):
            packed, _ = pack_graphs(graphs[lo:hi])
            keys.add(bucket_key(packed))
    max_nodes = sum(g.n_nodes for g in graphs)
    n_node_buckets = int(np.log2(max(max_nodes / NODE_FLOOR, 1))) + 2
    node_sizes = {k[0] for k in keys}
    assert len(node_sizes) <= n_node_buckets
    for p, q, w, g in keys:  # all axes are pow2 buckets (graph axis exact)
        assert p & (p - 1) == 0
        assert q & (q - 1) == 0
        assert w & (w - 1) == 0


# ---------------------------------------------------------------------------
# round trip: pack -> encode == dense per-graph encode
# ---------------------------------------------------------------------------


def test_packed_encode_matches_dense():
    graphs = _graphs(4)
    rc = RGCNConfig()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), rc)
    dense, mw = pad_batch(graphs)
    z_dense = np.asarray(rgcn_mod.encode(p, rc, _jnp(dense), mw))
    packed, meta = pack_graphs(graphs)
    z_packed = np.asarray(rgcn_mod.encode_packed(p, rc, _jnp(packed)))
    assert z_packed.shape == z_dense.shape
    np.testing.assert_allclose(z_packed, z_dense, atol=1e-4, rtol=1e-4)


def test_packed_encode_invariant_to_graph_padding():
    """Padding graph slots (graph_mask == 0) must give zero rows and leave
    real rows untouched."""
    graphs = _graphs(3)
    rc = RGCNConfig()
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(1), rc)
    b1, _ = pack_graphs(graphs)
    b2, _ = pack_graphs(graphs, pad_graphs_to=8)
    z1 = np.asarray(rgcn_mod.encode_packed(p, rc, _jnp(b1)))
    z2 = np.asarray(rgcn_mod.encode_packed(p, rc, _jnp(b2)))
    np.testing.assert_allclose(z2[:3], z1, atol=1e-5)
    np.testing.assert_allclose(z2[3:], 0.0, atol=1e-6)


def test_trainer_embed_matches_dense_path():
    graphs = _graphs(5)
    trainer = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig())
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(2), trainer.rc)
    z_packed = trainer.embed(params, graphs)
    z_dense = trainer.embed_dense(params, graphs)
    np.testing.assert_allclose(z_packed, z_dense, atol=1e-4, rtol=1e-4)
    assert trainer.embed_stats["encoded"] == 5
    # second call: all content-hash cache hits, no new encodes
    z_again = trainer.embed(params, graphs)
    np.testing.assert_allclose(z_again, z_packed, atol=0)
    assert trainer.embed_stats["cache_hits"] == 5
    assert trainer.embed_stats["encoded"] == 0


def test_embed_cache_lru_hot_entry_survives_eviction_pressure():
    """The content-hash embed cache is LRU (hits move an entry to MRU), so a
    hot entry outlives eviction pressure that would have expelled it under
    the old FIFO policy (insertion order alone)."""
    graphs = _graphs(6)
    trainer = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig())
    trainer.embed_cache_max = 4
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(2), trainer.rc)

    hot = graphs[:1]
    trainer.embed(params, hot)              # hot enters as oldest
    trainer.embed(params, graphs[1:4])      # cache full: [hot, g1, g2, g3]
    trainer.embed(params, hot)              # LRU touch -> [g1, g2, g3, hot]
    assert trainer.embed_stats["cache_hits"] == 1
    trainer.embed(params, graphs[4:6])      # pressure: evicts g1, g2
    assert len(trainer._embed_cache) == 4
    trainer.embed(params, hot)              # FIFO would re-encode here
    assert trainer.embed_stats["cache_hits"] == 1
    assert trainer.embed_stats["encoded"] == 0


def test_embed_prefetch_parity():
    """embed() with the one-ahead staging pipeline is bit-exact vs inline
    staging, and reports the overlap accounting fields."""
    graphs = _graphs(5)
    t_pre = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig(prefetch=True))
    t_off = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig(prefetch=False))
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(2), t_pre.rc)
    z_pre = t_pre.embed(params, graphs)
    z_off = t_off.embed(params, graphs)
    np.testing.assert_array_equal(z_pre, z_off)
    assert t_pre.embed_stats["prefetch"] is True
    assert t_off.embed_stats["prefetch"] is False
    assert t_pre.embed_stats["prefetch_stage_s"] > 0
    assert 0.0 <= t_pre.embed_stats["prefetch_overlap"] <= 1.0
    assert t_off.embed_stats["prefetch_overlap"] == 0.0


def test_embed_compiles_bounded_by_buckets():
    """Mixed-size population: jit compiles of the packed encode stay bounded
    by the number of distinct bucket keys, not the number of micro-batches."""
    graphs = []
    for cap in (16, 24, 32, 48, 64):
        graphs += _graphs(3, cap=cap)
    trainer = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig())
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(3), trainer.rc)
    trainer.embed(params, graphs, batch_size=4)
    stats = trainer.embed_stats
    assert stats["microbatches"] >= 2
    if stats["compiles"] >= 0:  # -1 when the jit cache size API is absent
        assert stats["compiles"] <= len(stats["bucket_keys"])


# ---------------------------------------------------------------------------
# flat rgcn_spmm kernel
# ---------------------------------------------------------------------------

FLAT_SHAPES = [
    # (P, D, Q, nb, O)
    (64, 32, 100, 2, 48),
    (128, 64, 256, 3, 64),
    (32, 16, 17, 2, 32),  # edge count not divisible by block
]


@pytest.mark.parametrize("P,D,Q,nb,O", FLAT_SHAPES)
def test_rgcn_spmm_flat_matches_ref(P, D, Q, nb, O):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    h = jax.random.normal(ks[0], (P, D))
    basis = jax.random.normal(ks[1], (nb, D, O))
    src = jax.random.randint(ks[2], (Q,), 0, P)
    dst = jnp.sort(jax.random.randint(ks[3], (Q,), 0, P))  # dst-sorted stream
    w = jax.random.normal(ks[4], (Q, nb))
    out = rgcn_message_agg_flat(h, basis, src, dst, w, P, True)
    ref = rgcn_message_agg_flat_ref(h, basis, src, dst, w, P)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_rgcn_spmm_flat_grad_via_oracle():
    P, D, Q, nb = 32, 16, 40, 2
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    h = jax.random.normal(ks[0], (P, D))
    basis = jax.random.normal(ks[1], (nb, D, 24))
    src = jax.random.randint(ks[2], (Q,), 0, P)
    dst = jnp.sort(jax.random.randint(ks[3], (Q,), 0, P))
    w = jax.random.normal(ks[4], (Q, nb))
    g1 = jax.grad(
        lambda h_: rgcn_message_agg_flat(h_, basis, src, dst, w, P, True).sum()
    )(h)
    g2 = jax.grad(
        lambda h_: rgcn_message_agg_flat_ref(h_, basis, src, dst, w, P).sum()
    )(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_packed_pallas_encode_matches_jnp():
    graphs = _graphs(3)
    packed, _ = pack_graphs(graphs)
    batch = _jnp(packed)
    p = rgcn_mod.init_rgcn(jax.random.PRNGKey(6), RGCNConfig())
    z_jnp = rgcn_mod.encode_packed(p, RGCNConfig(use_pallas=False), batch)
    z_pls = rgcn_mod.encode_packed(p, RGCNConfig(use_pallas=True), batch)
    np.testing.assert_allclose(np.asarray(z_jnp), np.asarray(z_pls),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# truncation accounting + micro-batch planning + augmentation
# ---------------------------------------------------------------------------


def test_pad_batch_truncation_is_accounted():
    graphs = _graphs(2)
    n_cap = graphs[0].n_nodes // 2
    with pytest.warns(UserWarning, match="truncated"):
        b, _ = pad_batch(graphs, max_nodes=n_cap)
    assert b["trunc_nodes"].sum() > 0
    assert (b["trunc_nodes"] >= 0).all() and (b["trunc_edges"] >= 0).all()
    total_nodes = sum(g.n_nodes for g in graphs)
    assert b["trunc_nodes"].sum() == total_nodes - int(b["node_mask"].sum())


def test_pack_graphs_truncation_is_accounted():
    graphs = _graphs(2)
    cap = graphs[0].n_nodes // 2
    packed, meta = pack_graphs(graphs, max_nodes_per_graph=cap)
    assert (packed["trunc_nodes"][:2] > 0).all()
    assert meta.trunc_nodes.sum() == sum(g.n_nodes - cap for g in graphs)
    # all surviving edges stay inside their graph's node range
    used = packed["edge_mask"] > 0
    src, dst = packed["edge_src"][used], packed["edge_dst"][used]
    gid = packed["edge_graph"][used]
    assert (src >= meta.node_off[gid]).all()
    assert (dst < meta.node_off[gid] + np.minimum(
        [g.n_nodes for g in graphs], cap)[gid]).all()


def test_embed_truncates_oversized_graphs_with_accounting():
    """A graph larger than the micro-batch budget is truncated (bounding the
    packed bucket, and hence Pallas VMEM) and the loss is surfaced."""
    graphs = _graphs(2)
    trainer = ContrastiveTrainer(RGCNConfig(), GCLTrainConfig())
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(7), trainer.rc)
    cap = graphs[1].n_nodes // 2
    with pytest.warns(UserWarning, match="truncated"):
        z = trainer.embed(params, graphs, max_nodes=cap)
    assert z.shape == (2, 256)
    assert trainer.embed_stats["trunc_nodes"] > 0
    for key in trainer.embed_stats["bucket_keys"]:
        assert key[0] <= bucket_size(cap, NODE_FLOOR)
    # different caps must not serve stale cached embeddings
    z_full = trainer.embed(params, graphs)
    assert trainer.embed_stats["encoded"] == 2
    assert trainer.embed_stats["trunc_nodes"] == 0
    assert not np.allclose(z, z_full)


def test_plan_microbatches_respects_budgets():
    graphs = _graphs(7)
    bins = plan_microbatches(graphs, max_nodes=2 * max(g.n_nodes for g in graphs),
                             max_graphs=3)
    seen = sorted(i for b in bins for i in b)
    assert seen == list(range(7))
    for b in bins:
        assert len(b) <= 3
        assert sum(graphs[i].n_nodes for i in b) <= 2 * max(
            g.n_nodes for g in graphs)


def test_graph_content_hash_distinguishes():
    g1, g2 = _graphs(2)
    same = build_kernel_graph(
        make_kernel("k0", "gemm", {"M": 128, "N": 128, "K": 128}, 0,
                    seed=0).trace(2, 48)
    )
    assert graph_content_hash(g1) == graph_content_hash(same)
    assert graph_content_hash(g1) != graph_content_hash(g2)


def test_packed_augmentation_only_removes():
    packed, _ = pack_graphs(_graphs(4))
    batch = _jnp(packed)
    v, noise = augment_view_packed(jax.random.PRNGKey(0), batch)
    assert np.all(np.asarray(v["node_mask"]) <= np.asarray(batch["node_mask"]))
    assert np.all(np.asarray(v["edge_mask"]) <= np.asarray(batch["edge_mask"]))
    kept = np.asarray(v["node_mask"]).sum() / np.asarray(batch["node_mask"]).sum()
    assert kept > 0.6
    assert noise.shape == (batch["graph_mask"].shape[0],)
    assert set(np.unique(np.asarray(noise))).issubset({0.0, 1.0})
