"""Parallel trace->graph ingestion: engine determinism, graph store,
vectorized-tracer parity, and the model-zoo Program namespace
(DESIGN.md §13).

The load-bearing invariants:
- `IngestEngine` output is BIT-identical to sequential ingestion at any
  (workers, depth) — FIFO collection + keyed RNG, no shared mutable state
  (hypothesis sweeps the configuration space);
- the vectorized `trace_kernel` replays the loop oracle's exact RNG
  stream: every array of every warp matches `trace_kernel_loop` bit for
  bit, per template, including divergent control flow;
- a `GraphStore` entry round-trips exactly, a corrupt entry is rejected
  and re-traced (never served), and trace caps are part of the key so a
  cached graph cannot be replayed across differing trace windows;
- `model:<config>[:phase]` programs resolve from PROGRAMS and stream
  end-to-end through `embed_stream`.
"""

import numpy as np
import pytest

from repro.ingest import (
    GraphStore, IngestConfig, IngestEngine, kernel_graph_key,
)
from repro.tracing.programs import Program, get_program
from repro.tracing.templates import TEMPLATES, make_kernel
from repro.tracing.tracer import trace_kernel, trace_kernel_loop

# one valid parameter set per template (templates have no defaults)
TEMPLATE_PARAMS = {
    "gemm": {"M": 128, "N": 64, "K": 32},
    "elementwise": {"n": 4096},
    "reduction": {"n": 8192},
    "stencil": {"nx": 256, "ny": 8},
    "softmax": {"rows": 64, "cols": 128},
    "conv": {"c": 8, "hw": 32, "k": 16},
    "traversal": {"nodes": 512},   # divergent branches (mask bits vary)
    "gemv": {"n": 256, "m": 64},
}

_TRACE_FIELDS = ("opcode", "pc", "mask", "dest", "src",
                 "mem_width", "mem_addr", "vstats")
_GRAPH_FIELDS = ("node_type", "token", "pc_norm", "vstats", "warp_id",
                 "edge_src", "edge_dst", "edge_type")


def _mixed_program(n=10, seed=3):
    """Small program cycling through templates, with duplicate
    invocations (exercises the dedup memo) and per-kernel seeds."""
    names = sorted(TEMPLATE_PARAMS)
    ks = []
    for i in range(n):
        t = names[i % len(names)]
        ks.append(make_kernel(f"k{i}", t, TEMPLATE_PARAMS[t], i,
                              seed=seed + (i % 3)))
    return Program("ingest-test", ks)


def _assert_graphs_equal(a, b):
    for f in _GRAPH_FIELDS:
        x, y = getattr(a, f), getattr(b, f)
        assert x.dtype == y.dtype and x.shape == y.shape, f
        assert np.array_equal(x, y), f"graph field {f} differs"
    assert a.n_warps == b.n_warps


# ---------------------------------------------------------------------------
# vectorized tracer vs the loop oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("template", sorted(TEMPLATE_PARAMS))
def test_trace_kernel_matches_loop_oracle(template):
    inv = make_kernel("k", template, TEMPLATE_PARAMS[template], 0, seed=11)
    for caps in ((2, 96), (4, 192), (1, 24)):
        fast = inv.trace(*caps)
        slow = inv.trace(*caps, loop=True)
        assert len(fast) == len(slow) == caps[0]
        for wf, ws in zip(fast, slow):
            for f in _TRACE_FIELDS:
                x, y = getattr(wf, f), getattr(ws, f)
                assert x.dtype == y.dtype, (f, caps)
                assert np.array_equal(x, y), \
                    f"{template} caps={caps} field {f} diverges from oracle"


def test_trace_default_caps_resolve_from_config():
    from repro.config import DEFAULT_CAP_INSTR, DEFAULT_CAP_WARPS

    inv = make_kernel("k", "gemm", TEMPLATE_PARAMS["gemm"], 0, seed=5)
    traces = inv.trace()   # no caps anywhere -> repo-wide defaults
    assert len(traces) == DEFAULT_CAP_WARPS
    assert all(len(w.opcode) <= DEFAULT_CAP_INSTR for w in traces)


def test_all_templates_covered():
    assert set(TEMPLATE_PARAMS) == set(TEMPLATES.names())


# ---------------------------------------------------------------------------
# engine determinism (hypothesis over the config space)
# ---------------------------------------------------------------------------


def _ingest(program, workers, depth=2, store=None):
    eng = IngestEngine(IngestConfig(workers=workers, depth=depth,
                                    cache=store is not None), store)
    return list(eng.iter_graphs(program)), eng


def test_parallel_matches_sequential_basic():
    prog = _mixed_program(12)
    ref, _ = _ingest(prog, workers=0)
    par, eng = _ingest(prog, workers=3)
    assert len(par) == len(ref) == 12
    for a, b in zip(par, ref):
        _assert_graphs_equal(a, b)
    assert eng.stats["kernels"] == 12
    # duplicates collapse in the memo: fewer traces than invocations
    assert eng.stats["traced"] + eng.stats["memo_hits"] >= 12


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(workers=st.integers(0, 4), depth=st.integers(1, 3),
           n=st.integers(1, 14), seed=st.integers(0, 50))
    def test_parallel_matches_sequential_property(workers, depth, n, seed):
        prog = _mixed_program(n, seed=seed)
        ref, _ = _ingest(prog, workers=0)
        par, _ = _ingest(prog, workers=workers, depth=depth)
        assert len(par) == len(ref) == n
        for a, b in zip(par, ref):
            _assert_graphs_equal(a, b)
except ImportError:  # hypothesis is a dev-only dep (requirements-dev.txt)
    pass


def test_engine_matches_iter_kernel_graphs():
    """The engine is a drop-in for the core sequential path."""
    from repro.core.graphs import iter_kernel_graphs

    prog = _mixed_program(6)
    ref = list(iter_kernel_graphs(prog))
    par, _ = _ingest(prog, workers=2)
    for a, b in zip(par, ref):
        _assert_graphs_equal(a, b)


# ---------------------------------------------------------------------------
# graph store
# ---------------------------------------------------------------------------


def test_graph_store_round_trip(tmp_path):
    from repro.core.graphs import build_kernel_graph

    inv = make_kernel("k", "gemm", TEMPLATE_PARAMS["gemm"], 0, seed=9)
    g = build_kernel_graph(inv.trace(2, 96))
    store = GraphStore(str(tmp_path))
    key = kernel_graph_key(inv, 2, 96)
    store.save_kernel(key, g)
    assert store.has_kernel(key)
    loaded = store.load_kernel(key)
    assert loaded is not None
    _assert_graphs_equal(loaded, g)
    assert store.stats["writes"] == 1 and store.stats["hits"] == 1


def test_graph_store_miss_returns_none(tmp_path):
    store = GraphStore(str(tmp_path))
    assert store.load_kernel("0" * 20) is None
    assert store.stats["misses"] == 1


def test_caps_are_part_of_the_cache_key():
    inv = make_kernel("k", "gemm", TEMPLATE_PARAMS["gemm"], 0, seed=9)
    keys = {kernel_graph_key(inv, *caps)
            for caps in ((2, 96), (2, 64), (4, 96))}
    assert len(keys) == 3, "trace caps must derive distinct cache keys"
    # same trace identity at the same caps -> same key (name/seq excluded:
    # duplicate invocations share one entry)
    other = make_kernel("other-name", "gemm", TEMPLATE_PARAMS["gemm"], 77,
                        seed=9)
    assert kernel_graph_key(other, 2, 96) == kernel_graph_key(inv, 2, 96)


def test_corrupted_entry_rejected_and_retraced(tmp_path):
    prog = _mixed_program(8)
    store = GraphStore(str(tmp_path))
    cold, eng_cold = _ingest(prog, workers=0, store=store)
    n_unique = eng_cold.stats["traced"]
    assert n_unique > 0

    # flip bytes inside one on-disk entry
    victim = next((tmp_path / "kernels").rglob("*.npz"))
    blob = bytearray(victim.read_bytes())
    blob[100:120] = b"\xff" * 20
    victim.write_bytes(bytes(blob))

    rewarm, eng = _ingest(prog, workers=2, store=store)
    for a, b in zip(rewarm, cold):
        _assert_graphs_equal(a, b)     # corruption never changes output
    assert eng.stats["corrupt"] == 1
    assert eng.stats["traced"] == 1    # only the victim re-traced
    # the overwrite healed the store: fully warm now
    warm, eng2 = _ingest(prog, workers=0, store=store)
    assert eng2.stats["traced"] == 0
    for a, b in zip(warm, cold):
        _assert_graphs_equal(a, b)


def test_warm_run_retraces_nothing(tmp_path):
    prog = _mixed_program(10)
    store = GraphStore(str(tmp_path))
    cold, eng_cold = _ingest(prog, workers=2, store=store)
    assert eng_cold.stats["traced"] > 0
    assert store.warm(prog, 2, 96)     # manifest published on full drain

    warm, eng = _ingest(prog, workers=2, store=store)
    assert eng.stats["traced"] == 0, "warm GraphStore run must not re-trace"
    assert eng.stats["store_hits"] + eng.stats["memo_hits"] == 10
    for a, b in zip(warm, cold):
        _assert_graphs_equal(a, b)
    # a different trace window is a different cache universe
    _, eng3 = _ingest(prog, workers=0, store=store)
    assert eng3.stats["traced"] == 0
    eng4 = IngestEngine(IngestConfig(workers=0, cache=True), store)
    list(eng4.iter_graphs(prog, cap_warps=2, cap_instr=64))
    assert eng4.stats["traced"] > 0


def test_partial_drain_publishes_no_manifest(tmp_path):
    prog = _mixed_program(8)
    store = GraphStore(str(tmp_path))
    eng = IngestEngine(IngestConfig(workers=2), store)
    it = eng.iter_graphs(prog)
    next(it); next(it)
    it.close()
    assert not store.warm(prog, 2, 96)


# ---------------------------------------------------------------------------
# model-zoo Program namespace
# ---------------------------------------------------------------------------


def test_model_zoo_programs_resolve():
    from repro.workloads import zoo_names

    names = zoo_names()
    assert len(names) >= 6
    for name in names:
        assert name.startswith("model:")
    for name in ("model:llama3.2-3b:prefill", "model:mamba2-780m:decode",
                 "model:dbrx-132b:prefill"):
        prog = get_program(name)
        assert len(prog) > 0
        assert prog.trace_caps is not None      # 10-100x trace window
        assert "modelzoo" in prog.fingerprint_extra


def test_model_zoo_graphs_are_model_scale():
    from repro.core.graphs import build_kernel_graph

    prog = get_program("model:llama3.2-3b:prefill")
    small = make_kernel("k", "gemm", TEMPLATE_PARAMS["gemm"], 0, seed=1)
    g_small = build_kernel_graph(small.trace())  # repo-default window
    g_zoo = build_kernel_graph(prog.kernels[0].trace(*prog.trace_caps))
    assert g_zoo.n_nodes >= 10 * g_small.n_nodes


def test_model_program_streams_through_embed(tmp_path):
    """A (truncated) model program flows end-to-end: parallel ingestion ->
    stream_pack -> train_stream -> embed_stream, warm run re-traces 0."""
    from repro.core.rgcn import RGCNConfig
    from repro.core.sampler import GCLSampler, GCLSamplerConfig
    from repro.core.train import GCLTrainConfig

    full = get_program("model:llama3.2-3b:decode")
    prog = Program(full.name, full.kernels[:6],
                   fingerprint_extra=full.fingerprint_extra,
                   trace_caps=(2, 64))   # keep the unit test cheap
    cfg = GCLSamplerConfig(
        train=GCLTrainConfig(steps=8, batch_size=4, scan_chunk=4),
        rgcn=RGCNConfig(),
        ingest=IngestConfig(workers=2),
    )
    s = GCLSampler(cfg)
    s.attach_graph_store(GraphStore(str(tmp_path)))
    s.train_stream(s.iter_graphs(prog), n_total=len(prog))
    emb = s.embed_stream(s.iter_graphs(prog))
    assert emb.shape[0] == len(prog)
    assert np.isfinite(emb).all()
    warm = GCLSampler(cfg)
    warm.attach_graph_store(GraphStore(str(tmp_path)))
    list(warm.iter_graphs(prog))
    assert warm.ingest.stats["traced"] == 0


# ---------------------------------------------------------------------------
# streaming front door routes through the engine
# ---------------------------------------------------------------------------


def test_iter_program_graphs_engine_route():
    from repro.workloads.streaming import iter_program_graphs

    prog = _mixed_program(5)
    eng = IngestEngine(IngestConfig(workers=2))
    ref = list(iter_program_graphs(prog))
    par = list(iter_program_graphs(prog, engine=eng))
    assert eng.stats["kernels"] == 5
    for a, b in zip(par, ref):
        _assert_graphs_equal(a, b)
