"""Fault tolerance (distributed/fault.py + the engines' degrade paths).

The primitives (Watchdog, Heartbeat, retry) and the 1-device degradation
paths run everywhere; the multi-width mesh-shrink scenarios carry the
`scaleout` marker (forced-8-device interpreter only, see conftest).
"""

import json
import time

import numpy as np
import pytest

from repro.distributed.fault import DeviceLost, Heartbeat, Watchdog, retry

# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------


def test_watchdog_fires_on_straggler():
    seen = []
    wd = Watchdog(min_timeout_s=0.02, on_straggler=seen.append)
    wd.step_start()
    time.sleep(0.1)
    wd.step_end()
    assert wd.fired == 1
    assert seen and seen[0] == pytest.approx(0.02)


def test_watchdog_quiet_within_slo():
    wd = Watchdog(min_timeout_s=5.0)
    for _ in range(3):
        wd.step_start()
        dt = wd.step_end()
        assert dt < 1.0
    assert wd.fired == 0
    assert wd._timer is None  # step_end cancels the armed timer


def test_watchdog_timeout_tracks_median_window():
    wd = Watchdog(slo_factor=4.0, min_timeout_s=0.001, window=3)
    assert wd.timeout_s() == 0.001  # no history -> floor
    wd._times = [0.5, 1.0, 2.0]
    assert wd.timeout_s() == pytest.approx(4.0)  # 4 x median(1.0)
    # window keeps only the trailing 3 samples
    wd._times = []
    for dt in (0.1, 0.2, 0.3, 10.0):
        wd._t0 = time.time() - dt
        wd._timer = None
        wd.step_end()
    assert len(wd._times) == 3
    assert wd._times[0] == pytest.approx(0.2, abs=0.05)


# ---------------------------------------------------------------------------
# Heartbeat
# ---------------------------------------------------------------------------


def test_heartbeat_liveness_file(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = Heartbeat(path, interval_s=30.0, host_id=3)
    hb.update(17)
    hb.beat()
    with open(path) as f:
        doc = json.load(f)
    assert doc["host"] == 3 and doc["step"] == 17
    assert doc["time"] == pytest.approx(time.time(), abs=5.0)
    # no stale tmp file left behind (atomic swap)
    assert not (tmp_path / "hb.json.tmp").exists()


def test_heartbeat_thread_start_stop(tmp_path):
    path = str(tmp_path / "hb.json")
    hb = Heartbeat(path, interval_s=0.01)
    hb.start()
    time.sleep(0.05)
    hb.stop()
    t0 = json.load(open(path))["time"]
    time.sleep(0.05)
    assert json.load(open(path))["time"] == t0  # stopped: no more beats


# ---------------------------------------------------------------------------
# retry
# ---------------------------------------------------------------------------


def test_retry_recovers_from_transient_failures(monkeypatch):
    sleeps = []
    monkeypatch.setattr(time, "sleep", sleeps.append)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=3, backoff_s=0.5) == "ok"
    assert sleeps == [0.5, 1.0]  # bounded exponential backoff


def test_retry_exhausts_and_reraises(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda s: None)
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise OSError("down")

    with pytest.raises(OSError, match="down"):
        retry(always_fails, attempts=3, backoff_s=0.0)
    assert calls["n"] == 3


def test_retry_only_catches_declared_exceptions(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda s: None)
    with pytest.raises(ValueError):
        retry(lambda: (_ for _ in ()).throw(ValueError("not transient")),
              attempts=3)


# ---------------------------------------------------------------------------
# training engine: fault boundary + degrade-don't-abort (1 device)
# ---------------------------------------------------------------------------


def _graphs(n=6, cap=48):
    from repro.core.graphs import build_kernel_graph
    from repro.tracing.templates import make_kernel

    ks = [make_kernel(f"k{i}", "gemm",
                      {"M": 128 * (i % 3 + 1), "N": 128, "K": 128}, i, seed=i)
          for i in range(n)]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=cap))
            for k in ks]


def _tc(**kw):
    from repro.core.train import GCLTrainConfig

    base = dict(steps=8, batch_size=4, scan_chunk=4, log_every=50,
                checkpoint_every=4)
    base.update(kw)
    return GCLTrainConfig(**base)


def test_fit_fault_hook_checkpoints_then_raises(tmp_path):
    """An injected DeviceLost surfaces at the chunk boundary AFTER the
    engine checkpointed — nothing computed is lost."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import ContrastiveTrainer

    ck = str(tmp_path / "ck")

    def hook(done):
        if done >= 4:
            raise DeviceLost("injected participant loss")

    with pytest.raises(DeviceLost, match="injected"):
        ContrastiveTrainer(RGCNConfig(), _tc()).fit(
            _graphs(), checkpoint_dir=ck, fault_hook=hook)
    assert CheckpointManager(ck).latest_step() >= 4


def test_fit_python_engine_rejects_fault_protocol():
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import ContrastiveTrainer

    with pytest.raises(ValueError, match="scan"):
        ContrastiveTrainer(RGCNConfig(), _tc(engine="python",
                                             checkpoint_every=0)).fit(
            _graphs(), fault_hook=lambda done: None)


def test_fit_watchdog_slo_becomes_device_lost(tmp_path):
    """A fired watchdog converts into DeviceLost at the SAME chunk
    boundary (after checkpointing), never mid-step."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import ContrastiveTrainer

    ck = str(tmp_path / "ck")
    wd = Watchdog(min_timeout_s=1e-4)  # the first chunk always exceeds this
    with pytest.raises(DeviceLost, match="watchdog SLO"):
        ContrastiveTrainer(RGCNConfig(), _tc()).fit(
            _graphs(), checkpoint_dir=ck, watchdog=wd)
    assert wd.fired >= 1
    assert CheckpointManager(ck).latest_step() is not None


def test_fit_resilient_degrades_and_finishes(tmp_path):
    """One injected loss -> shrink to the next width, resume from the
    checkpoint, finish the full step count (device_counts=[1, 1] keeps
    this scenario runnable on a single device)."""
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import fit_resilient

    state = {"hits": 0}

    def hook(done):
        if state["hits"] == 0 and done >= 4:
            state["hits"] += 1
            raise DeviceLost("injected")

    params, info = fit_resilient(
        RGCNConfig(), _tc(), _graphs(), checkpoint_dir=str(tmp_path / "ck"),
        device_counts=[1, 1], fault_hook=hook)
    assert len(info["degradations"]) == 1
    assert info["degradations"][0]["from_devices"] == 1
    assert info["resumed_from"] >= 4
    assert len(info["history"]) == 8  # every step accounted for
    assert params is not None


def test_fit_resilient_requires_checkpoint_dir():
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import fit_resilient

    with pytest.raises(ValueError, match="checkpoint_dir"):
        fit_resilient(RGCNConfig(), _tc(), _graphs(), checkpoint_dir="")


def test_fit_resilient_exhausts_every_width(tmp_path):
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import fit_resilient

    def always_lost(done):
        raise DeviceLost("hard down")

    with pytest.raises(DeviceLost, match="every mesh width"):
        fit_resilient(RGCNConfig(), _tc(), _graphs(),
                      checkpoint_dir=str(tmp_path / "ck"),
                      device_counts=[1, 1], fault_hook=always_lost)


# ---------------------------------------------------------------------------
# plan engine degrade loop (1 device: shard-width bookkeeping only)
# ---------------------------------------------------------------------------


def test_plan_engine_degrades_on_device_lost():
    """A DeviceLost from the fault hook halves the shard width and retries
    the SAME chunk — requests are served, the drop is counted."""
    from repro.sampling.engine import PlanEngine

    rng = np.random.default_rng(0)
    embs = [rng.normal(size=(40, 4)).astype(np.float32) for _ in range(4)]
    eng = PlanEngine(k_max=4, iters=8, data_devices=2)
    fired = {"n": 0}

    def hook():
        if fired["n"] == 0:
            fired["n"] += 1
            raise DeviceLost("injected")

    eng.fault_hook = hook
    results = eng.cluster_many(embs)
    assert all(not isinstance(r, Exception) for r in results)
    st = eng.engine_stats()
    assert st["degraded_dispatches"] == 1
    assert st["data_shards"] == 1
    assert st["errors"] == 0


def test_plan_engine_raises_at_one_shard_floor():
    """Below one shard there is nothing left to degrade to: DeviceLost
    propagates (errors='raise') so the caller sees the hard failure."""
    from repro.sampling.engine import PlanEngine

    rng = np.random.default_rng(0)
    embs = [rng.normal(size=(40, 4)).astype(np.float32)]
    eng = PlanEngine(k_max=4, iters=8, data_devices=1)

    def hook():
        raise DeviceLost("hard down")

    eng.fault_hook = hook
    with pytest.raises(DeviceLost, match="hard down"):
        eng.cluster_many(embs)


def test_plan_service_surfaces_degradation():
    """PlanService stats expose the engine's degradation counters."""
    from repro.sampling.engine import PlanRequest
    from repro.serving import PlanService

    rng = np.random.default_rng(0)
    fired = {"n": 0}

    def hook():
        if fired["n"] == 0:
            fired["n"] += 1
            raise DeviceLost("injected")

    with PlanService(max_batch=4, max_delay_ms=1.0, data_devices=2,
                     fault_hook=hook, k_max=4, iters=8) as svc:
        futs = [svc.submit(PlanRequest(
            rng.normal(size=(40, 4)).astype(np.float32),
            np.arange(40), "m")) for _ in range(4)]
        plans = [f.result(timeout=120) for f in futs]
    assert all(p is not None and not isinstance(p, Exception)
               for p in plans)
    st = svc.stats()
    assert st["engine"]["degraded_dispatches"] == 1
    assert st["engine"]["data_shards"] == 1
    assert st["failed"] == 0


# ---------------------------------------------------------------------------
# multi-width mesh shrink (simulated 8-device mesh only)
# ---------------------------------------------------------------------------


@pytest.mark.scaleout
def test_fit_resilient_shrinks_mesh_8_to_4(tmp_path):
    """The real scale-out scenario: a participant lost on the 8-wide mesh
    degrades to 4, resumes from the checkpoint, and finishes."""
    from repro.core.rgcn import RGCNConfig
    from repro.core.train import fit_resilient

    state = {"hits": 0}

    def hook(done):
        if state["hits"] == 0 and done >= 4:
            state["hits"] += 1
            raise DeviceLost("injected participant loss")

    params, info = fit_resilient(
        RGCNConfig(), _tc(), _graphs(n=8), checkpoint_dir=str(tmp_path / "ck"),
        device_counts=[8, 4], fault_hook=hook)
    assert info["data_shards"] == 4
    assert info["degradations"] == [
        {"from_devices": 8, "to_devices": 4,
         "error": "injected participant loss"}]
    assert len(info["history"]) == 8


@pytest.mark.scaleout
def test_plan_engine_sharded_degrade_keeps_parity():
    """Degrading 8 -> 4 shards mid-serve must not change any program's
    labels (the shard width is an execution detail, not math)."""
    from repro.sampling.engine import PlanEngine

    rng = np.random.default_rng(1)
    embs = [rng.normal(size=(40 + i, 8)).astype(np.float32)
            for i in range(8)]
    reference = PlanEngine(k_max=6, iters=8,
                           engine="sequential").cluster_many(embs)
    eng = PlanEngine(k_max=6, iters=8, max_batch=1, data_devices=8)
    fired = {"n": 0}

    def hook():
        if fired["n"] == 0:
            fired["n"] += 1
            raise DeviceLost("injected")

    eng.fault_hook = hook
    results = eng.cluster_many(embs)
    st = eng.engine_stats()
    assert st["degraded_dispatches"] == 1 and st["data_shards"] == 4
    for (lab, info), (lab_r, info_r) in zip(results, reference):
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_r))
        assert info["k"] == info_r["k"]
