"""Infrastructure tests: checkpoint roundtrip/resume/elastic, deterministic
data pipeline, optimizer behavior, gradient compression, fault tolerance."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.data import TokenStream
from repro.distributed.fault import Heartbeat, Watchdog, retry
from repro.optim import adamw_init, apply_gradients
from repro.optim.grad_compress import compress_decompress
from repro.optim.schedules import cosine_schedule


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _state():
    params = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
    return adamw_init(params, TrainConfig())


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(7, state, blocking=True)
    abstract = jax.eval_shape(lambda: state)
    restored, step = mgr.restore(abstract)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(1, state, blocking=True)
    # a .tmp dir left behind by a "crash" must not be listed as a step
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert mgr.all_steps() == [1]


def test_checkpoint_elastic_restore_with_shardings(tmp_path):
    """Restore with explicit (new-mesh) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(3, state, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), state,
    )
    restored, _ = mgr.restore(jax.eval_shape(lambda: state), shardings=sh)
    np.testing.assert_array_equal(
        np.asarray(restored.params["a"]), np.asarray(state.params["a"])
    )


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_skippable():
    a = TokenStream(1000, 32, 4, seed=5)
    b = TokenStream(1000, 32, 4, seed=5)
    for _ in range(3):
        a.next()
    b.skip(3)
    np.testing.assert_array_equal(a.next()["tokens"], b.next()["tokens"])


def test_data_host_sharding_disjoint():
    h0 = TokenStream(1000, 16, 8, seed=1, host_id=0, num_hosts=2)
    h1 = TokenStream(1000, 16, 8, seed=1, host_id=1, num_hosts=2)
    b0, b1 = h0.next(), h1.next()
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_data_labels_shifted():
    ds = TokenStream(1000, 16, 2, seed=3)
    b = ds.next()
    # labels are next-token targets
    ds2 = TokenStream(1000, 16, 2, seed=3)
    b2 = ds2.next()
    np.testing.assert_array_equal(b["labels"][:, :-1], b2["tokens"][:, 1:])


def test_data_vlm_label_masking():
    ds = TokenStream(1000, 32, 2, seed=3, frontend="vision", d_model=8,
                     frontend_tokens=8)
    b = ds.next()
    assert b["tokens"].shape == (2, 24)
    assert b["labels"].shape == (2, 32)
    assert (b["labels"][:, :8] == -1).all()
    assert b["frontend"].shape == (2, 8, 8)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                       total_steps=200, schedule="constant")
    state = adamw_init(params, tcfg)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(state.params)
        state, _ = apply_gradients(state, g, tcfg)
    assert float(jnp.abs(state.params["w"]).max()) < 0.05


def test_cosine_schedule_shape():
    lr0 = cosine_schedule(0, base_lr=1.0, total_steps=100, warmup_steps=10)
    lr_mid = cosine_schedule(55, base_lr=1.0, total_steps=100, warmup_steps=10)
    lr_end = cosine_schedule(100, base_lr=1.0, total_steps=100, warmup_steps=10)
    assert float(lr0) == 0.0
    assert 0.3 < float(lr_mid) < 0.7
    assert float(lr_end) == pytest.approx(0.01, abs=1e-3)


def test_grad_compress_error_feedback():
    """int8 EF compression: carried error keeps the cumulative sum faithful."""
    rng = np.random.default_rng(0)
    g_true = [jnp.asarray(rng.standard_normal(64), jnp.float32)
              for _ in range(20)]
    err = {"g": jnp.zeros(64)}
    total_compressed = jnp.zeros(64)
    for g in g_true:
        out, err_new = compress_decompress({"g": g}, err)
        err = err_new
        total_compressed = total_compressed + out["g"]
    total_true = sum(g_true)
    resid = total_compressed + err["g"] - total_true
    # cumulative sum + residual matches exactly (EF invariant)
    np.testing.assert_allclose(np.asarray(resid), 0.0, atol=1e-3)
    # and per-step error is bounded by the quantization grid
    assert float(jnp.max(jnp.abs(err["g"]))) < float(jnp.max(jnp.abs(total_true))) / 50


def test_bf16_moments_supported():
    tcfg = TrainConfig(opt_dtype="bfloat16")
    st = adamw_init({"w": jnp.ones(4)}, tcfg)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4) * 0.1}
    st2, _ = apply_gradients(st, g, tcfg)
    assert np.isfinite(np.asarray(st2.params["w"])).all()


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_watchdog_fires_on_straggler():
    fired = []
    wd = Watchdog(slo_factor=1.0, min_timeout_s=0.05,
                  on_straggler=lambda t: fired.append(t))
    wd.step_start()
    time.sleep(0.15)
    wd.step_end()
    assert wd.fired == 1 and fired


def test_watchdog_quiet_on_normal_steps():
    wd = Watchdog(slo_factor=5.0, min_timeout_s=1.0)
    for _ in range(3):
        wd.step_start()
        time.sleep(0.01)
        wd.step_end()
    assert wd.fired == 0


def test_heartbeat_writes(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), interval_s=60)
    hb.update(42)
    hb.beat()
    import json

    with open(tmp_path / "hb.json") as f:
        assert json.load(f)["step"] == 42


def test_retry_transient():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, attempts=4, backoff_s=0.001) == "ok"
    assert len(calls) == 3
