"""Unified sampling API: registry round-trip over every method, artifact
store save/load equality, the evaluate() harness pinned against
hand-computed values, and the launch-grid results-JSON schema."""

import numpy as np
import pytest

from repro.core.sampler import GCLSampler
from repro.launch.sample import run_grid, validate_results
from repro.sampling import (
    ArtifactStore, Artifacts, available_methods, evaluate_metrics,
    flatten_tree, get_method, plan_from_labels, program_fingerprint,
    unflatten_tree,
)
from repro.sim.simulate import SamplingPlan
from repro.sim.timing import KernelMetrics
from repro.tracing.programs import get_program

GCL_SMOKE = dict(steps=6, batch_size=4, cap_instr=48)


def _method(method_id, **extra):
    kwargs = dict(GCL_SMOKE) if method_id == "gcl" else {}
    kwargs.update(extra)
    return get_method(method_id, **kwargs)


# ---------------------------------------------------------------------------
# registry round-trip: every method -> valid plan on a small traced program
# ---------------------------------------------------------------------------

def test_registry_lists_all_paper_methods():
    assert available_methods() == ["gcl", "pka", "sieve", "stem_root"]


def test_unknown_method_names_known_ones():
    with pytest.raises(KeyError, match="sieve"):
        get_method("nope")


@pytest.mark.parametrize("method_id", ["gcl", "pka", "sieve", "stem_root"])
def test_registry_round_trip_valid_plan(method_id):
    prog = get_program("3mm")
    plan, artifacts = _method(method_id).run(prog)
    n = len(prog)
    assert isinstance(plan, SamplingPlan)
    assert plan.labels.shape == (n,)
    clusters = set(np.unique(plan.labels).tolist())
    assert set(plan.reps) == clusters
    for c, reps in plan.reps.items():
        assert reps, f"cluster {c} has no representative"
        members = set(np.nonzero(plan.labels == c)[0].tolist())
        assert set(reps) <= members
    assert artifacts.method == method_id
    assert artifacts.program == program_fingerprint(prog)


# ---------------------------------------------------------------------------
# shared plan_from_labels policies + legacy shims stay identical
# ---------------------------------------------------------------------------

def test_plan_from_labels_priority_and_selector():
    labels = np.array([0, 0, 0, 1])
    seqs = np.array([0, 1, 2, 3])
    pri = np.array([1, 5, 5, 2])
    p = plan_from_labels(labels, seqs, "m", priority=pri)
    assert p.reps == {0: [1], 1: [3]}  # max priority, then min seq
    p = plan_from_labels(labels, seqs, "m",
                         rep_selector=lambda c, members: members[:2])
    assert p.reps == {0: [0, 1], 1: [3]}
    with pytest.raises(ValueError):
        plan_from_labels(labels, seqs, "m", priority=pri,
                         rep_selector=lambda c, m: m)


@pytest.mark.parametrize("method_id", ["pka", "sieve", "stem_root"])
def test_registry_matches_legacy_shims(method_id):
    from repro.core.baselines import pka_plan, sieve_plan, stem_root_plan

    legacy = {"pka": pka_plan, "sieve": sieve_plan,
              "stem_root": stem_root_plan}[method_id]
    prog = get_program("AlexNet")
    plan, _ = _method(method_id).run(prog)
    old = legacy(prog)
    np.testing.assert_array_equal(plan.labels, old.labels)
    assert plan.reps == old.reps
    assert plan.method == old.method


# ---------------------------------------------------------------------------
# artifact store: save/load equality, content-hash replay
# ---------------------------------------------------------------------------

def test_tree_flatten_roundtrip():
    tree = {
        "embed": np.arange(6.0).reshape(2, 3),
        "layers": [
            {"w": np.ones((2, 2)), "b": np.zeros(2)},
            {"w": np.full((2, 2), 3.0), "b": np.ones(2)},
        ],
    }
    flat = flatten_tree(tree)
    back = unflatten_tree(flat)
    assert isinstance(back["layers"], list) and len(back["layers"]) == 2
    np.testing.assert_array_equal(back["embed"], tree["embed"])
    np.testing.assert_array_equal(back["layers"][1]["w"],
                                  tree["layers"][1]["w"])


def test_artifact_store_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    payload = {
        "embeddings": np.random.default_rng(0).normal(size=(5, 8)),
        "seqs": np.arange(5),
        "params": {"proj": {"w": np.ones((3, 3))},
                   "layers": [{"b": np.zeros(4)}]},
    }
    art = Artifacts(method="gcl", program="prog-abc", config_hash="cfg123",
                    payload=payload, timings={"train_s": 1.5},
                    meta={"note": "x"})
    store.save(art)
    assert store.has("gcl", art.key)
    loaded = store.load("gcl", art.key)
    assert loaded.method == "gcl" and loaded.config_hash == "cfg123"
    assert loaded.timings == {"train_s": 1.5} and loaded.meta == {"note": "x"}
    np.testing.assert_array_equal(loaded.payload["embeddings"],
                                  payload["embeddings"])
    np.testing.assert_array_equal(loaded.payload["params"]["layers"][0]["b"],
                                  payload["params"]["layers"][0]["b"])
    assert store.load("gcl", "missing-key") is None


def test_store_replays_prepare(tmp_path):
    """Second run() with a store must skip prepare() and reuse artifacts."""
    store = ArtifactStore(str(tmp_path))
    prog = get_program("3mm")
    m1 = _method("pka")
    plan1, art1 = m1.run(prog, store=store)

    m2 = _method("pka")
    calls = {"prepare": 0}
    orig = m2.prepare

    def counting_prepare(program):
        calls["prepare"] += 1
        return orig(program)

    m2.prepare = counting_prepare
    plan2, art2 = m2.run(prog, store=store)
    assert calls["prepare"] == 0
    np.testing.assert_array_equal(plan2.labels, plan1.labels)
    assert plan2.reps == plan1.reps


def test_gcl_cross_program_reuse_keys_provenance(tmp_path):
    """An encoder trained on program A and reused for program B must store
    B's artifacts under a key carrying A's fingerprint, so replayed results
    never silently depend on store history / grid order."""
    store = ArtifactStore(str(tmp_path))
    m = _method("gcl")
    prog_a, prog_b = get_program("3mm"), get_program("backprop")
    _, art_a = m.run(prog_a, store=store)
    assert art_a.provenance == ""  # self-trained
    _, art_b = m.run(prog_b, store=store)
    assert art_b.meta["encoder_reused"]
    assert art_b.provenance == f"enc-{program_fingerprint(prog_a)}"
    assert art_b.key == m.artifact_key(prog_b)  # lookup and save agree
    assert store.has("gcl", art_b.key)
    # a replaying instance adopts the SAME provenance for its next lookups
    m2 = _method("gcl")
    _, art_b2 = m2.run(prog_b, store=store)  # fresh instance: trains on B...
    assert art_b2.provenance == ""           # ...so its key has none
    assert art_b2.key != art_b.key           # the two artifacts coexist


def test_plan_store_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    plan = SamplingPlan(labels=np.array([0, 0, 1]), reps={0: [0], 1: [2]},
                        method="PKA", extra={"k": 2})
    store.save_plan(plan, "pka", "key1")
    loaded = store.load_plan("pka", "key1")
    np.testing.assert_array_equal(loaded.labels, plan.labels)
    assert loaded.reps == plan.reps and loaded.method == "PKA"
    assert loaded.extra["k"] == 2
    assert store.load_plan("pka", "other") is None


# ---------------------------------------------------------------------------
# evaluate(): golden values, hand-computed
# ---------------------------------------------------------------------------

def _metric(cycles, time_s, ipc, sim_time_s, hit):
    return KernelMetrics(cycles=cycles, time_s=time_s, ipc=ipc, l1_hit=hit,
                         l2_hit=hit, occupancy=hit, dram_bytes=0.0,
                         sim_time_s=sim_time_s)


def test_evaluate_golden():
    metrics = [
        _metric(100.0, 1.0, 1.0, 10.0, 0.5),
        _metric(200.0, 2.0, 2.0, 20.0, 0.6),
        _metric(300.0, 3.0, 3.0, 30.0, 0.7),
    ]
    plan = SamplingPlan(labels=np.array([0, 0, 1]), reps={0: [0], 1: [2]},
                        method="test")
    res = evaluate_metrics(plan, metrics, program="p", platform="P1")
    # full: cycles 600, ipc cycle-weighted = (100*1+200*2+300*3)/600
    assert res.full["cycles"] == pytest.approx(600.0)
    assert res.full["ipc"] == pytest.approx(1400.0 / 600.0)
    # sampled: rep 0 carries cluster 0's 2 invocations, rep 2 carries 1
    # -> cycles 100*2 + 300*1 = 500; ipc = (1*200 + 3*300) / 500
    assert res.sampled["cycles"] == pytest.approx(500.0)
    assert res.sampled["ipc"] == pytest.approx(1100.0 / 500.0)
    assert res.error_pct["cycles"] == pytest.approx(100.0 / 6.0)
    assert res.error_pct["ipc"] == pytest.approx(
        abs(1400 / 600 - 1100 / 500) / (1400 / 600) * 100.0)
    # eq. 6: (1+2+3) / (1+3); §5.4 wall time 60 -> 40
    assert res.speedup == pytest.approx(1.5)
    assert res.sim_time_full_s == pytest.approx(60.0)
    assert res.sim_time_sampled_s == pytest.approx(40.0)
    assert res.sim_speedup == pytest.approx(1.5)
    assert res.num_kernels == 3 and res.num_clusters == 2 and res.num_reps == 2


def test_evaluate_multi_rep_cluster_exact():
    """Two reps in one cluster split the cluster's weight evenly."""
    metrics = [
        _metric(100.0, 1.0, 1.0, 10.0, 0.5),
        _metric(200.0, 2.0, 2.0, 20.0, 0.6),
        _metric(300.0, 3.0, 3.0, 30.0, 0.7),
    ]
    plan = SamplingPlan(labels=np.zeros(3, int), reps={0: [0, 2]},
                        method="test")
    res = evaluate_metrics(plan, metrics)
    # share 3/2 per rep: cycles (100 + 300) * 1.5 = 600 == full
    assert res.sampled["cycles"] == pytest.approx(600.0)
    assert res.error_pct["cycles"] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# GCLSampler hardening (satellite)
# ---------------------------------------------------------------------------

def test_embed_before_train_raises_with_hint():
    s = GCLSampler()
    with pytest.raises(RuntimeError, match="train"):
        s.embed([])


# ---------------------------------------------------------------------------
# launch grid results JSON schema (fast: clustering-only methods)
# ---------------------------------------------------------------------------

def test_run_grid_results_schema(tmp_path):
    doc = run_grid(["pka", "sieve"], ["3mm"], ["P1", "P2"],
                   str(tmp_path), verbose=False)
    validate_results(doc)
    assert not doc["failures"]
    assert len(doc["results"]) == 4  # 2 methods x 1 program x 2 platforms
    row = doc["results"][0]
    assert row["error_pct"]["cycles"] >= 0 and row["speedup"] > 0

    import copy
    bad = copy.deepcopy(doc)
    bad["results"][0]["speedup"] = -1.0
    with pytest.raises(ValueError, match="speedup"):
        validate_results(bad)
    bad = copy.deepcopy(doc)
    bad["schema"] = "other/v0"
    with pytest.raises(ValueError, match="schema"):
        validate_results(bad)
    bad = copy.deepcopy(doc)
    del bad["results"][0]["error_pct"]
    with pytest.raises(ValueError, match="error_pct"):
        validate_results(bad)


def test_run_grid_survives_broken_cell(tmp_path):
    doc = run_grid(["pka"], ["no-such-program", "3mm"], ["P1"],
                   str(tmp_path), verbose=False)
    validate_results(doc)
    assert len(doc["failures"]) == 1
    assert "no-such-program" in doc["failures"][0]["cell"]
    assert len(doc["results"]) == 1
