"""Launch-layer tests: collective-bytes HLO parser, roofline math, elastic
checkpoint resharding across mesh shapes (subprocess: needs >1 host device),
and a dry-run smoke cell (subprocess: forces 512 host devices)."""

import json
import os
import subprocess
import sys

import pytest

from repro.launch.roofline import (
    _shape_bytes, collective_bytes_from_hlo, roofline_terms,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


# ---------------------------------------------------------------------------
# HLO collective parser (lines captured from real compiled.as_text() dumps)
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %all-gather.12 = f32[8,2048,6144]{1,0,2} all-gather(%bitcast_copy_fusion.5), channel_id=54, replica_groups=[16,16]<=[256]
  %all-gather.9 = f32[8,16,1,6144]{2,1,0,3} all-gather(%convert_copy_fusion), channel_id=51
  %all-reduce.18 = f32[8,6144,8,2]{3,2,1,0} all-reduce(%convert_bitcast_fusion.2), channel_id=55
  %reduce-scatter.3 = bf16[64,128]{1,0} reduce-scatter(%param.7), channel_id=9
  %collective-permute.1 = bf16[2,4]{1,0} collective-permute(%x), channel_id=3
  %add.5 = f32[8,16]{1,0} add(%a, %b)
  %all-to-all.2 = s32[16,4]{1,0} all-to-all(%y), channel_id=12
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,2048,6144]{1,0,2}") == 8 * 2048 * 6144 * 4
    assert _shape_bytes("bf16[64,128]{1,0}") == 64 * 128 * 2
    assert _shape_bytes("s32[16,4]") == 16 * 4 * 4
    assert _shape_bytes("pred[7]") == 7


def test_collective_parser_counts_and_bytes():
    c = collective_bytes_from_hlo(HLO_SAMPLE)
    assert c["op_counts"]["all-gather"] == 2
    assert c["op_counts"]["all-reduce"] == 1
    assert c["op_counts"]["reduce-scatter"] == 1
    assert c["op_counts"]["collective-permute"] == 1
    assert c["op_counts"]["all-to-all"] == 1
    ag = 8 * 2048 * 6144 * 4 + 8 * 16 * 1 * 6144 * 4
    assert c["by_kind_bytes"]["all-gather"] == ag
    # plain add must not be counted
    assert c["per_device_bytes"] < ag + 8 * 6144 * 8 * 2 * 4 + 64 * 128 * 2 \
        + 2 * 4 * 2 + 16 * 4 * 4 + 1


def test_roofline_terms_math():
    rec = {
        "num_devices": 256,
        "cost": {"flops_per_device": 197e12, "bytes_per_device": 819e9},
        "collectives": {"per_device_bytes": 50e9},
        "model_flops": 197e12 * 256 * 0.5,
    }
    rl = roofline_terms(rec)
    assert rl["compute_s"] == pytest.approx(1.0)
    assert rl["memory_s"] == pytest.approx(1.0)
    assert rl["collective_s"] == pytest.approx(1.0)
    assert rl["useful_flop_ratio"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# elastic resharding across mesh shapes (subprocess with 8 host devices)
# ---------------------------------------------------------------------------

ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

d = "{ckpt}"
state = {{"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}}

# save on a (4, 2) mesh, w sharded over 'a'
mesh1 = jax.make_mesh((4, 2), ("a", "b"))
sh1 = {{"w": NamedSharding(mesh1, P("a", None)), "b": NamedSharding(mesh1, P())}}
state1 = jax.device_put(state, sh1)
mgr = CheckpointManager(d)
mgr.save(5, state1, specs=sh1, blocking=True)

# restore on a (2, 4) mesh with a DIFFERENT sharding
mesh2 = jax.make_mesh((2, 4), ("a", "b"))
sh2 = {{"w": NamedSharding(mesh2, P(None, "b")), "b": NamedSharding(mesh2, P())}}
restored, step = mgr.restore(jax.eval_shape(lambda: state), shardings=sh2)
assert step == 5
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
assert restored["w"].sharding.spec == P(None, "b")
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    script = ELASTIC_SCRIPT.format(ckpt=str(tmp_path / "ck"))
    out = subprocess.run(
        [sys.executable, "-c", script], env=ENV, capture_output=True,
        text=True, timeout=300,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# dry-run smoke (subprocess: 512 host devices; lightest cell)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_smoke_cell(tmp_path):
    out_json = str(tmp_path / "dr.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-780m", "--shape", "long_500k", "--out", out_json],
        env=ENV, capture_output=True, text=True, timeout=500, cwd=REPO,
    )
    assert "1 ok" in out.stdout, out.stdout + out.stderr[-1500:]
    with open(out_json) as f:
        rec = json.load(f)[0]
    assert rec["status"] == "ok"
    assert rec["num_devices"] == 256
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
