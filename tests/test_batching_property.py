"""Property-based tests (hypothesis) for packed-batch invariants.

Owns the PR-9 acceptance property: the degree normalizer hoisted into
``core/batching.pack_graphs`` (packed-batch schema v2, ``edge_norm``) is
BIT-exact against the per-layer jnp recomputation it replaced
(``core.rgcn.edge_norm_packed``), for arbitrary packed batches — including
the bucket-padding rows, which both paths clamp to a degree of 1.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.batching import pack_graphs
from repro.core.graphs import NUM_RELATIONS, build_kernel_graph
from repro.core.rgcn import edge_norm_packed
from repro.tracing.templates import make_kernel


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(0, 10_000))
def test_precomputed_edge_norm_matches_recompute(n_graphs, seed):
    rng = np.random.default_rng(seed)
    ks = [
        make_kernel(
            f"g{i}", "gemm",
            {"M": 128 * int(rng.integers(1, 4)), "N": 128, "K": 128},
            i, seed=int(rng.integers(0, 1 << 16)),
        )
        for i in range(n_graphs)
    ]
    graphs = [build_kernel_graph(k.trace(cap_warps=2, cap_instr=24))
              for k in ks]
    packed, _ = pack_graphs(graphs)
    assert packed["edge_norm"].dtype == np.float32
    recomputed = edge_norm_packed(
        jnp.asarray(packed["edge_dst"]), jnp.asarray(packed["edge_type"]),
        jnp.asarray(packed["edge_mask"]), packed["node_mask"].shape[0],
        NUM_RELATIONS,
    )
    assert np.array_equal(np.asarray(recomputed), packed["edge_norm"])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 200), st.integers(1, 64), st.integers(0, 10_000))
def test_edge_norm_packed_is_inverse_masked_degree(Q, P, seed):
    """Direct property on random (dst, etype, emask): norm[e] is exactly the
    f32 reciprocal of the masked in-degree of (dst_e, etype_e), clamped >= 1
    — zero-degree (fully masked) keys get norm 1, never inf/NaN."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, P, Q).astype(np.int32)
    etype = rng.integers(0, NUM_RELATIONS, Q).astype(np.int32)
    emask = (rng.random(Q) < 0.7).astype(np.float32)
    norm = np.asarray(edge_norm_packed(
        jnp.asarray(dst), jnp.asarray(etype), jnp.asarray(emask),
        P, NUM_RELATIONS))
    deg = np.zeros((P, NUM_RELATIONS), np.float32)
    np.add.at(deg, (dst, etype), emask)
    expect = np.float32(1.0) / np.maximum(deg[dst, etype], np.float32(1.0))
    assert np.array_equal(norm, expect)
    assert np.isfinite(norm).all()
