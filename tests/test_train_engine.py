"""Compiled training engine (core/train.py, DESIGN.md §4-§6): scan-vs-shim
parity, bit-exact interrupt/resume, eval-mode validation loss, host-sync
accounting, precision policy, loss scaling, and packed-batch sharding."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import rgcn as rgcn_mod
from repro.core.batching import pack_graphs, plan_epoch
from repro.core.graphs import build_kernel_graph
from repro.core.precision import Policy, get_policy
from repro.core.rgcn import RGCNConfig
from repro.core.train import (
    ContrastiveTrainer, FitInterrupted, GCLTrainConfig, METRIC_KEYS,
    packed_loss,
)
from repro.distributed.sharding import MeshRules, constrain_batch
from repro.tracing.templates import make_kernel


def _graphs(n=6, cap=48):
    ks = [
        make_kernel(f"k{i}", "gemm",
                    {"M": 128 * (i % 3 + 1), "N": 128, "K": 128}, i, seed=i)
        for i in range(n)
    ]
    return [build_kernel_graph(k.trace(cap_warps=2, cap_instr=cap)) for k in ks]


GRAPHS = _graphs()


def _tc(**kw):
    base = dict(steps=8, batch_size=4, scan_chunk=4, log_every=50)
    base.update(kw)
    return GCLTrainConfig(**base)


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


# ---------------------------------------------------------------------------
# engine parity + host-sync accounting
# ---------------------------------------------------------------------------


def test_scan_engine_matches_python_shim():
    """Same seed -> the compiled scan engine and the per-step shim must
    produce the same loss trajectory and parameters (they share the loss;
    only execution differs)."""
    p_scan, i_scan = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="scan")).fit(GRAPHS)
    p_py, i_py = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="python")).fit(GRAPHS)

    l_scan = np.array([h["loss"] for h in i_scan["history"]])
    l_py = np.array([h["loss"] for h in i_py["history"]])
    assert len(l_scan) == len(l_py) == 8
    np.testing.assert_allclose(l_scan, l_py, atol=1e-5, rtol=1e-5)
    for a, b in zip(_leaves(p_scan), _leaves(p_py)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
    # every metric key present in both histories
    assert set(i_scan["history"][0]) == set(METRIC_KEYS)
    assert set(i_py["history"][0]) == set(METRIC_KEYS)
    # eval-mode validation ran in both engines
    assert "val_loss" in i_scan and "val_loss" in i_py
    assert np.isclose(i_scan["val_loss"], i_py["val_loss"], atol=1e-5)


def test_prefetch_fit_bit_exact_vs_inline_staging():
    """Double-buffered host->device staging (GCLTrainConfig.prefetch) rides
    a background thread but stages the SAME arrays in the SAME order with
    the SAME fold_in keys — the trajectory must be bit-exact vs inline
    staging, and the overlap accounting must be reported."""
    p_pre, i_pre = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="scan", prefetch=True)).fit(GRAPHS)
    p_off, i_off = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="scan", prefetch=False)).fit(GRAPHS)

    for a, b in zip(_leaves(p_pre), _leaves(p_off)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    l_pre = [h["loss"] for h in i_pre["history"]]
    l_off = [h["loss"] for h in i_off["history"]]
    np.testing.assert_array_equal(l_pre, l_off)

    assert i_pre["prefetch"] is True and i_off["prefetch"] is False
    assert i_pre["prefetch_stage_s"] > 0
    assert 0.0 <= i_pre["prefetch_overlap"] <= 1.0
    # inline staging by definition overlaps nothing
    assert i_off["prefetch_overlap"] == 0.0


def test_scan_host_syncs_bounded_by_log_every():
    """The engine's selling point: metrics cross to the host only at
    log_every boundaries (+ the final flush and the val pull), not per
    step — the shim syncs every step."""
    _, info = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="scan", log_every=4)).fit(GRAPHS)
    windows = -(-8 // 4)  # ceil(steps / log_every)
    assert info["host_syncs"] <= windows + 2  # + final flush + val
    _, info_py = ContrastiveTrainer(
        RGCNConfig(), _tc(engine="python")).fit(GRAPHS)
    assert info_py["host_syncs"] >= 8  # one per step (+ val)
    assert info["engine"] == "scan" and info_py["engine"] == "python"


def test_epoch_plan_covers_steps_in_order():
    sel = np.array([[0, 1, 2, 3], [2, 3, 4, 5], [0, 0, 1, 1], [4, 5, 0, 1]])
    plan = plan_epoch(GRAPHS, sel)
    assert plan.n_steps == 4
    covered = []
    for seg in plan.segments:
        assert seg.stop > seg.start
        assert all(v.shape[0] == len(seg) for v in seg.batches.values())
        covered.extend(range(seg.start, seg.stop))
    assert covered == [0, 1, 2, 3]
    # stacked rows reproduce a fresh per-step pack exactly
    seg0 = plan.segments[0]
    packed, _ = pack_graphs([GRAPHS[i] for i in sel[seg0.start]])
    for k, v in packed.items():
        np.testing.assert_array_equal(seg0.batches[k][0], v)


# ---------------------------------------------------------------------------
# interrupt / resume
# ---------------------------------------------------------------------------


def test_interrupt_resume_bit_exact(tmp_path):
    """A fit interrupted at step k and resumed must reproduce the
    uninterrupted run's params AND history bit-exactly (chunks are masked
    per step, so the resume boundary cannot change the math)."""
    tc = _tc(steps=12, checkpoint_every=4)
    p_full, i_full = ContrastiveTrainer(RGCNConfig(), tc).fit(GRAPHS)

    ck = str(tmp_path / "ck")
    with pytest.raises(FitInterrupted):
        ContrastiveTrainer(RGCNConfig(), tc).fit(
            GRAPHS, checkpoint_dir=ck, interrupt_after=8)
    assert CheckpointManager(ck).latest_step() == 8

    p_res, i_res = ContrastiveTrainer(RGCNConfig(), tc).fit(
        GRAPHS, checkpoint_dir=ck)
    assert i_res["resumed_from"] == 8
    for a, b in zip(_leaves(p_full), _leaves(p_res)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in i_full["history"]] == \
        [h["loss"] for h in i_res["history"]]
    assert i_full["val_loss"] == i_res["val_loss"]


def test_resume_refuses_foreign_seed(tmp_path):
    ck = str(tmp_path / "ck")
    tc = _tc(steps=12, checkpoint_every=4)
    with pytest.raises(FitInterrupted):
        ContrastiveTrainer(RGCNConfig(), tc).fit(
            GRAPHS, checkpoint_dir=ck, interrupt_after=4)
    with pytest.raises(ValueError, match="different seed"):
        ContrastiveTrainer(RGCNConfig(), _tc(steps=12, checkpoint_every=4,
                                             seed=1)).fit(
            GRAPHS, checkpoint_dir=ck)


def test_python_engine_rejects_checkpointing(tmp_path):
    with pytest.raises(ValueError, match="scan"):
        ContrastiveTrainer(RGCNConfig(), _tc(engine="python")).fit(
            GRAPHS, checkpoint_dir=str(tmp_path / "ck"))


def test_restore_tree_round_trip(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    tree = {
        "state": {"params": {"layers": [np.arange(4.0), np.ones((2, 3))]}},
        "cursor": np.int64(7),
        "hist": {"loss": np.array([1.0, 0.5], np.float32)},
    }
    mgr.save(7, tree, blocking=True)
    got, step = mgr.restore_tree()
    assert step == 7
    assert int(got["cursor"]) == 7
    np.testing.assert_array_equal(got["state"]["params"]["layers"][0],
                                  tree["state"]["params"]["layers"][0])
    np.testing.assert_array_equal(got["state"]["params"]["layers"][1],
                                  tree["state"]["params"]["layers"][1])
    np.testing.assert_array_equal(got["hist"]["loss"], tree["hist"]["loss"])


def test_gcl_prepare_resumes_and_store_replays(tmp_path):
    """Store-level resume protocol: an interrupted gcl prepare() resumes
    from the last checkpoint instead of refitting, produces the SAME
    encoder as an uninterrupted fit, and a later run() replays the stored
    artifact outright."""
    from repro.core.sampler import GCLSampler, GCLSamplerConfig
    from repro.sampling import ArtifactStore, get_method
    from repro.tracing.programs import get_program

    prog = get_program("3mm")
    cfg = GCLSamplerConfig(
        cap_instr=48,
        train=_tc(checkpoint_every=4))
    kw = dict(cfg=cfg)
    store = ArtifactStore(str(tmp_path / "store"))

    m1 = get_method("gcl", **kw)
    m1.attach_store(store)
    ckdir = m1._fit_checkpoint_dir(prog)
    assert ckdir is not None and ckdir.startswith(store.root)

    # simulate the killed prepare(): identical sampler config, same
    # checkpoint dir, interrupted mid-fit
    crashed = GCLSampler(m1.cfg)
    graphs = crashed.build_graphs(prog)
    with pytest.raises(FitInterrupted):
        crashed.trainer.fit(graphs, checkpoint_dir=ckdir, interrupt_after=4)

    plan, art = m1.run(prog, store=store)
    assert art.meta["train"]["resumed_from"] == 4

    # resumed encoder == uninterrupted encoder (fresh store => its own
    # checkpoint dir is empty, so this fit runs start-to-finish)
    m2 = get_method("gcl", **kw)
    _, art2 = m2.run(prog, store=ArtifactStore(str(tmp_path / "store2")))
    assert art2.meta["train"]["resumed_from"] == 0
    for a, b in zip(_leaves(art.payload["params"]),
                    _leaves(art2.payload["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # a fresh method replays the resumed artifact from the store (no refit)
    m3 = get_method("gcl", **kw)
    m3.attach_store(store)
    assert store.has("gcl", m3.artifact_key(prog))
    _, art3 = m3.run(prog, store=store)
    assert art3.meta["train"]["resumed_from"] == 4  # the stored fit's meta
    assert m3.sampler.params is not None            # encoder adopted


# ---------------------------------------------------------------------------
# eval-mode validation loss
# ---------------------------------------------------------------------------


def test_eval_loss_is_deterministic_and_dropout_free():
    """The val block advertises "no dropout/noise, fixed augs": eval mode
    must be a pure function of (params, batch, key) and differ from the
    stochastic train-mode loss."""
    rc = RGCNConfig()
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(0), rc)
    packed, _ = pack_graphs(GRAPHS[:4])
    batch = {k: jnp.asarray(v) for k, v in packed.items()}
    key = jax.random.PRNGKey(123)

    e1, m1 = packed_loss(params, rc, 0.05, batch, key, train=False)
    e2, m2 = packed_loss(params, rc, 0.05, batch, key, train=False)
    assert float(e1) == float(e2)
    assert float(m1["nce_acc"]) == float(m2["nce_acc"])

    t1, _ = packed_loss(params, rc, 0.05, batch, key, train=True)
    assert float(t1) != float(e1)  # dropout + noise + gated augs active


# ---------------------------------------------------------------------------
# precision policy + loss scaling
# ---------------------------------------------------------------------------


def test_bf16_policy_encodes_close_to_f32():
    rc32 = RGCNConfig()
    rc16 = RGCNConfig(policy=get_policy("bf16"))
    params = rgcn_mod.init_rgcn(jax.random.PRNGKey(1), rc32)
    packed, _ = pack_graphs(GRAPHS[:4])
    batch = {k: jnp.asarray(v) for k, v in packed.items()}
    z32 = np.asarray(rgcn_mod.encode_packed(params, rc32, batch))
    z16 = np.asarray(rgcn_mod.encode_packed(params, rc16, batch))
    assert z16.dtype == np.float32  # readout is upcast
    assert np.all(np.isfinite(z16))
    # bf16 has ~3 decimal digits; embeddings must stay close in direction
    cos = np.sum(z32 * z16, -1) / (
        np.linalg.norm(z32, axis=-1) * np.linalg.norm(z16, axis=-1) + 1e-9)
    assert np.all(cos > 0.99)


def test_pow2_loss_scale_is_bit_neutral():
    """Scaling the loss by a power of two and unscaling the grads inside
    AdamW is exact in f32 — the trajectory must be identical to scale=1."""
    rc_scaled = RGCNConfig(policy=Policy(loss_scale=256.0))
    p0, i0 = ContrastiveTrainer(RGCNConfig(), _tc(steps=4)).fit(GRAPHS)
    p1, i1 = ContrastiveTrainer(rc_scaled, _tc(steps=4)).fit(GRAPHS)
    l0 = [h["loss"] for h in i0["history"]]
    l1 = [h["loss"] for h in i1["history"]]
    np.testing.assert_allclose(l0, l1, atol=0, rtol=0)
    for a, b in zip(_leaves(p0), _leaves(p1)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # grad_norm is reported UNSCALED
    assert np.isclose(i0["history"][0]["grad_norm"],
                      i1["history"][0]["grad_norm"], rtol=1e-6)


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def test_constrain_batch_no_rules_is_identity():
    packed, _ = pack_graphs(GRAPHS[:2])
    batch = {k: jnp.asarray(v) for k, v in packed.items()}
    out = constrain_batch(batch)
    assert out is batch or all(out[k] is batch[k] for k in batch)


def test_scan_engine_under_mesh_rules_matches_unsharded():
    """A 1x1 mesh makes every sharding constraint a layout no-op, so the
    scanned fit under MeshRules must reproduce the unsharded fit."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    rules = MeshRules(mesh=mesh)
    p_plain, i_plain = ContrastiveTrainer(
        RGCNConfig(), _tc(steps=4)).fit(GRAPHS)
    p_mesh, i_mesh = ContrastiveTrainer(
        RGCNConfig(), _tc(steps=4), mesh_rules=rules).fit(GRAPHS)
    np.testing.assert_allclose(
        [h["loss"] for h in i_plain["history"]],
        [h["loss"] for h in i_mesh["history"]], atol=1e-6)
    for a, b in zip(_leaves(p_plain), _leaves(p_mesh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
