"""repro.serving: continuous batcher, warm pool, loadgen, tenant serving."""

import threading

import numpy as np
import pytest

from repro.core import clustering
from repro.core.clustering import select_k_and_cluster
from repro.sampling import ArtifactStore, get_method
from repro.sampling.base import plan_from_labels
from repro.sampling.engine import (
    PlanEngine, PlanRequest, bucket_key, normalize_embeddings,
)
from repro.serving import (
    PlanService, parse_buckets, poisson_arrivals, run_open_loop,
    synthetic_fleet,
)
from repro.sim.simulate import SamplingPlan
from repro.tracing.programs import get_program

KW = dict(k_max=6, iters=10)


def _req(n, d=8, seed=0, method="t"):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    return PlanRequest(x, np.arange(n), method, seed=seed)


class _GateEngine(PlanEngine):
    """Engine whose dispatches block on an event + log their batch."""

    def __init__(self, gate, calls, **kw):
        super().__init__(**kw)
        self.gate, self.calls = gate, calls

    def plan_many(self, requests, errors="raise"):
        self.gate.wait(5.0)
        self.calls.append([bucket_key(r.embeddings) for r in requests])
        return super().plan_many(requests, errors=errors)


def test_parse_buckets():
    assert parse_buckets("64x16,128x8") == [(64, 16), (128, 8)]
    assert parse_buckets(" 32x4 ,") == [(32, 4)]


def test_fill_flush_batches_same_bucket():
    gate = threading.Event()
    calls = []
    eng = _GateEngine(gate, calls, max_batch=4, **KW)
    with PlanService(eng, max_batch=4, max_delay_ms=10_000.0) as svc:
        futs = [svc.submit(_req(40, seed=i)) for i in range(4)]
        gate.set()  # requests queue while the dispatcher is held
        plans = [f.result(10.0) for f in futs]
    assert all(isinstance(p, SamplingPlan) for p in plans)
    # one full-batch dispatch, counted as a fill flush
    assert [len(c) for c in calls] == [4]
    s = svc.stats()
    assert s["flush_causes"]["fill"] == 1
    assert s["served"] == 4 and s["failed"] == 0
    assert s["batch_occupancy"] == 1.0


def test_deadline_flush_partial_batch():
    with PlanService(max_batch=8, max_delay_ms=5.0, **KW) as svc:
        plan = svc.submit(_req(40)).result(30.0)
    assert isinstance(plan, SamplingPlan)
    s = svc.stats()
    assert s["flush_causes"]["deadline"] + s["flush_causes"]["drain"] >= 1
    assert s["flush_causes"]["fill"] == 0


def test_bucket_isolation_interleaved_sizes():
    """Interleaved submissions never share a dispatch across buckets."""
    gate = threading.Event()
    calls = []
    eng = _GateEngine(gate, calls, max_batch=4, **KW)
    with PlanService(eng, max_batch=4, max_delay_ms=10_000.0) as svc:
        futs = []
        for i in range(4):  # alternate 64-point and 128-point buckets
            futs.append(svc.submit(_req(40, seed=i)))
            futs.append(svc.submit(_req(100, seed=10 + i)))
        gate.set()
        for f in futs:
            assert isinstance(f.result(10.0), SamplingPlan)
    assert len(calls) == 2
    for batch in calls:
        assert len(set(batch)) == 1  # every dispatch is single-bucket
    assert {batch[0] for batch in calls} == {(64, 8), (128, 8)}


def test_served_plans_match_sequential_reference():
    fleet = synthetic_fleet(6, d=8, seed=3)
    with PlanService(max_batch=4, max_delay_ms=2.0, **KW) as svc:
        plans = [f.result(60.0) for f in [svc.submit(r) for r in fleet]]
    for req, plan in zip(fleet, plans):
        labels, info = select_k_and_cluster(
            normalize_embeddings(req.embeddings), seed=req.seed, **KW)
        ref = plan_from_labels(labels, req.seqs, req.method, extra=info)
        assert np.array_equal(ref.labels, plan.labels)
        assert ref.reps == plan.reps
        assert plan.extra["k"] == info["k"]
        # record_timings (on by default for service-owned engines) stamps
        # dispatch telemetry into the plan
        assert plan.extra["serve"]["points_bucket"] == bucket_key(
            req.embeddings)[0]


def test_warmup_takes_builds_off_serving_path():
    clustering._ENGINE_CACHE.clear()
    with PlanService(max_batch=4, max_delay_ms=2.0, **KW) as svc:
        built = svc.warmup("64x8", batch_sizes=[1, 2, 4])
        assert built > 0
        assert svc.warmup([(64, 8)], batch_sizes=[1, 2, 4]) == 0  # idempotent
        before = clustering.ENGINE_STATS["builds"]
        futs = [svc.submit(_req(40, seed=i)) for i in range(5)]
        for f in futs:
            assert isinstance(f.result(30.0), SamplingPlan)
    assert clustering.ENGINE_STATS["builds"] == before
    assert svc.stats()["engine"]["warmed_executables"] == built


def test_poison_request_fails_only_its_future():
    with PlanService(max_batch=4, max_delay_ms=10_000.0, **KW) as svc:
        bad = svc.submit(PlanRequest(np.float32(3.0), np.arange(1), "bad"))
        good = [svc.submit(_req(40, seed=i)) for i in range(4)]
        with pytest.raises(ValueError):
            bad.result(10.0)
        for f in good:
            assert isinstance(f.result(10.0), SamplingPlan)
    s = svc.stats()
    assert s["failed"] == 1 and s["served"] == 4


def test_submit_after_close_fails_cleanly():
    svc = PlanService(max_batch=2, max_delay_ms=1.0, **KW)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(_req(16)).result(5.0)


def test_close_drains_pending_requests():
    gate = threading.Event()
    eng = _GateEngine(gate, [], max_batch=8, **KW)
    svc = PlanService(eng, max_batch=8, max_delay_ms=10_000.0)
    futs = [svc.submit(_req(40, seed=i)) for i in range(3)]
    gate.set()
    svc.close()
    for f in futs:
        assert isinstance(f.result(1.0), SamplingPlan)
    s = svc.stats()
    assert s["flush_causes"]["drain"] == 1 and s["served"] == 3


def test_submit_program_pka_and_sieve_fallback(tmp_path):
    prog = get_program("3mm")
    store = ArtifactStore(str(tmp_path), cache=True)
    method = get_method("pka")
    with PlanService(max_batch=4, max_delay_ms=2.0,
                     k_max=method.k_max, seed=method.seed) as svc:
        served = svc.submit_program(method, prog, store=store).result(120.0)
        direct, _ = get_method("pka").run(prog, store=store)
        # sieve has no engine request -> resolved via its own plan, already
        # done when the future comes back
        fb = svc.submit_program(get_method("sieve"), prog, store=store)
        assert fb.done() and isinstance(fb.result(), SamplingPlan)
    assert np.array_equal(served.labels, direct.labels)
    assert served.reps == direct.reps
    # the second pka prepare replayed through the in-process cache
    assert store.cache_stats["hits"] >= 1


def test_submit_program_gcl_replays_encoder(tmp_path):
    prog = get_program("3mm")
    store = ArtifactStore(str(tmp_path), cache=True)
    gcl_kw = dict(steps=6, batch_size=4, cap_instr=48)
    m1 = get_method("gcl", **gcl_kw)
    with PlanService(max_batch=4, max_delay_ms=2.0,
                     k_max=m1.cfg.k_max, seed=m1.cfg.train.seed) as svc:
        p1 = svc.submit_program(m1, prog, store=store).result(240.0)
        # a SECOND tenant with the same config replays the stored encoder
        # through the in-process artifact cache: no refit
        m2 = get_method("gcl", **gcl_kw)
        calls = {"prepare": 0}
        orig = m2.prepare

        def counting_prepare(program):
            calls["prepare"] += 1
            return orig(program)

        m2.prepare = counting_prepare
        p2 = svc.submit_program(m2, prog, store=store).result(240.0)
    assert calls["prepare"] == 0
    assert np.array_equal(p1.labels, p2.labels)
    assert p1.reps == p2.reps
    assert store.cache_stats["hits"] >= 1


def test_loadgen_poisson_and_open_loop():
    arr = poisson_arrivals(50, rate_hz=100.0, seed=0)
    assert len(arr) == 50 and np.all(np.diff(arr) > 0)
    assert 0.1 < arr[-1] < 2.5  # ~0.5s expected span

    fleet = synthetic_fleet(8, d=8, seed=1)
    with PlanService(max_batch=4, max_delay_ms=2.0, **KW) as svc:
        svc.warmup(sorted({bucket_key(r.embeddings) for r in fleet}))
        res = run_open_loop(svc, fleet, rate_hz=200.0, seed=2)
    assert res.n_ok == 8 and res.n_err == 0
    assert res.latency_ms["p99"] >= res.latency_ms["p50"] > 0
    assert res.plans_per_s > 0
    j = res.to_json()
    assert j["service"]["served"] == 8
    assert j["service"]["engine"]["programs"] == 8


def test_stats_reset_windows_counters():
    with PlanService(max_batch=2, max_delay_ms=2.0, **KW) as svc:
        svc.submit(_req(16)).result(30.0)
        assert svc.stats()["served"] == 1
        svc.reset_stats()
        s = svc.stats()
        assert s["served"] == 0 and s["latency_ms"]["p50"] is None
        assert s["engine"]["programs"] == 0
