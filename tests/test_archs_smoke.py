"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes and no NaNs (assignment f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_arch, list_archs, smoke_arch
from repro.data import TokenStream
from repro.launch.steps import make_train_step
from repro.models import decode_step, init_decode_state, init_params, prefill
from repro.optim import adamw_init

ALL_ARCHS = list_archs()


def _batch(cfg, B=2, S=32, seed=0):
    ds = TokenStream(cfg.vocab_size, S, B, seed=seed, frontend=cfg.frontend,
                     d_model=cfg.d_model, frontend_tokens=cfg.frontend_tokens)
    return {k: jnp.asarray(v) for k, v in ds.next().items()}


def test_all_archs_registered():
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_smoke_train_step(arch_id):
    cfg = smoke_arch(arch_id)
    assert cfg.num_layers == cfg.block_size  # one block
    params, axes = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=4)
    state = adamw_init(params, tcfg)
    batch = _batch(cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # params changed and stayed finite
    l0 = jax.tree_util.tree_leaves(new_state.params)
    assert all(np.isfinite(np.asarray(x)).all() for x in l0)


@pytest.mark.parametrize("arch_id", ["qwen2-72b", "grok-1-314b", "yi-34b",
                                     "granite-3-2b", "musicgen-medium"])
def test_smoke_decode_shapes(arch_id):
    cfg = smoke_arch(arch_id)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    state = init_decode_state(cfg, B, max_seq=16)
    logits, state = decode_step(cfg, params, state, jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert int(state["index"]) == 1
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch_id", ["llama3.2-3b", "mamba2-780m",
                                     "jamba-v0.1-52b", "dbrx-132b",
                                     "paligemma-3b"])
def test_decode_matches_prefill(arch_id):
    """KV/SSM-cache decode reproduces teacher-forced prefill logits.
    capacity_factor is raised so MoE token-drop (a prefill-vs-decode
    semantic difference by design) doesn't mask cache bugs."""
    cfg = smoke_arch(arch_id).replace(attn_chunk_threshold=10**9,
                                      capacity_factor=8.0)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend == "vision":
        fe = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model)
        )
    ref_logits, _, _ = prefill(cfg, params, tokens, fe)
    # decode path needs the same prefix: feed image-less text decode only for
    # non-frontend archs; for vlm, decode from scratch is a different prefix,
    # so only test shape there.
    if cfg.frontend == "vision":
        return
    state = init_decode_state(cfg, B, max_seq=S)
    for t in range(S):
        lg, state = decode_step(cfg, params, state, tokens[:, t : t + 1])
    ref = np.asarray(ref_logits)
    got = np.asarray(lg)
    rel = np.max(np.abs(got - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 2e-2, rel


def test_full_configs_match_assignment():
    """Published config numbers (assignment block) are encoded exactly."""
    qwen = get_arch("qwen2-72b")
    assert (qwen.num_layers, qwen.d_model, qwen.num_heads,
            qwen.num_kv_heads, qwen.d_ff, qwen.vocab_size) == (
        80, 8192, 64, 8, 29568, 152064)
    assert qwen.qkv_bias
    grok = get_arch("grok-1-314b")
    assert (grok.num_experts, grok.top_k) == (8, 2)
    dbrx = get_arch("dbrx-132b")
    assert (dbrx.num_experts, dbrx.top_k) == (16, 4)
    mam = get_arch("mamba2-780m")
    assert (mam.num_heads, mam.d_ff, mam.ssm_state) == (0, 0, 128)
    jam = get_arch("jamba-v0.1-52b")
    specs = jam.layer_specs()
    assert sum(1 for s in specs if s.mixer == "attention") == 1  # 1:7
    assert sum(1 for s in specs if s.ffn == "moe") == 4  # every other layer
    pal = get_arch("paligemma-3b")
    assert (pal.num_kv_heads, pal.head_dim, pal.frontend_tokens) == (1, 256, 256)


def test_param_counts_plausible():
    from repro.config import param_counts

    approx = {
        "qwen2-72b": 72e9, "yi-34b": 34e9, "grok-1-314b": 314e9,
        "dbrx-132b": 132e9, "llama3.2-3b": 3.2e9, "granite-3-2b": 2.6e9,
        "mamba2-780m": 0.78e9, "jamba-v0.1-52b": 52e9,
    }
    for arch, expect in approx.items():
        got = param_counts(get_arch(arch))["total"]
        assert 0.55 * expect < got < 1.45 * expect, (arch, got, expect)
